#!/usr/bin/env bash
# Tier-1 smoke: the fast test tier, the interp microbench at toy size
# (plan/batch/ghost-exchange regressions fail fast: the suite asserts the
# counted collective-permute structure on every run), plus one tiny
# coarse-to-fine registration end-to-end (restrict -> coarse GN solve ->
# prolong warm start -> fine GN solve -> diffeomorphism check).  Total
# budget ~3 min on the CPU container.
#
#     bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q -m "not slow"

# toy-size interp suite: writes results/BENCH_interp_toy.json (gitignored),
# never the committed BENCH_interp.json record
BENCH_INTERP_TOY=1 python -m benchmarks.run --suite interp

python - <<'EOF'
import jax.numpy as jnp
from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic
from repro.multilevel.hierarchy import MultilevelConfig

rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
cfg = RegistrationConfig(multilevel=MultilevelConfig(
    solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30),
    n_levels=2,
))
out = register(rho_R, rho_T, cfg, grid=grid)
assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6, out["history"][-1]
assert out["det_min"] > 0.0, out["det_min"]
assert len(out["levels"]) == 2, out["levels"]
print("smoke 2-level registration OK:",
      f"fine matvecs={out['fine_matvecs']}",
      f"fine-equiv={out['fine_equiv_matvecs']:.1f}",
      f"residual_rel={out['residual_rel']:.3f}")
EOF

echo "tier-1 smoke PASSED"

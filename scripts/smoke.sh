#!/usr/bin/env bash
# Tier-1 smoke: the fast test tier, the interp + fft microbenches at toy
# size (plan/batch/ghost-exchange and transform-coalescing/chunked-FFT
# regressions fail fast: both suites assert their counted collective
# structure on every run), one tiny coarse-to-fine registration
# end-to-end (restrict -> coarse GN solve -> prolong warm start -> fine
# GN solve -> diffeomorphism check), and a toy 3-level V-cycle cell
# (Galerkin multigrid preconditioner vs spectral).
# Total budget ~8 min on the CPU container.
#
#     bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q -m "not slow"

# toy-size interp suite: writes results/BENCH_interp_toy.json (gitignored),
# never the committed BENCH_interp.json record
BENCH_INTERP_TOY=1 python -m benchmarks.run --suite interp

# toy-size fft suite: counted all-to-alls for the coalesced GN matvec and
# the stage-A SpectralBatch ride, packed-vs-unpacked bytes, chunked-FFT
# parity — writes results/BENCH_fft_toy.json (gitignored) and asserts the
# >= 2x coalescing structure on every run
BENCH_FFT_TOY=1 python -m benchmarks.run --suite fft

# toy-size multilevel suite: C2F record + the spectral/two-level/V-cycle
# precond sweep at 16^3, written to results/BENCH_multilevel_toy.json
# (gitignored) — exercises the merge-aware record writer every run
BENCH_ML_TOY=1 python -m benchmarks.run --suite multilevel

# toy-size cohort suite: S=2 solve_cohort vs 2 independent solves (billing
# parity + one-executable invariant) and a 3-job/2-slot serve session —
# writes results/BENCH_cohort_toy.json (gitignored)
BENCH_COHORT_TOY=1 python -m benchmarks.run --suite cohort

# toy-size blocks suite: a real tiled 32^3 blockwise solve vs monolithic
# (residual within 10%, ONE compiled executable for all 8 blocks) plus the
# 4096^3 partition dry-run accounting — writes results/BENCH_blocks_toy.json
# (gitignored) and asserts both invariants on every run
BENCH_BLOCKS_TOY=1 python -m benchmarks.run --suite blocks

# toy-size autotune sweep: two 2-cell coordinate-descent sweeps on an
# 8-host-device 2x4 mesh, then a second pass that must resolve every cell
# from the tuning cache without re-sweeping — writes
# results/autotune_toy.json (gitignored)
BENCH_AUTOTUNE_TOY=1 python -m benchmarks.run --suite autotune

# toy-size resilience suite: NaN-injected serve vs healthy baseline
# (un-faulted bit-identical, ONE executable) plus kill+resume — writes
# results/BENCH_resilience_toy.json (gitignored) and asserts the chaos
# invariants on every run
BENCH_RESILIENCE_TOY=1 python -m benchmarks.run --suite resilience

# telemetry trace (ISSUE 7): the 2-level registration below and a toy
# 6-job/3-slot serve session both write results/smoke_trace.jsonl; the
# trace_report CLI renders it and ci.sh schema-validates every record
rm -f results/smoke_trace.jsonl

python - <<'EOF'
import jax.numpy as jnp
from repro import telemetry
from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic
from repro.multilevel.hierarchy import MultilevelConfig

rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
cfg = RegistrationConfig(multilevel=MultilevelConfig(
    solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30),
    n_levels=2,
))
with telemetry.jsonl_sink("results/smoke_trace.jsonl"):
    out = register(rho_R, rho_T, cfg, grid=grid)
assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6, out["history"][-1]
assert out["det_min"] > 0.0, out["det_min"]
assert len(out["levels"]) == 2, out["levels"]
print("smoke 2-level registration OK:",
      f"fine matvecs={out['fine_matvecs']}",
      f"fine-equiv={out['fine_equiv_matvecs']:.1f}",
      f"residual_rel={out['residual_rel']:.3f}")
EOF

# toy cohort-serve session appending to the same trace (per-job billing,
# queue-wait, slot occupancy, and the step program's collective counts)
python -m repro.launch.reg_serve --jobs 6 --slots 3 --size 12 --n-t 2 \
    --max-newton 6 --max-cg 15 --trace results/smoke_trace.jsonl

# chaos cell (ISSUE 10): the same toy serve with a NaN injected into one
# job's iterate — every job completes, the faulted one is retried ONCE
# under the degraded policy, the un-faulted jobs are bit-identical to the
# fault-free run, and the typed FaultEvent/RecoveryEvent land in the same
# trace (ci.sh schema-validates them)
python - <<'EOF'
import numpy as np
from repro import telemetry
from repro.core import gauss_newton as gn
from repro.data import synthetic
from repro.launch.reg_serve import RegJob, serve_jobs
from repro.resilience import health
from repro.resilience.faults import NaNInjector
from repro.resilience.policy import RetryPolicy

cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=6, gtol=1e-2, max_cg=15)
probs = [synthetic.synthetic_problem(12, n_t=2, amplitude=a)
         for a in (0.4, 0.8, 1.2)]
jobs = lambda: [RegJob(job_id=f"job{s}", rho_R=p[0], rho_T=p[1])
                for s, p in enumerate(probs)]
ref = {r.job_id: r for r in serve_jobs(jobs(), cfg, slots=2)["results"]}
fault = NaNInjector(job_id="job1", field="v", at_iteration=1)
with telemetry.jsonl_sink("results/smoke_trace.jsonl"):
    out = serve_jobs(jobs(), cfg, slots=2,
                     retry=RetryPolicy(max_attempts=2), faults=[fault])
res = {r.job_id: r for r in out["results"]}
assert fault.fired and set(res) == set(ref)
assert res["job1"].attempts == 2, res["job1"].attempts
assert res["job1"].status not in health.FAILED_NAMES, res["job1"].status
assert np.isfinite(res["job1"].v).all()
for jid in ("job0", "job2"):
    np.testing.assert_array_equal(res[jid].v, ref[jid].v)
    assert res[jid].attempts == 1 and res[jid].status == ref[jid].status
assert out["compiled_executables"] == 1, out["compiled_executables"]
print("smoke chaos serve OK:",
      f"faulted=job1 status={res['job1'].status} attempts=2",
      f"executables={out['compiled_executables']}")
EOF

# render the per-phase wall/matvec/collective tables off the live trace
python -m repro.analysis.trace_report results/smoke_trace.jsonl

# toy 3-level V-cycle cell: the recursive Galerkin preconditioner must beat
# the spectral preconditioner on fine-grid matvecs in the low-beta regime
python - <<'EOF'
import jax.numpy as jnp
from repro.core import gauss_newton as gn
from repro.data import synthetic
from repro import multilevel
from repro.multilevel.hierarchy import MultilevelConfig

rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
base = gn.GNConfig(beta=1e-4, n_t=4, max_newton=6, gtol=1e-2, max_cg=200)
counts = {}
for kind in ("none", "vcycle"):
    cfg = MultilevelConfig(solver=base, n_levels=3, min_size=4, precond=kind,
                           precond_cg_iters=4, precond_coarse_cg_iters=10,
                           precond_min_size=4)  # recurse the full toy ladder
    out = multilevel.solve(rho_R, rho_T, grid, cfg)
    assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6, out["history"][-1]
    counts[kind] = out
vc, sp = counts["vcycle"], counts["none"]
assert vc["fine_matvecs"] < sp["fine_matvecs"], (vc["fine_matvecs"], sp["fine_matvecs"])
assert vc["precond_fine_equiv_matvecs"] > 0.0
print("smoke 3-level V-cycle OK:",
      f"fine matvecs {sp['fine_matvecs']} (spectral) -> {vc['fine_matvecs']} (vcycle)",
      f"total fine-equiv {sp['total_fine_equiv_matvecs']:.1f} -> "
      f"{vc['total_fine_equiv_matvecs']:.1f}")
EOF

echo "tier-1 smoke PASSED"

#!/usr/bin/env python
"""Line coverage for ``src/repro`` without hard-depending on pytest-cov.

    PYTHONPATH=src python scripts/pycov.py --fail-under 60 -q -m "not slow"

When ``pytest-cov`` (and therefore ``coverage``) is importable, this is a
thin shim over ``pytest --cov=repro --cov-report=term --cov-fail-under=N``
— the standard tool does the measuring.  On containers without the dev
dependency (this repo's baked image has none) it falls back to a stdlib
``sys.settrace`` line tracer:

* only frames whose code object lives under ``src/repro`` get a local
  tracer (everything else returns ``None`` from the global hook, so the
  interpreter skips per-line events there — the fast path stays fast);
* executable lines per file come from compiling the source and walking
  ``dis.findlinestarts`` over the code object tree (the same universe
  ``coverage.py`` uses for statement coverage, minus branch analysis);
* the report is the familiar per-file ``Stmts Miss Cover`` table and the
  exit code honors ``--fail-under`` — so ``scripts/ci.sh`` can gate on a
  floor either way.

The fallback deliberately measures ONLY ``src/repro`` (not tests, not
benchmarks): the gate exists to catch subsystems that lose their tests,
not to audit the test files themselves.
"""
from __future__ import annotations

import argparse
import dis
import os
import sys
import threading

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src", "repro")


def _have_pytest_cov() -> bool:
    try:
        import pytest_cov  # noqa: F401

        return True
    except ImportError:
        return False


def _executable_lines(path: str) -> set[int]:
    """Line numbers of executable statements in ``path`` (code-object walk)."""
    with open(path, "rb") as fh:
        source = fh.read()
    try:
        code = compile(source, path, "exec")
    except SyntaxError:
        return set()
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, ln in dis.findlinestarts(co) if ln is not None)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_code"))
    # a module/class/function docstring compiles to a no-op constant load;
    # keep it — executing the def/module does hit that line — but drop the
    # phantom line 0 some wrappers report
    lines.discard(0)
    return lines


def _iter_source_files():
    for dirpath, _, names in os.walk(SRC):
        for name in sorted(names):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def _run_fallback(pytest_args: list[str], fail_under: float) -> int:
    import pytest

    hit: dict[str, set[int]] = {}
    prefix = SRC + os.sep

    def global_tracer(frame, event, arg):
        if event != "call":
            return None
        fn = frame.f_code.co_filename
        if not (fn.startswith(prefix) or fn == SRC):
            return None
        lines = hit.setdefault(fn, set())

        def local_tracer(frame, event, arg):
            if event == "line":
                lines.add(frame.f_lineno)
            return local_tracer

        lines.add(frame.f_lineno)
        return local_tracer

    threading.settrace(global_tracer)
    sys.settrace(global_tracer)
    try:
        status = pytest.main(pytest_args)
    finally:
        sys.settrace(None)
        threading.settrace(None)

    total_stmts = total_miss = 0
    rows = []
    for path in _iter_source_files():
        stmts = _executable_lines(path)
        if not stmts:
            continue
        miss = stmts - hit.get(path, set())
        total_stmts += len(stmts)
        total_miss += len(miss)
        rows.append((os.path.relpath(path, ROOT), len(stmts), len(miss)))

    name_w = max(len(r[0]) for r in rows)
    print(f"\n{'Name'.ljust(name_w)}  Stmts   Miss  Cover")
    print("-" * (name_w + 21))
    for name, stmts, miss in rows:
        pct = 100.0 * (stmts - miss) / stmts
        print(f"{name.ljust(name_w)}  {stmts:5d}  {miss:5d}  {pct:5.1f}%")
    print("-" * (name_w + 21))
    covered = 100.0 * (total_stmts - total_miss) / max(total_stmts, 1)
    print(f"{'TOTAL'.ljust(name_w)}  {total_stmts:5d}  {total_miss:5d}  {covered:5.1f}%")

    if int(status) != 0:
        return int(status)
    if covered < fail_under:
        print(f"FAIL: coverage {covered:.1f}% < --fail-under {fail_under:.1f}%")
        return 2
    print(f"coverage {covered:.1f}% >= {fail_under:.1f}% (settrace fallback)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0], allow_abbrev=False
    )
    ap.add_argument("--fail-under", type=float, default=0.0,
                    help="minimum TOTAL percent; exit nonzero below it")
    args, pytest_args = ap.parse_known_args()

    if _have_pytest_cov():
        import pytest

        return int(
            pytest.main(
                [
                    "--cov=repro",
                    "--cov-report=term",
                    f"--cov-fail-under={args.fail_under}",
                    *pytest_args,
                ]
            )
        )
    return _run_fallback(pytest_args, args.fail_under)


if __name__ == "__main__":
    sys.exit(main())

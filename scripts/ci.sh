#!/usr/bin/env bash
# CI entry point — what a checks job runs on every push.
#
#     bash scripts/ci.sh          # fast tier + toy benchmark cells (~10 min)
#     CI_SLOW=1 bash scripts/ci.sh   # additionally the slow/dist tier
#
# After the smoke gate, every telemetry record the smoke run emitted is
# validated against the versioned event schema (repro.telemetry.events):
# a drifted emitter fails CI here, not in a downstream trace consumer.
#
# The fast gate is scripts/smoke.sh: the `-m "not slow"` test tier (every
# counted-collective pin, the masked-cohort parity pins, the bugfix
# regression tests) plus the toy interp/fft/multilevel/cohort benchmark
# cells — including the S=2 `solve_cohort` billing-parity +
# one-executable smoke cell — and two tiny end-to-end registrations.
# The slow tier adds the subprocess multi-device mesh suites (pencil-FFT
# layouts, halo exchange, mesh-vs-local `register` parity, the S=4
# cohort collective-count pin).
#
# After the gates, the fast tier re-runs under the line-coverage floor
# (COV_MIN, scripts/pycov.py; COV_SKIP=1 to skip).
set -euo pipefail
cd "$(dirname "$0")/.."

bash scripts/smoke.sh

# schema gate: every event in the smoke trace must validate (non-zero exit
# on any violation)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.analysis.trace_report --validate results/smoke_trace.jsonl > /dev/null

# chaos gate: the smoke chaos cell must have left typed FaultEvent /
# RecoveryEvent records in the trace, and each must individually pass the
# versioned schema (a drifted chaos emitter fails here, not in a consumer)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json

from repro import telemetry

recs = [json.loads(l) for l in open("results/smoke_trace.jsonl") if l.strip()]
faults = [r for r in recs if r.get("kind") == "fault"]
recov = [r for r in recs if r.get("kind") == "recovery"]
assert faults, "smoke trace has no FaultEvent (chaos cell missing?)"
assert recov, "smoke trace has no RecoveryEvent (retry never recorded?)"
assert any(r["fault"] == "nan_injection" for r in faults), faults
assert any(r["action"] == "retry_degraded" for r in recov), recov
for r in faults + recov:
    problems = telemetry.validate_record(r)
    assert not problems, (r["kind"], problems)
print(f"chaos gate OK: {len(faults)} fault / {len(recov)} recovery events validated")
EOF

# autotune cache gate: the tuning cache the smoke sweep just wrote (and any
# cache a developer committed by mistake) must pass the schema/knob
# allowlist — a corrupt or stale cache is a silent perf bug, not a crash
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.autotune --validate

# coverage gate: the fast tier re-runs under a line-coverage floor
# (scripts/pycov.py delegates to pytest-cov when installed, else a stdlib
# settrace tracer over src/repro).  COV_MIN is the ratchet — set just
# below the currently measured fast-tier coverage; raise it as tests
# land, never lower it silently.  COV_SKIP=1 skips the re-run (local
# quick loops); see benchmarks/README.md "Coverage gate".
if [[ -z "${COV_SKIP:-}" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/pycov.py --fail-under "${COV_MIN:-69}" -q -m "not slow"
fi

if [[ -n "${CI_SLOW:-}" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q -m slow
fi

echo "ci PASSED"

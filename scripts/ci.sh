#!/usr/bin/env bash
# CI entry point — what a checks job runs on every push.
#
#     bash scripts/ci.sh          # fast tier + toy benchmark cells (~10 min)
#     CI_SLOW=1 bash scripts/ci.sh   # additionally the slow/dist tier
#
# After the smoke gate, every telemetry record the smoke run emitted is
# validated against the versioned event schema (repro.telemetry.events):
# a drifted emitter fails CI here, not in a downstream trace consumer.
#
# The fast gate is scripts/smoke.sh: the `-m "not slow"` test tier (every
# counted-collective pin, the masked-cohort parity pins, the bugfix
# regression tests) plus the toy interp/fft/multilevel/cohort benchmark
# cells — including the S=2 `solve_cohort` billing-parity +
# one-executable smoke cell — and two tiny end-to-end registrations.
# The slow tier adds the subprocess multi-device mesh suites (pencil-FFT
# layouts, halo exchange, mesh-vs-local `register` parity, the S=4
# cohort collective-count pin).
#
# After the gates, the fast tier re-runs under the line-coverage floor
# (COV_MIN, scripts/pycov.py; COV_SKIP=1 to skip).
set -euo pipefail
cd "$(dirname "$0")/.."

bash scripts/smoke.sh

# schema gate: every event in the smoke trace must validate (non-zero exit
# on any violation)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.analysis.trace_report --validate results/smoke_trace.jsonl > /dev/null

# autotune cache gate: the tuning cache the smoke sweep just wrote (and any
# cache a developer committed by mistake) must pass the schema/knob
# allowlist — a corrupt or stale cache is a silent perf bug, not a crash
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.autotune --validate

# coverage gate: the fast tier re-runs under a line-coverage floor
# (scripts/pycov.py delegates to pytest-cov when installed, else a stdlib
# settrace tracer over src/repro).  COV_MIN is the ratchet — set just
# below the currently measured fast-tier coverage; raise it as tests
# land, never lower it silently.  COV_SKIP=1 skips the re-run (local
# quick loops); see benchmarks/README.md "Coverage gate".
if [[ -z "${COV_SKIP:-}" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/pycov.py --fail-under "${COV_MIN:-69}" -q -m "not slow"
fi

if [[ -n "${CI_SLOW:-}" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q -m slow
fi

echo "ci PASSED"

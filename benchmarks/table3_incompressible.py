"""Paper Table III analogue: incompressible (volume-preserving) solves.

Measures the incompressibility overhead (Leray projections + the extra
spectral work) against the unconstrained solver on the same grid, and
checks det(grad y) = 1 — the paper's "mass preserving" mode.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


def main():
    n = 24
    for incomp in (False, True):
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(
            n, incompressible=incomp, amplitude=0.5
        )
        cfg = RegistrationConfig(
            solver=gn.GNConfig(
                beta=1e-2, n_t=4, incompressible=incomp, max_newton=8, gtol=1e-2, max_cg=30
            )
        )
        t0 = time.time()
        out = register(rho_R, rho_T, cfg, grid=grid)
        dt = time.time() - t0
        tag = "incompressible" if incomp else "generic"
        emit(
            f"table3/{tag}_N{n}",
            dt * 1e6,
            f"newton={out['newton_iters']};matvecs={out['hessian_matvecs']};"
            f"res={out['residual_rel']:.3f};det=[{out['det_min']:.3f},{out['det_max']:.3f}]",
        )


if __name__ == "__main__":
    main()

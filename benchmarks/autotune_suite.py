"""Autotune sweep suite (the ISSUE 8 record).

    PYTHONPATH=src python -m benchmarks.run --suite autotune

Writes ``BENCH_autotune.json`` at the repo root (structure pinned by
``tests/test_autotune.py::test_bench_autotune_record``): one entry per
``(grid, mesh)`` cell with every candidate knob set the coordinate-descent
sweep scored (``repro.autotune.search``), the winner, its measured (wall)
or counted (deterministic collective count/byte) cost, the preconditioner
race, and the mesh-layout race.  After the sweeps the suite re-resolves
every cell from the tuning cache and records that the SECOND run is pure
cache resolution — no re-sweep (the acceptance pin).

The winners land in the persistent tuning cache
(``results/autotune_cache.json`` — gitignored; ``REPRO_AUTOTUNE_CACHE``
overrides), where ``DistContext``/``gn.solve`` resolve them by default.

Env knobs: ``BENCH_AUTOTUNE_TOY=1`` shrinks the cells and redirects the
record to ``results/autotune_toy.json`` (the ``scripts/smoke.sh``
tripwire); ``BENCH_AUTOTUNE_OUT`` overrides the path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks import common
from benchmarks.common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_autotune.json")
TOY_OUT = os.path.join(ROOT, "results", "autotune_toy.json")

SWEEP_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
{cache_env}
import sys, json
sys.path.insert(0, {root_src!r})
import jax, numpy as np
from repro.autotune import resolve_tuned, TuningCache, cell_key
from repro.autotune.search import sweep_cell, sweep_mesh_layouts
from repro.core.grid import make_grid
from repro.launch.mesh import make_mesh

cells = []
for shape in {shapes!r}:
    grid = make_grid(tuple(shape))
    mesh = make_mesh((2, 4), ("data", "model"))
    rec = sweep_cell(grid, mesh, beta=1e-2, include_precond={precond!r})
    # persist the beta-agnostic alias too, so DistContext (which has no
    # beta at construction time) resolves the same winner
    cache = TuningCache()
    tuned = cache.get(rec["cell"])
    if tuned is not None:
        cache.put(cell_key(grid.shape, 8, None), tuned)
    rec["layouts"] = sweep_mesh_layouts(grid, beta=1e-2)
    cells.append(rec)

# ---- second run: every cell must resolve from the cache, no re-sweep ----
second = []
for shape in {shapes!r}:
    t = resolve_tuned(tuple(shape), 8, beta=1e-2)
    second.append({{
        "cell": cell_key(tuple(shape), 8, 1e-2),
        "resolved_from_cache": t is not None,
        "knobs": t.knobs() if t is not None else None,
        "mode": t.mode if t is not None else None,
    }})

print(json.dumps({{"cells": cells, "second_run": second}}))
"""


def _sweep_record(shapes, cache_path=None, precond=True) -> dict:
    cache_env = (
        f"os.environ['REPRO_AUTOTUNE_CACHE'] = {cache_path!r}" if cache_path else ""
    )
    code = SWEEP_BODY.format(
        root_src=os.path.join(ROOT, "src"),
        shapes=[list(s) for s in shapes],
        cache_env=cache_env,
        precond=bool(precond),
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=1800, cwd=ROOT,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"autotune sub-bench failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def measure(toy: bool = False) -> dict:
    shapes = [(8, 8, 16), (8, 16, 8)] if toy else [(16, 16, 32), (16, 32, 16)]
    return _sweep_record(shapes, precond=not toy)


def write_record(rec: dict, out: str) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(int(os.environ.get("BENCH_AUTOTUNE_TOY", "0")))
    out = out or os.environ.get("BENCH_AUTOTUNE_OUT") or (TOY_OUT if toy else DEFAULT_OUT)
    rec = measure(toy=toy)
    write_record(rec, out)

    for cell in rec["cells"]:
        emit(
            f"autotune/{cell['cell']}",
            0.0,
            f"mode={cell['mode']};winner={json.dumps(cell['winner'])};"
            f"cost={cell['cost']:.4g};trials={len(cell['trials'])}",
        )
        lay = cell["layouts"]
        emit(
            f"autotune/{cell['cell']}/layouts",
            0.0,
            f"winner={lay['winner']};n={len(lay['layouts'])}",
        )
        for pt in cell.get("precond_trials", []):
            emit(
                f"autotune/{cell['cell']}/precond_{pt['variant']}",
                0.0,
                f"cost={pt['cost']:.4g}",
            )
    hits = [s for s in rec["second_run"] if s["resolved_from_cache"]]
    emit("autotune/second_run", 0.0,
         f"resolved={len(hits)}/{len(rec['second_run'])}")

    # structural pins, enforced on every run (incl. toy)
    assert rec["cells"], rec
    for cell in rec["cells"]:
        assert cell["trials"], cell["cell"]
        # coordinate descent only ever accepts improvements: the winner is
        # never worse than the defaults trial (trials[0])
        assert cell["cost"] <= cell["trials"][0]["cost"] * (1 + 1e-9), cell["cell"]
        assert cell["layouts"]["layouts"], cell["cell"]
    assert all(s["resolved_from_cache"] for s in rec["second_run"]), rec["second_run"]
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()

"""Interpolation kernel microbenchmark (the paper's hot spot, §III-C2).

Measures the oracle's CPU throughput and derives the Pallas kernel's TPU
bound from its flop/byte structure (the kernel itself is validated in
interpret mode — wall-clock on CPU is meaningless for it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.tricubic import tricubic_displace_pallas

PEAK = 197e12
HBM = 819e9


def main():
    rng = np.random.default_rng(0)
    for n in (32, 64):
        f = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        d = jnp.asarray(rng.uniform(-3, 3, (3, n, n, n)), jnp.float32)
        interp = jax.jit(lambda f, d: kops.tricubic_displace(f, d, method="ref"))
        t = time_fn(interp, f, d)
        pts = n**3
        emit(f"kernel/tricubic_ref_N{n}", t * 1e6, f"{pts/t/1e6:.1f} Mpts/s (CPU)")

        # batched-channel + plan-reuse columns (ISSUE 3: the full sweep with
        # the mesh exchange counts is `benchmarks.run --suite interp`)
        c = 3
        fc = jnp.asarray(rng.standard_normal((c, n, n, n)), jnp.float32)
        tb = time_fn(jax.jit(ref.tricubic_displace_many), fc, d)
        emit(f"kernel/tricubic_batched_C{c}_N{n}", tb * 1e6,
             f"{c*pts/tb/1e6:.1f} Mpts/s;vs-looped={c*t/tb:.2f}x")
        plan = jax.jit(ref.make_interp_plan)(d)
        tp = time_fn(jax.jit(ref.interp_apply), fc, plan)
        tplan = time_fn(jax.jit(ref.make_interp_plan), d)
        emit(f"kernel/tricubic_planned_C{c}_N{n}", tp * 1e6,
             f"{c*pts/tp/1e6:.1f} Mpts/s;plan-build={tplan*1e6:.0f}us "
             f"(amortized over a Newton iteration)")

    # Pallas kernel: structural cost on TPU v5e
    # direct gather model (paper): 64 loads * 4B + ~600 flops / point
    t_mem_direct = (64 * 4) / HBM
    # one-hot matmul model: ~2*W1*(W2*W3)/ (T2*T3) flops/pt on MXU (tile 8x8x32, halo 4)
    w1, w2, w3, p = 19, 19, 43, 8 * 32
    flops_pt = 2 * w1 * w2 * w3 / (8 * 32) * (8 * 32) / p + 600  # ~ per point
    t_mxu = (2 * w1 * w2 * w3) / p / PEAK
    emit("kernel/tricubic_pallas_model", 0.0,
         f"direct-gather-bound={1/(t_mem_direct*1e9):.2f} Gpts/s;"
         f"onehot-mxu-bound={1/(t_mxu*1e9):.2f} Gpts/s per-core")

    # correctness spot check in interpret mode (ensures the kernel path works
    # in the benchmark environment too)
    f = jnp.asarray(rng.standard_normal((16, 16, 32)), jnp.float32)
    d = jnp.asarray(rng.uniform(-3, 3, (3, 16, 16, 32)), jnp.float32)
    out = tricubic_displace_pallas(f, d, tile=(8, 8, 16), halo=4, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref.tricubic_displace(f, d))))
    emit("kernel/tricubic_pallas_interpret_err", err * 1e6, "max-abs-err-times-1e6")


if __name__ == "__main__":
    main()

"""Cohort-registration suite: solves/second amortization + cost parity.

    PYTHONPATH=src python -m benchmarks.run --suite cohort

Measures ``gn.solve_cohort`` (the subjects axis through the GN solver)
against S independent ``gn.solve`` runs on the paper's synthetic problem
at S distinct deformation amplitudes, and a ``launch.reg_serve`` session
streaming 2S jobs through S slots.  Writes ``BENCH_cohort.json``:

* per-subject ``fine_equiv_matvecs`` (the paper's Table V metric as a
  per-job billing meter) — pinned EQUAL between cohort and independent
  solves: batching subjects never changes what any one subject pays;
* wall-clock per solve (``wall_s_per_subject`` vs ``wall_s_single``) and
  the compile counts (the cohort's ONE executable vs S independent jit
  programs);
* the serve session's cohort-iteration count and per-job billing with
  mid-flight slot refills.

``BENCH_COHORT_TOY=1`` (used by ``scripts/smoke.sh``) shrinks the problem
and writes ``results/BENCH_cohort_toy.json`` instead of the committed
record.
"""
from __future__ import annotations

import os
import time

from benchmarks import common
from benchmarks.common import emit
from repro.core import gauss_newton as gn
from repro.data import synthetic

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_cohort.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_cohort_toy.json")


def measure(n: int = 24, amps=(0.3, 0.6, 0.9, 1.2), n_t: int = 4,
            beta: float = 1e-2, gtol: float = 1e-2, max_newton: int = 12,
            max_cg: int = 50) -> dict:
    """S-subject cohort vs S independent solves, same tolerance."""
    import jax.numpy as jnp

    cfg = gn.GNConfig(beta=beta, n_t=n_t, max_newton=max_newton, gtol=gtol,
                      max_cg=max_cg)
    probs = [synthetic.synthetic_problem(n, n_t=n_t, amplitude=a) for a in amps]
    grid = probs[0][3]

    t0 = time.time()
    singles = [gn.solve(rR, rT, grid, cfg) for rR, rT, _, _ in probs]
    t_single = time.time() - t0

    rho_R = jnp.stack([p[0] for p in probs])
    rho_T = jnp.stack([p[1] for p in probs])
    t0 = time.time()
    cohort = gn.solve_cohort(rho_R, rho_T, grid, cfg)
    t_cohort = time.time() - t0

    S = len(amps)
    rec = {
        "problem": {"grid": list(grid.shape), "beta": beta, "gtol": gtol,
                    "n_t": n_t, "amplitudes": list(amps), "subjects": S},
        "independent": {
            "newton_iters": [s["newton_iters"] for s in singles],
            "fine_equiv_matvecs": [float(s["hessian_matvecs"]) for s in singles],
            "compiled_executables": S,  # one jit program per gn.solve call
            "wall_s_total": t_single,
            "wall_s_per_subject": t_single / S,
        },
        "cohort": {
            "newton_iters": cohort["newton_iters"],
            "fine_equiv_matvecs": cohort["fine_equiv_matvecs"],
            "compiled_executables": cohort["compiled_executables"],
            "wall_s_total": t_cohort,
            "wall_s_per_subject": t_cohort / S,
        },
    }
    # the cost-parity invariant the suite exists to record
    rec["billing_matches_independent"] = (
        cohort["fine_equiv_matvecs"]
        == rec["independent"]["fine_equiv_matvecs"]
    )
    return rec


def measure_serve(n: int = 24, n_jobs: int = 8, slots: int = 4, n_t: int = 4,
                  beta: float = 1e-2, gtol: float = 1e-2, max_newton: int = 12,
                  max_cg: int = 50, seed: int = 0) -> dict:
    """Stream 2S jobs through an S-slot server (mid-flight refills)."""
    import numpy as np

    from repro.launch.reg_serve import CohortServer, RegJob

    cfg = gn.GNConfig(beta=beta, n_t=n_t, max_newton=max_newton, gtol=gtol,
                      max_cg=max_cg)
    rng = np.random.default_rng(seed)
    jobs, grid = [], None
    for j in range(n_jobs):
        amp = float(rng.uniform(0.3, 1.2))
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(n, n_t=n_t, amplitude=amp)
        jobs.append(RegJob(job_id=f"job{j}", rho_R=rho_R, rho_T=rho_T))
    server = CohortServer(grid, cfg, slots=slots)
    server.admit(*jobs)
    t0 = time.time()
    results = server.run()
    wall = time.time() - t0
    return {
        "jobs": n_jobs,
        "slots": slots,
        "cohort_iterations": server.iterations,
        "compiled_executables": server.compiled_executables(),
        "all_converged": all(r.converged for r in results),
        "per_job": [
            {"job_id": r.job_id, "newton_iters": r.newton_iters,
             "fine_equiv_matvecs": r.fine_equiv_matvecs,
             "rel_gnorm": r.rel_gnorm}
            for r in sorted(results, key=lambda r: r.job_id)
        ],
        "wall_s_total": wall,
        "wall_s_per_job": wall / n_jobs,
    }


def write_record(rec: dict, out: str = DEFAULT_OUT) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(os.environ.get("BENCH_COHORT_TOY"))
    out = out or (TOY_OUT if toy else DEFAULT_OUT)
    if toy:
        rec = measure(n=12, amps=(0.4, 1.0), n_t=2, max_newton=5, max_cg=15)
        rec["serve"] = measure_serve(n=12, n_jobs=3, slots=2, n_t=2,
                                     max_newton=5, max_cg=15)
    else:
        rec = measure()
        rec["serve"] = measure_serve()
    write_record(rec, out)
    ind, coh = rec["independent"], rec["cohort"]
    emit("cohort/independent", ind["wall_s_per_subject"] * 1e6,
         f"matvecs={ind['fine_equiv_matvecs']};executables={ind['compiled_executables']}")
    emit("cohort/cohort", coh["wall_s_per_subject"] * 1e6,
         f"matvecs={coh['fine_equiv_matvecs']};executables={coh['compiled_executables']}")
    sv = rec["serve"]
    emit("cohort/serve", sv["wall_s_per_job"] * 1e6,
         f"jobs={sv['jobs']};slots={sv['slots']};iterations={sv['cohort_iterations']}")


if __name__ == "__main__":
    main()

"""Paper Table V: sensitivity of the workload to the regularization weight.

The paper reports Hessian matvec counts and time-to-solution growth as
beta shrinks (1e-1 -> 1e-5), demonstrating that the (beta Lap^2)^{-1}
preconditioner is mesh- but not beta-independent.  We reproduce the exact
experiment (matvecs + relative time) on a CPU-scale grid.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


def main():
    n = 16
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(n)
    base = None
    for beta in (1e-1, 1e-3, 1e-5):
        cfg = RegistrationConfig(
            solver=gn.GNConfig(beta=beta, n_t=4, max_newton=4, gtol=1e-3, max_cg=300)
        )
        t0 = time.time()
        out = register(rho_R, rho_T, cfg, grid=grid)
        dt = time.time() - t0
        if base is None:
            base = dt
        emit(
            f"table5/beta_{beta:.0e}",
            dt * 1e6,
            f"matvecs={out['hessian_matvecs']};rel_time={dt/base:.1f}",
        )


if __name__ == "__main__":
    main()

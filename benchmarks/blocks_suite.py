"""Blockwise-registration suite: out-of-core map-reduce vs monolithic.

    PYTHONPATH=src python -m benchmarks.run --suite blocks

Two cells, written to ``BENCH_blocks.json``:

* ``tiled`` — a REAL tiled solve at 64^3 (32^3 cores, overlap 8 -> 48^3
  extended blocks) against the monolithic ``gn.solve`` on the same
  (presmoothed-once) pair.  The record pins the two invariants the
  subsystem exists for: the blockwise transported residual lands within
  10% of the monolithic one (``residual_ratio <= 1.1``) and every block
  of the partition was served by ONE compiled cohort executable
  (``compiled_executables == 1``), plus the seam-consistency report and
  the fine-grid-equivalent matvec bill (coarse warm start + halo
  overhead included).
* ``dryrun`` — partition/memory accounting for a 4096^3-equivalent
  volume tiled into 256^3 cores with overlap 16: block counts, the
  halo-overhead factor, bytes per extended block vs bytes for the whole
  volume (the out-of-core ratio), and the single served shape.  Pure
  geometry — nothing 4096^3-sized is allocated.

``BENCH_BLOCKS_TOY=1`` (used by ``scripts/smoke.sh``) shrinks the tiled
cell to 32^3 and writes ``results/BENCH_blocks_toy.json`` instead of the
committed record.
"""
from __future__ import annotations

import os
import time

from benchmarks import common
from benchmarks.common import emit
from repro.core import gauss_newton as gn
from repro.data import synthetic

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_blocks.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_blocks_toy.json")


def _residual(v, rho_R, rho_T, grid, cfg, ops):
    """Relative transported residual |rho_T o y - rho_R| / |rho_T - rho_R|."""
    import jax.numpy as jnp

    from repro.core import semilag
    from repro.core.planner import make_plan

    plan = make_plan(v, grid, ops, cfg.n_t, cfg.incompressible, None)
    rho1 = semilag.transport_state(rho_T, plan, None)[-1]
    num = float(jnp.linalg.norm((rho1 - rho_R).ravel()))
    den = float(jnp.linalg.norm((rho_T - rho_R).ravel()))
    return num / max(den, 1e-30)


def measure_tiled(n: int = 64, block: int = 32, overlap: int = 8,
                  coarse: int = 16, amplitude: float = 0.5, n_t: int = 4,
                  beta: float = 1e-2, gtol: float = 1e-2, max_newton: int = 10,
                  max_cg: int = 20, slots: int = 4) -> dict:
    """Real tiled solve vs monolithic on the same presmoothed pair.

    The pair is presmoothed ONCE up front and both solvers run with their
    own presmoothing off, so they optimize the same objective and the
    residual ratio compares like with like.
    """
    from repro import blocks
    from repro.core.spectral import SpectralOps

    cfg = gn.GNConfig(beta=beta, n_t=n_t, max_newton=max_newton, gtol=gtol,
                      max_cg=max_cg)
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(
        n, n_t=n_t, amplitude=amplitude
    )
    ops = SpectralOps(grid)
    rho_R, rho_T = ops.smooth(rho_R), ops.smooth(rho_T)

    t0 = time.time()
    mono = gn.solve(rho_R, rho_T, grid, cfg, ops=ops)
    t_mono = time.time() - t0

    bcfg = blocks.BlocksConfig(solver=cfg, block_shape=block, overlap=overlap,
                               coarse_shape=coarse, slots=slots,
                               presmooth=False)
    t0 = time.time()
    out = blocks.solve(rho_R, rho_T, grid, bcfg, ops=ops)
    t_blocks = time.time() - t0

    r_mono = _residual(mono["v"], rho_R, rho_T, grid, cfg, ops)
    r_blocks = _residual(out["v"], rho_R, rho_T, grid, cfg, ops)
    rec = {
        "problem": {"grid": list(grid.shape), "beta": beta, "gtol": gtol,
                    "n_t": n_t, "amplitude": amplitude},
        "partition": out["partition"],
        "coarse": out["coarse"],
        "monolithic": {
            "newton_iters": mono["newton_iters"],
            "hessian_matvecs": mono["hessian_matvecs"],
            "residual_rel": r_mono,
            "wall_s": t_mono,
        },
        "blockwise": {
            "newton_iters": out["newton_iters"],
            "block_matvecs": out["block_matvecs"],
            "fine_equiv_matvecs": out["fine_equiv_matvecs"],
            "cohort_iterations": out["cohort_iterations"],
            "compiled_executables": out["compiled_executables"],
            "all_converged": out["all_converged"],
            "residual_rel": r_blocks,
            "seam": out["seam"],
            "wall_s": t_blocks,
        },
        "residual_ratio": r_blocks / max(r_mono, 1e-30),
        "per_block": out["per_block"],
    }
    # the two invariants the subsystem exists for
    assert rec["residual_ratio"] <= 1.1, (
        f"blockwise residual {r_blocks:.4f} not within 10% of monolithic "
        f"{r_mono:.4f} (ratio {rec['residual_ratio']:.3f})"
    )
    assert out["compiled_executables"] == 1, (
        f"{out['compiled_executables']} executables for "
        f"{out['partition']['n_blocks']} blocks (expected 1)"
    )
    return rec


def measure_dryrun(n: int = 4096, block: int = 256, overlap: int = 16,
                   dtype_bytes: int = 4) -> dict:
    """Partition/memory accounting for an out-of-core volume (no arrays
    of that size are ever allocated — pure geometry)."""
    from repro.blocks.partition import BlockPartition

    part = BlockPartition(n, block, overlap)
    ext = part.ext_shapes
    vol_bytes = dtype_bytes * n**3
    # resident per in-flight block job: pair of images + velocity (3) +
    # warm start (3) on the extended shape
    ext_vox = max(int(e1 * e2 * e3) for e1, e2, e3 in ext)
    block_bytes = dtype_bytes * ext_vox * 8
    return {
        "grid": [n, n, n],
        "block_shape": block,
        "overlap": list(part.overlap),
        "counts": list(part.counts),
        "n_blocks": len(part),
        "ext_shapes": [list(s) for s in ext],
        "served_shapes": len(ext),  # == executable count for the partition
        "halo_overhead": part.halo_overhead,
        "volume_gb": vol_bytes / 2**30,
        "block_job_gb": block_bytes / 2**30,
        "out_of_core_ratio": vol_bytes / block_bytes,
    }


def write_record(rec: dict, out: str = DEFAULT_OUT) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(os.environ.get("BENCH_BLOCKS_TOY"))
    out = out or (TOY_OUT if toy else DEFAULT_OUT)
    if toy:
        rec = {"tiled": measure_tiled(n=32, block=16, overlap=6, coarse=16,
                                      n_t=2, max_newton=6, max_cg=15, slots=4)}
    else:
        rec = {"tiled": measure_tiled()}
    rec["dryrun"] = measure_dryrun()
    write_record(rec, out)
    tl, dr = rec["tiled"], rec["dryrun"]
    emit("blocks/tiled", tl["blockwise"]["wall_s"] * 1e6,
         f"blocks={tl['partition']['n_blocks']};"
         f"ratio={tl['residual_ratio']:.3f};"
         f"executables={tl['blockwise']['compiled_executables']}")
    emit("blocks/monolithic", tl["monolithic"]["wall_s"] * 1e6,
         f"residual={tl['monolithic']['residual_rel']:.4f}")
    emit("blocks/dryrun4096", dr["n_blocks"],
         f"halo_overhead={dr['halo_overhead']:.3f};"
         f"out_of_core_ratio={dr['out_of_core_ratio']:.0f}")


if __name__ == "__main__":
    main()

"""Batched / planned interpolation suite (the ISSUE 3 perf record).

    PYTHONPATH=src python -m benchmarks.run --suite interp

Writes ``BENCH_interp.json`` at the repo root (structure pinned by
``tests/test_interp_plan.py::test_bench_interp_record``):

* ``single_device`` — per (N, C): wall time of C looped per-field calls vs
  ONE batched ``tricubic_displace_many`` call vs the planned
  ``interp_apply`` against a prebuilt ``InterpPlan``, plus the plan build
  cost itself (paid once per Newton iteration, amortized over every
  transport + PCG matvec).  Each row also measures the bf16-packed plan
  apply (``planned_bf16_s`` + its relative error vs the f32 plan) and,
  where available, the batched Pallas kernel (``pallas_batched_s``:
  compiled natively on TPU, interpret mode elsewhere at N <= 32 —
  ``pallas_mode`` records which; pinned by ``tests/test_interp_plan.py::
  test_bench_interp_record_bf16_and_pallas_columns``).
* ``mesh`` — an 8-device pencil-mesh subprocess: wall times AND the
  **counted** ``collective_permute`` ops in the lowered program — the
  batched path issues one ghost-exchange sequence per call regardless of
  C, the looped baseline issues C (the paper's Alg. 1 scatter phase, C x
  fewer collective rounds).

Env knobs: ``BENCH_INTERP_TOY=1`` shrinks the grid sweep to 16^3 and
redirects the record to ``results/BENCH_interp_toy.json`` (the
``scripts/smoke.sh`` regression tripwire — fails fast if any path breaks
or the record schema drifts); ``BENCH_INTERP_OUT`` overrides the path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.kernels import ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_interp.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_interp_toy.json")

MESH_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, {root_src!r})
import jax, jax.numpy as jnp, numpy as np
from repro.core.grid import make_grid
from repro.dist.context import DistContext
from repro.launch.mesh import make_mesh
sys.path.insert(0, {root!r})
from benchmarks.common import time_fn

halo = 4
mesh = make_mesh((2, 4), ("data", "model"))
grid = make_grid({grid_shape!r})
ctx = DistContext(grid, mesh, halo=halo, halo_check="off")
rng = np.random.default_rng(0)
f = jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32)
d = jnp.asarray(rng.uniform(-3.9, 3.9, (3,) + grid.shape), jnp.float32)
fs = jax.device_put(f, ctx.vector_sharding())
ds = jax.device_put(d, ctx.vector_sharding())
plan = jax.jit(ctx.interp.make_plan)(ds)

batched = jax.jit(ctx.interp)
looped = jax.jit(lambda ff, dd: jnp.stack([ctx.interp(ff[i], dd) for i in range(3)]))
planned = jax.jit(ctx.interp.apply_plan)

def count_cp(fn, *args):
    return jax.jit(fn).lower(*args).as_text().count("collective_permute")

rec = {{
    "mesh_shape": [2, 4],
    "grid": list(grid.shape),
    "collective_permutes": {{
        "c1": count_cp(ctx.interp, fs[0], ds),
        "batched_c3": count_cp(ctx.interp, fs, ds),
        "planned_c3": count_cp(ctx.interp.apply_plan, fs, plan),
        "looped_c3": count_cp(
            lambda ff, dd: jnp.stack([ctx.interp(ff[i], dd) for i in range(3)]), fs, ds
        ),
    }},
    "looped_s": time_fn(looped, fs, ds),
    "batched_s": time_fn(batched, fs, ds),
    "planned_s": time_fn(planned, fs, plan),
}}
print(json.dumps(rec))
"""


def _single_device(sizes, channels=(3, 4)) -> list[dict]:
    from repro.kernels import tricubic

    rng = np.random.default_rng(0)
    rows = []
    # 5-sample medians at the sizes the record test pins: the batched-vs-
    # looped gap is real but O(10-30%), so keep regeneration noise below it
    iters = {"iters": 5}
    # the Pallas kernel compiles natively on TPU; elsewhere it runs in
    # interpret mode — correct but slow, so measure it at small N only
    on_tpu = jax.default_backend() == "tpu"
    for n in sizes:
        d = jnp.asarray(rng.uniform(-3, 3, (3, n, n, n)), jnp.float32)
        single = jax.jit(lambda ff, dd: ref.tricubic_displace(ff, dd))
        plan_build = jax.jit(ref.make_interp_plan)
        plan_build_bf16 = jax.jit(
            lambda dd: ref.make_interp_plan(dd, dtype=jnp.bfloat16)
        )
        plan = plan_build(d)
        plan_bf16 = plan_build_bf16(d)
        f1 = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        single_s = time_fn(single, f1, d)
        plan_build_s = time_fn(plan_build, d)
        for c in channels:
            f = jnp.asarray(rng.standard_normal((c, n, n, n)), jnp.float32)
            looped = jax.jit(
                lambda ff, dd, _c=c: jnp.stack(
                    [ref.tricubic_displace(ff[i], dd) for i in range(_c)]
                )
            )
            batched = jax.jit(ref.tricubic_displace_many)
            planned = jax.jit(ref.interp_apply)
            ref_out = planned(f, plan)
            bf16_out = planned(f, plan_bf16)
            bf16_rel_err = float(
                jnp.max(jnp.abs(bf16_out - ref_out)) / jnp.max(jnp.abs(ref_out))
            )
            row = {
                "n": n,
                "c": c,
                "single_s": single_s,
                "looped_s": time_fn(looped, f, d, **iters),
                "batched_s": time_fn(batched, f, d, **iters),
                "planned_s": time_fn(planned, f, plan, **iters),
                "planned_bf16_s": time_fn(planned, f, plan_bf16, **iters),
                "planned_bf16_rel_err": bf16_rel_err,
                "plan_build_s": plan_build_s,
            }
            if on_tpu or n <= 32:
                tile = (8, 8, min(32, n))
                pallas = jax.jit(
                    lambda ff, dd: tricubic.tricubic_displace_pallas_many(
                        ff, dd, tile=tile, interpret=not on_tpu
                    )
                )
                pallas_out = pallas(f, d)
                row["pallas_batched_s"] = time_fn(
                    pallas, f, d, iters=5 if on_tpu else 3
                )
                row["pallas_mode"] = "tpu" if on_tpu else "interpret"
                row["pallas_rel_err"] = float(
                    jnp.max(jnp.abs(pallas_out - batched(f, d)))
                    / jnp.max(jnp.abs(ref_out))
                )
            rows.append(row)
    return rows


def _mesh_record(grid_shape=(16, 16, 32)) -> dict:
    code = MESH_BODY.format(
        root=ROOT, root_src=os.path.join(ROOT, "src"), grid_shape=tuple(grid_shape)
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"mesh sub-bench failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def measure(toy: bool = False) -> dict:
    sizes = (16,) if toy else (32, 64)
    return {
        "single_device": _single_device(sizes),
        "mesh": _mesh_record(),
    }


def write_record(rec: dict, out: str) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(int(os.environ.get("BENCH_INTERP_TOY", "0")))
    out = out or os.environ.get("BENCH_INTERP_OUT") or (TOY_OUT if toy else DEFAULT_OUT)
    rec = measure(toy=toy)
    write_record(rec, out)
    for r in rec["single_device"]:
        extra = ""
        if "pallas_batched_s" in r:
            extra = (
                f";pallas={r['pallas_batched_s']*1e6:.0f}us"
                f"({r['pallas_mode']})"
            )
        emit(
            f"interp/N{r['n']}_C{r['c']}",
            r["batched_s"] * 1e6,
            f"looped={r['looped_s']*1e6:.0f}us;planned={r['planned_s']*1e6:.0f}us;"
            f"planned_bf16={r['planned_bf16_s']*1e6:.0f}us;"
            f"speedup={r['looped_s']/r['batched_s']:.2f}x;"
            f"planned_speedup={r['looped_s']/r['planned_s']:.2f}x" + extra,
        )
    m = rec["mesh"]
    cp = m["collective_permutes"]
    emit(
        "interp/mesh_2x4",
        m["batched_s"] * 1e6,
        f"looped={m['looped_s']*1e6:.0f}us;cp_c1={cp['c1']};"
        f"cp_batched_c3={cp['batched_c3']};cp_looped_c3={cp['looped_c3']}",
    )
    # the satellite's structural claims, enforced on every run (incl. toy)
    assert cp["batched_c3"] == cp["c1"], cp
    assert cp["planned_c3"] == cp["c1"], cp
    assert cp["looped_c3"] == 3 * cp["c1"], cp
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()

"""LM-architecture roofline summary (reads the dry-run sweep JSON).

Prints one row per (arch x shape) single-pod cell with the three roofline
terms and bottleneck — the numbers behind EXPERIMENTS §Roofline.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

DEFAULT_FILES = (
    "results/dryrun_lm_single.json",
    "results/dryrun_full.json",
    "results/dryrun_reg_targeted.json",
)


def main():
    paths = (
        [os.environ["DRYRUN_JSON"]]
        if os.environ.get("DRYRUN_JSON")
        else [p for p in DEFAULT_FILES if os.path.exists(p)]
    )
    if not paths:
        emit("lm_roofline/missing", 0.0, "run launch.dryrun --all first")
        return
    records = []
    for p in paths:
        with open(p) as f:
            records.extend(json.load(f))
    for r in records:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        if "roofline" in r:
            rf = r["roofline"]
            emit(
                f"lm_roofline/{r['arch']}@{r['shape']}",
                rf["t_bound_s"] * 1e6,
                f"bottleneck={rf['bottleneck']};compute={rf['t_compute_s']:.4f}s;"
                f"memory={rf['t_memory_s']:.4f}s;coll={rf['t_collective_s']:.4f}s;"
                f"useful={rf['useful_flops_ratio']:.2f};mfu_bound={rf['mfu_bound']:.3f}",
            )
        elif "components" in r:
            for comp, c in r["components"].items():
                t = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
                emit(
                    f"reg_roofline/{r['arch']}/{comp}",
                    t * 1e6,
                    f"compute={c['t_compute_s']:.5f}s;memory={c['t_memory_s']:.5f}s;"
                    f"coll={c['t_collective_s']:.5f}s",
                )


if __name__ == "__main__":
    main()

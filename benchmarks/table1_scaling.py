"""Paper Table I/II analogue: solver component scaling.

On this CPU container we (a) *measure* wall-clock for the solver and its two
dominant components (spectral/FFT ops, semi-Lagrangian interpolation) on
CPU-scale grids, reproducing the paper's per-component accounting, and
(b) *derive* the paper's (N, p) scaling table from the complexity model of
§III-C4 combined with TPU v5e roofline constants (the measured dry-run
collective bytes live in EXPERIMENTS §Roofline):

    T_flop(N,p) = n_t (8 * 7.5 N^3/p log2 N + 4 * 600 N^3/p) / peak
    T_mem (N,p) ~ n_t * (fields r/w per transport) / (p * HBM_bw)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import gauss_newton as gn
from repro.core import objective as obj
from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps
from repro.data import synthetic

PEAK = 197e12
HBM = 819e9


def measured_components():
    for n in (16, 32, 48):
        rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(n)
        ops = SpectralOps(grid)
        v = 0.5 * v_star
        prob = obj.Problem(grid, rho_R, rho_T, 1e-2, 4, False)

        fft_pair = jax.jit(lambda f: ops.inv_laplacian(ops.laplacian(f)))
        t_fft = time_fn(fft_pair, rho_T)
        emit(f"table1/fft_roundtrip_N{n}", t_fft * 1e6, f"grid={n}^3")

        from repro.core.planner import make_plan
        from repro.kernels import ops as kops

        plan = jax.jit(lambda vv: make_plan(vv, grid, ops, 4, False))(v)
        interp = jax.jit(lambda f, d: kops.tricubic_displace(f, d, method="ref"))
        t_int = time_fn(interp, rho_T, plan.disp_fwd)
        emit(f"table1/interp_N{n}", t_int * 1e6, f"grid={n}^3")

        state_fn = jax.jit(lambda vv: obj.newton_state(vv, prob, ops).g)
        t_grad = time_fn(state_fn, v)
        emit(f"table1/gradient_eval_N{n}", t_grad * 1e6, f"grid={n}^3")
        # interpolation share of a transport-dominated evaluation (paper: ~60%)
        share = 6 * t_int / max(t_grad, 1e-12)
        emit(f"table1/interp_share_N{n}", share * 100, "percent-of-gradient(6 interps)")


def derived_paper_table():
    """The paper's Table I rows, re-predicted for TPU v5e chips."""
    nt = 4
    rows = [(64, 16), (128, 16), (128, 256), (256, 32), (256, 1024), (512, 128),
            (512, 1024), (1024, 512), (1024, 2048)]
    for n, p in rows:
        import math

        n3 = n**3
        logn = math.log2(n)
        flops = nt * (8 * 7.5 * n3 * logn + 4 * 600 * n3) / p
        t_comp = flops / PEAK
        # memory: each of 8 n_t FFT round trips + 4 n_t interps streams the
        # grid a small constant number of times
        bytes_ = nt * (8 * 6 + 4 * (64 + 2)) * 4.0 * n3 / p
        t_mem = bytes_ / HBM
        # ~10 Hessian matvecs + gradient per Newton iter, ~5 Newton iters
        t_solve = 50 * max(t_comp, t_mem)
        emit(
            f"table1_derived/N{n}_p{p}",
            t_solve * 1e6,
            f"per-matvec_compute={t_comp*1e6:.1f}us;per-matvec_mem={t_mem*1e6:.1f}us",
        )


def main():
    measured_components()
    derived_paper_table()


if __name__ == "__main__":
    main()

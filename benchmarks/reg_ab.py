"""A/B dry-run of the registration communication knobs on the production mesh.

Compares, per solver component (gradient assembly vs one GN Hessian
matvec), the per-chip collective bytes/counts of:

* ``unpacked``        — ``PencilFFT(packed=False)``: every real transform
                        pays a full c2c ride each way;
* ``packed``          — the default: paired real fields per c2c transform
                        on both sides (halved all-to-all bytes);
* ``packed+chunked``  — additionally ``chunk="auto"``: the pipelined
                        transform that overlaps each chunk's all-to-all
                        with the next chunk's local FFTs (bytes are
                        unchanged — the win is overlap, visible on real
                        hardware rather than in the dry-run byte columns).

This is a *dry run* (nothing executes): cells are lowered+compiled on
placeholder host devices exactly like ``repro.launch.dryrun``, and the
collective schedule is harvested from the compiled HLO.

    PYTHONPATH=src python -m benchmarks.reg_ab                 # claire-256
    PYTHONPATH=src python -m benchmarks.reg_ab --cell claire-64 \
        --devices 512 --out results/reg_perf_ab.json

Standalone on purpose (not a ``benchmarks.run`` suite): it needs the
placeholder device count set *before* jax initializes, so everything jax
is imported inside ``main()`` — importing this module never mutates
``XLA_FLAGS`` or touches device state.
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser(
        description="A/B dry-run of registration FFT communication knobs"
    )
    ap.add_argument("--cell", default="claire-256",
                    help="REGISTRATION_GRIDS cell name (default: claire-256)")
    ap.add_argument("--devices", type=int, default=512,
                    help="placeholder host device count (must cover the mesh)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="lower on the 2x16x16 multi-pod mesh (folded pencil axis)")
    ap.add_argument("--out", default="results/reg_perf_ab.json")
    args = ap.parse_args()

    # placeholder devices BEFORE any jax import (jax locks the count at
    # init); appended LAST so --devices wins over any count flag already in
    # the environment (duplicate XLA flags resolve last-one-wins)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}"
    )
    from repro.configs import REGISTRATION_GRIDS
    from repro.core.grid import make_grid
    from repro.dist.context import DistContext
    from repro.launch.dryrun import _reg_component_costs
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    axes = (("pod", "data"), "model") if args.multi_pod else ("data", "model")
    rcfg = REGISTRATION_GRIDS[args.cell]
    grid = make_grid(rcfg.grid)
    variants = [
        ("unpacked", dict(packed=False)),
        ("packed", dict(packed=True)),
        ("packed+chunked", dict(packed=True, chunk="auto")),
    ]
    out = {"cell": args.cell, "mesh": "2x16x16" if args.multi_pod else "16x16"}
    for name, kw in variants:
        ctx = DistContext(grid, mesh, axes=axes, halo=rcfg.halo, **kw)
        comps = _reg_component_costs(grid, ctx, rcfg, mesh, mesh.size)
        out[name] = comps
        for c, v in comps.items():
            a2a = v["collectives"].get("all-to-all", {})
            cp = v["collectives"].get("collective-permute", {})
            print(
                f"{name:15s} {c:15s} coll={v['t_collective_s']*1e3:8.3f}ms  "
                f"a2a={a2a.get('bytes', 0)/1e6:8.1f}MB/{a2a.get('count', 0):4d}  "
                f"halo={cp.get('bytes', 0)/1e6:6.1f}MB  "
                f"mem={v['t_memory_s']*1e3:8.3f}ms"
            )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()

"""Shared benchmark utilities: timing, CSV/telemetry output, record writer."""
from __future__ import annotations

import json
import os
import time

import jax

from repro import telemetry


def time_fn(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall-clock per call (seconds), after jit warmup."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    """One benchmark row: CSV line on stdout (the legacy contract, kept),
    plus a structured ``bench`` event for any installed telemetry sink."""
    print(f"{name},{us_per_call:.1f},{derived}")
    telemetry.emit(telemetry.BenchEvent(
        name=name, us_per_call=float(us_per_call), derived=derived))


def _json_default(o):
    """numpy/jax scalars (np.bool_, np.int64, np.float32, 0-d arrays) leak
    into suite dicts easily; coerce anything with ``.item()`` rather than
    losing a long measurement run to a TypeError at write time."""
    item = getattr(o, "item", None)
    if callable(item):
        return item()
    raise TypeError(f"Object of type {o.__class__.__name__} "
                    "is not JSON serializable")


def write_record(rec: dict, out: str) -> None:
    """Merge ``rec``'s top-level keys into the JSON record at ``out``.

    The one writer behind every ``BENCH_*.json``: merge-aware (suites that
    refresh one section at a time — e.g. the C2F table vs the precond
    sweep — keep the other sections), atomic (tmp + replace, so an
    interrupted run never truncates a committed record).
    """
    merged = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                merged = json.load(f)
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(rec)
    from repro.resilience.atomic import atomic_write_json

    atomic_write_json(out, merged, indent=1, default=_json_default)

"""Benchmark harness — one module per paper table (+ kernel + LM roofline).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table5     # one

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import kernel_microbench, lm_roofline, table1_scaling, table3_incompressible, table5_beta

TABLES = {
    "table1": table1_scaling.main,
    "table3": table3_incompressible.main,
    "table5": table5_beta.main,
    "kernel": kernel_microbench.main,
    "lm_roofline": lm_roofline.main,
}


def main() -> None:
    which = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    failed = []
    for name in which:
        try:
            TABLES[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

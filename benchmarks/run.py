"""Benchmark harness — one module per paper table (+ kernel + LM roofline).

    PYTHONPATH=src python -m benchmarks.run                    # all
    PYTHONPATH=src python -m benchmarks.run table5             # one
    PYTHONPATH=src python -m benchmarks.run --suite multilevel # same, flag form
                                             (writes BENCH_multilevel.json)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from repro import telemetry

from benchmarks import (
    autotune_suite,
    blocks_suite,
    cohort_suite,
    fft_suite,
    interp_suite,
    kernel_microbench,
    lm_roofline,
    multilevel_c2f,
    resilience_suite,
    table1_scaling,
    table3_incompressible,
    table5_beta,
)

TABLES = {
    "table1": table1_scaling.main,
    "table3": table3_incompressible.main,
    "table5": table5_beta.main,
    "kernel": kernel_microbench.main,
    "interp": interp_suite.main,
    "fft": fft_suite.main,
    "lm_roofline": lm_roofline.main,
    "multilevel": multilevel_c2f.main,
    "cohort": cohort_suite.main,
    "autotune": autotune_suite.main,
    "blocks": blocks_suite.main,
    "resilience": resilience_suite.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*", help=f"subset to run: {list(TABLES)}")
    ap.add_argument("--suite", action="append", default=[], choices=list(TABLES),
                    help="suite to run (repeatable); combined with positionals")
    args = ap.parse_args()
    which = list(args.suites) + list(args.suite) or list(TABLES)
    unknown = [w for w in which if w not in TABLES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from {list(TABLES)}")

    # REPRO_TRACE=path.jsonl captures every bench row as telemetry events
    telemetry.configure_from_env()
    print("name,us_per_call,derived")
    failed = []
    for name in which:
        try:
            TABLES[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

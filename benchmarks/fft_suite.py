"""Transform-coalescing / pipelined pencil-FFT suite (the ISSUE 5 record).

    PYTHONPATH=src python -m benchmarks.run --suite fft

Writes ``BENCH_fft.json`` at the repo root (structure pinned by
``tests/test_coalesce.py::test_bench_fft_record``):

* ``mesh`` — an 8-device pencil-mesh subprocess measuring the three
  communication levers on the lowered/compiled programs:
  - **counted all-to-alls**: the incompressible GN Hessian matvec with the
    coalesced elliptic assembly (``reg_plus_project``) vs the uncoalesced
    composition main used (``reg_apply`` + ``leray`` as separate round
    trips) — the ISSUE acceptance metric (>= 2x reduction, asserted on
    every run) — plus the ``newton_state`` stage-A pattern (div / reg /
    Lap of the same ``v``): eager per-call vs one ``SpectralBatch`` ride;
  - **packed vs unpacked**: all-to-all *bytes* (from the compiled HLO) and
    wall time of a batched forward with ``PencilFFT(packed=...)``;
  - **chunked vs unchunked**: wall time AND counted all-to-alls of a
    batched fwd+inv roundtrip per ``chunk`` setting (incl. the
    ``"auto"`` heuristic with its ``resolve_chunk`` result), with exact
    parity asserted; the ``chunk_winner`` block picks the cheapest
    setting and seeds the first ``repro.autotune`` tuning-cache entry
    (ISSUE 8 satellite);
  - **Armijo Parseval lever** (``armijo_trial``): counted all-to-alls of
    one line-search trial objective with the spectrum-side
    ``reg_energy`` riding the misfit transport's forward batch vs the
    old composition through ``reg_apply`` — >= 2 fewer all-to-alls per
    trial, asserted on every run (the solver-side pin is
    ``tests/test_coalesce.py::test_armijo_trial_drops_transform_ride_pin``).
* ``single_device`` — the LocalFFT leg: eager vs coalesced stage-A wall
  time (rfft batching amortization).

Env knobs: ``BENCH_FFT_TOY=1`` shrinks the grids and redirects the record
to ``results/BENCH_fft_toy.json`` (the ``scripts/smoke.sh`` tripwire —
still asserting the counted-collective structure); ``BENCH_FFT_OUT``
overrides the path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_fn

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_fft.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_fft_toy.json")

MESH_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, {root_src!r})
import jax, jax.numpy as jnp, numpy as np
from repro.core import objective as obj, semilag
from repro.core.grid import make_grid
from repro.dist.context import DistContext
from repro.dist.pencil_fft import PencilFFT
from repro.launch.mesh import make_mesh
from repro.telemetry import count_collectives
sys.path.insert(0, {root!r})
from benchmarks.common import time_fn

mesh = make_mesh((2, 4), ("data", "model"))
grid = make_grid({grid_shape!r})
# A/B measurement context: never consult the tuning cache this suite seeds
ctx = DistContext(grid, mesh, halo=2, autotune="off")
ops = ctx.ops
rng = np.random.default_rng(0)
n_t = 2

def compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()

def count_a2a(c):
    return count_collectives(c)["all-to-all"]["count"]

# ---- GN Hessian matvec: coalesced vs the uncoalesced composition (main) ----
rho_R = ctx.shard_scalar(jnp.asarray(rng.standard_normal(grid.shape), jnp.float32))
rho_T = ctx.shard_scalar(jnp.asarray(rng.standard_normal(grid.shape), jnp.float32))
prob = obj.Problem(grid, rho_R, rho_T, 1e-2, n_t, True)
v = jax.device_put(
    0.1 * jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
    ctx.vector_sharding())
state = jax.jit(lambda vv: obj.newton_state(vv, prob, ops, ctx.interp))(v)
p = jax.device_put(
    jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
    ctx.vector_sharding())

def matvec_coalesced(p):
    return obj.gn_hessian_matvec(p, state, prob, ops, ctx.interp)

def matvec_composed(p):  # the pre-coalescing composition, for the A/B count
    rho1_t = semilag.transport_inc_state(p, state.grad_rho_series, state.plan, ctx.interp)
    lamt = semilag.transport_inc_adjoint(-rho1_t, state.plan, ctx.interp)
    bt = semilag.time_integral_b(lamt, state.grad_rho_series, state.plan.dt)
    return ops.reg_apply(p, prob.beta) + ops.leray(bt)

c_co, c_cm = compiled(matvec_coalesced, p), compiled(matvec_composed, p)
ref = c_cm(p)
err_mv = float(jnp.max(jnp.abs(c_co(p) - ref)) / jnp.maximum(jnp.max(jnp.abs(ref)), 1.0))

# ---- newton_state stage A: eager per-call ops vs one SpectralBatch ride ----
def stage_a_eager(v):
    return ops.div(v), ops.reg_apply(v, 1e-2), ops.laplacian(v)

def stage_a_coalesced(v):
    with ops.batch() as sb:
        d, r, l = sb.div(v), sb.reg_apply(v, 1e-2), sb.laplacian(v)
    return d.get(), r.get(), l.get()

c_ae, c_ac = compiled(stage_a_eager, v), compiled(stage_a_coalesced, v)

# ---- packed vs unpacked forward: bytes + wall ----
B = {batch!r}
stack = jnp.asarray(rng.standard_normal((B,) + grid.shape), jnp.float32)
fft_p = PencilFFT(grid, mesh, packed=True)
fft_u = PencilFFT(grid, mesh, packed=False)
fwd_p = compiled(fft_p.fwd_packed, stack)
fwd_u = compiled(fft_u.fwd, stack)
bytes_p = count_collectives(fwd_p)["all-to-all"]["bytes"]
bytes_u = count_collectives(fwd_u)["all-to-all"]["bytes"]

# ---- chunked vs unchunked roundtrip: parity + wall + counted a2a ----
# Exercises resolve_chunk against the AUTO_CHUNK_TARGET_BYTES heuristic:
# each row records the *resolved* fields-per-chunk for this pencil
# footprint and the counted all-to-alls of the compiled roundtrip; the
# winner (fewest a2a launches, wall as tiebreak) seeds the tuning cache —
# the first autotune entry of a fresh checkout.
from repro.dist.pencil_fft import AUTO_CHUNK_TARGET_BYTES, resolve_chunk
ref_spec = fft_p.fwd(stack)
chunks = []
for chunk in (None, 1, 2, 4, "auto"):
    fft_c = PencilFFT(grid, mesh, chunk=chunk)
    rt = compiled(lambda u: fft_c.inv(fft_c.fwd(u)), stack)
    err = float(jnp.max(jnp.abs(fft_c.fwd(stack) - ref_spec)))
    chunks.append({{
        "chunk": 0 if chunk is None else fft_c.chunk,
        "label": str(chunk),
        "resolved_chunk": resolve_chunk(chunk, grid.shape, 2, 4) if chunk is not None else 0,
        "a2a_count": count_a2a(rt),
        "roundtrip_s": time_fn(rt, stack),
        "fwd_max_err": err,
    }})
winner_row = min(chunks, key=lambda r: (r["a2a_count"], r["roundtrip_s"]))
chunk_winner = None if winner_row["label"] == "None" else (
    "auto" if winner_row["label"] == "auto" else winner_row["chunk"])

# seed the tuning cache with the chunk winner (counted mode, beta-agnostic)
from repro.autotune import TunedConfig, TuningCache, cell_key
cache = TuningCache()
cache.put(
    cell_key(grid.shape, 8, None),
    TunedConfig(chunk=chunk_winner, mode="counted", cost=float(winner_row["a2a_count"])),
)

# ---- Armijo trial: Parseval reg energy vs the pre-Parseval composition ----
# (the ISSUE 8 lever: each line-search trial rides the forward spectrum for
# the regularization energy instead of paying a dedicated fwd+inv pair)
from repro.core.planner import make_plan

def trial_parseval(vv):
    jval, _ = obj.evaluate_objective(vv, prob, ops, ctx.interp)
    return jval

def trial_composed(vv):
    reg = 0.5 * grid.inner(vv, ops.reg_apply(vv, prob.beta))
    plan = make_plan(vv, grid, ops, prob.n_t, prob.incompressible, ctx.interp,
                     adjoint=False)
    rho1 = semilag.transport_state(prob.rho_T, plan, ctx.interp)[-1]
    return 0.5 * grid.inner(rho1 - prob.rho_R, rho1 - prob.rho_R) + reg

c_tp, c_tc = compiled(trial_parseval, v), compiled(trial_composed, v)
err_trial = abs(float(c_tp(v)) - float(c_tc(v))) / max(abs(float(c_tc(v))), 1.0)

rec = {{
    "mesh_shape": [2, 4],
    "grid": list(grid.shape),
    "n_t": n_t,
    "batch": B,
    "armijo_trial": {{
        "a2a_parseval": count_a2a(c_tp),
        "a2a_composed": count_a2a(c_tc),
        "parseval_s": time_fn(c_tp, v),
        "composed_s": time_fn(c_tc, v),
        "rel_err": err_trial,
    }},
    "chunk_winner": {{
        "label": winner_row["label"],
        "a2a_count": winner_row["a2a_count"],
        "auto_chunk_target_bytes": AUTO_CHUNK_TARGET_BYTES,
        "auto_resolved_fields": resolve_chunk("auto", grid.shape, 2, 4),
        "cache_path": cache.path,
    }},
    "all_to_alls": {{
        "gn_matvec_coalesced": count_a2a(c_co),
        "gn_matvec_composed": count_a2a(c_cm),
        "stage_a_coalesced": count_a2a(c_ac),
        "stage_a_eager": count_a2a(c_ae),
    }},
    "gn_matvec_rel_err": err_mv,
    "packed_fwd": {{
        "a2a_bytes_packed": int(bytes_p),
        "a2a_bytes_unpacked": int(bytes_u),
        "packed_s": time_fn(fwd_p, stack),
        "unpacked_s": time_fn(fwd_u, stack),
    }},
    "chunks": chunks,
}}
print(json.dumps(rec))
"""


def _mesh_record(grid_shape, batch) -> dict:
    code = MESH_BODY.format(
        root=ROOT, root_src=os.path.join(ROOT, "src"),
        grid_shape=tuple(grid_shape), batch=int(batch),
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"mesh sub-bench failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _single_device(n: int) -> dict:
    from repro.core.grid import make_grid
    from repro.core.spectral import SpectralOps

    grid = make_grid(n)
    ops = SpectralOps(grid)
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32)

    def eager(v):
        return ops.div(v), ops.reg_apply(v, 1e-2), ops.laplacian(v)

    def coalesced(v):
        with ops.batch() as sb:
            d, r, l = sb.div(v), sb.reg_apply(v, 1e-2), sb.laplacian(v)
        return d.get(), r.get(), l.get()

    e, c = jax.jit(eager), jax.jit(coalesced)
    de, dc = e(v), c(v)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(de, dc))
    return {
        "n": n,
        "eager_s": time_fn(e, v, iters=5),
        "coalesced_s": time_fn(c, v, iters=5),
        "max_err": err,
    }


def measure(toy: bool = False) -> dict:
    mesh_grid = (8, 8, 16) if toy else (16, 16, 32)
    return {
        "mesh": _mesh_record(mesh_grid, batch=6 if toy else 12),
        "single_device": _single_device(16 if toy else 48),
    }


def write_record(rec: dict, out: str) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(int(os.environ.get("BENCH_FFT_TOY", "0")))
    out = out or os.environ.get("BENCH_FFT_OUT") or (TOY_OUT if toy else DEFAULT_OUT)
    rec = measure(toy=toy)
    write_record(rec, out)

    m = rec["mesh"]
    a2a = m["all_to_alls"]
    emit(
        "fft/mesh_gn_matvec",
        0.0,
        f"a2a_coalesced={a2a['gn_matvec_coalesced']};"
        f"a2a_composed={a2a['gn_matvec_composed']};"
        f"reduction={a2a['gn_matvec_composed'] / max(a2a['gn_matvec_coalesced'], 1):.2f}x",
    )
    pf = m["packed_fwd"]
    emit(
        "fft/mesh_packed_fwd",
        pf["packed_s"] * 1e6,
        f"unpacked={pf['unpacked_s']*1e6:.0f}us;"
        f"bytes={pf['a2a_bytes_packed']}/{pf['a2a_bytes_unpacked']}",
    )
    for row in m["chunks"]:
        emit(f"fft/mesh_chunk_{row['label']}", row["roundtrip_s"] * 1e6,
             f"chunk={row['chunk']};a2a={row.get('a2a_count', '?')};"
             f"err={row['fwd_max_err']:.1e}")
    cw = m["chunk_winner"]
    emit("fft/mesh_chunk_winner", 0.0,
         f"label={cw['label']};a2a={cw['a2a_count']};"
         f"auto_fields={cw['auto_resolved_fields']};cache={cw['cache_path']}")
    at = m["armijo_trial"]
    emit("fft/mesh_armijo_trial", at["parseval_s"] * 1e6,
         f"composed={at['composed_s']*1e6:.0f}us;"
         f"a2a={at['a2a_parseval']}/{at['a2a_composed']}")
    sd = rec["single_device"]
    emit(
        f"fft/local_N{sd['n']}",
        sd["coalesced_s"] * 1e6,
        f"eager={sd['eager_s']*1e6:.0f}us;"
        f"speedup={sd['eager_s']/max(sd['coalesced_s'], 1e-12):.2f}x",
    )

    # the tentpole's structural claims, enforced on every run (incl. toy)
    assert 2 * a2a["gn_matvec_coalesced"] <= a2a["gn_matvec_composed"], a2a
    assert 2 * a2a["stage_a_coalesced"] <= a2a["stage_a_eager"], a2a
    assert m["gn_matvec_rel_err"] < 1e-3, m["gn_matvec_rel_err"]
    assert pf["a2a_bytes_packed"] < pf["a2a_bytes_unpacked"], pf
    for row in m["chunks"]:
        assert row["fwd_max_err"] < 1e-3, row
    assert sd["max_err"] < 1e-3, sd
    # ISSUE 8: the Parseval trial saves at least one full transform ride
    assert at["a2a_composed"] - at["a2a_parseval"] >= 2, at
    assert at["rel_err"] < 1e-4, at
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()

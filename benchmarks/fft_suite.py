"""Transform-coalescing / pipelined pencil-FFT suite (the ISSUE 5 record).

    PYTHONPATH=src python -m benchmarks.run --suite fft

Writes ``BENCH_fft.json`` at the repo root (structure pinned by
``tests/test_coalesce.py::test_bench_fft_record``):

* ``mesh`` — an 8-device pencil-mesh subprocess measuring the three
  communication levers on the lowered/compiled programs:
  - **counted all-to-alls**: the incompressible GN Hessian matvec with the
    coalesced elliptic assembly (``reg_plus_project``) vs the uncoalesced
    composition main used (``reg_apply`` + ``leray`` as separate round
    trips) — the ISSUE acceptance metric (>= 2x reduction, asserted on
    every run) — plus the ``newton_state`` stage-A pattern (div / reg /
    Lap of the same ``v``): eager per-call vs one ``SpectralBatch`` ride;
  - **packed vs unpacked**: all-to-all *bytes* (from the compiled HLO) and
    wall time of a batched forward with ``PencilFFT(packed=...)``;
  - **chunked vs unchunked**: wall time of a batched fwd+inv roundtrip per
    ``chunk`` setting, with exact parity asserted (the overlap itself
    needs real hardware; placeholder-device wall times mainly confirm the
    chunked program costs no extra work).
* ``single_device`` — the LocalFFT leg: eager vs coalesced stage-A wall
  time (rfft batching amortization).

Env knobs: ``BENCH_FFT_TOY=1`` shrinks the grids and redirects the record
to ``results/BENCH_fft_toy.json`` (the ``scripts/smoke.sh`` tripwire —
still asserting the counted-collective structure); ``BENCH_FFT_OUT``
overrides the path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_fn

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_fft.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_fft_toy.json")

MESH_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, {root_src!r})
import jax, jax.numpy as jnp, numpy as np
from repro.core import objective as obj, semilag
from repro.core.grid import make_grid
from repro.dist.context import DistContext
from repro.dist.pencil_fft import PencilFFT
from repro.launch.mesh import make_mesh
from repro.telemetry import count_collectives
sys.path.insert(0, {root!r})
from benchmarks.common import time_fn

mesh = make_mesh((2, 4), ("data", "model"))
grid = make_grid({grid_shape!r})
ctx = DistContext(grid, mesh, halo=2)
ops = ctx.ops
rng = np.random.default_rng(0)
n_t = 2

def compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()

def count_a2a(c):
    return count_collectives(c)["all-to-all"]["count"]

# ---- GN Hessian matvec: coalesced vs the uncoalesced composition (main) ----
rho_R = ctx.shard_scalar(jnp.asarray(rng.standard_normal(grid.shape), jnp.float32))
rho_T = ctx.shard_scalar(jnp.asarray(rng.standard_normal(grid.shape), jnp.float32))
prob = obj.Problem(grid, rho_R, rho_T, 1e-2, n_t, True)
v = jax.device_put(
    0.1 * jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
    ctx.vector_sharding())
state = jax.jit(lambda vv: obj.newton_state(vv, prob, ops, ctx.interp))(v)
p = jax.device_put(
    jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
    ctx.vector_sharding())

def matvec_coalesced(p):
    return obj.gn_hessian_matvec(p, state, prob, ops, ctx.interp)

def matvec_composed(p):  # the pre-coalescing composition, for the A/B count
    rho1_t = semilag.transport_inc_state(p, state.grad_rho_series, state.plan, ctx.interp)
    lamt = semilag.transport_inc_adjoint(-rho1_t, state.plan, ctx.interp)
    bt = semilag.time_integral_b(lamt, state.grad_rho_series, state.plan.dt)
    return ops.reg_apply(p, prob.beta) + ops.leray(bt)

c_co, c_cm = compiled(matvec_coalesced, p), compiled(matvec_composed, p)
ref = c_cm(p)
err_mv = float(jnp.max(jnp.abs(c_co(p) - ref)) / jnp.maximum(jnp.max(jnp.abs(ref)), 1.0))

# ---- newton_state stage A: eager per-call ops vs one SpectralBatch ride ----
def stage_a_eager(v):
    return ops.div(v), ops.reg_apply(v, 1e-2), ops.laplacian(v)

def stage_a_coalesced(v):
    with ops.batch() as sb:
        d, r, l = sb.div(v), sb.reg_apply(v, 1e-2), sb.laplacian(v)
    return d.get(), r.get(), l.get()

c_ae, c_ac = compiled(stage_a_eager, v), compiled(stage_a_coalesced, v)

# ---- packed vs unpacked forward: bytes + wall ----
B = {batch!r}
stack = jnp.asarray(rng.standard_normal((B,) + grid.shape), jnp.float32)
fft_p = PencilFFT(grid, mesh, packed=True)
fft_u = PencilFFT(grid, mesh, packed=False)
fwd_p = compiled(fft_p.fwd_packed, stack)
fwd_u = compiled(fft_u.fwd, stack)
bytes_p = count_collectives(fwd_p)["all-to-all"]["bytes"]
bytes_u = count_collectives(fwd_u)["all-to-all"]["bytes"]

# ---- chunked vs unchunked roundtrip: parity + wall ----
ref_spec = fft_p.fwd(stack)
chunks = []
for chunk in (None, 1, 2, 4, "auto"):
    fft_c = PencilFFT(grid, mesh, chunk=chunk)
    rt = compiled(lambda u: fft_c.inv(fft_c.fwd(u)), stack)
    err = float(jnp.max(jnp.abs(fft_c.fwd(stack) - ref_spec)))
    chunks.append({{
        "chunk": 0 if chunk is None else fft_c.chunk,
        "label": str(chunk),
        "roundtrip_s": time_fn(rt, stack),
        "fwd_max_err": err,
    }})

rec = {{
    "mesh_shape": [2, 4],
    "grid": list(grid.shape),
    "n_t": n_t,
    "batch": B,
    "all_to_alls": {{
        "gn_matvec_coalesced": count_a2a(c_co),
        "gn_matvec_composed": count_a2a(c_cm),
        "stage_a_coalesced": count_a2a(c_ac),
        "stage_a_eager": count_a2a(c_ae),
    }},
    "gn_matvec_rel_err": err_mv,
    "packed_fwd": {{
        "a2a_bytes_packed": int(bytes_p),
        "a2a_bytes_unpacked": int(bytes_u),
        "packed_s": time_fn(fwd_p, stack),
        "unpacked_s": time_fn(fwd_u, stack),
    }},
    "chunks": chunks,
}}
print(json.dumps(rec))
"""


def _mesh_record(grid_shape, batch) -> dict:
    code = MESH_BODY.format(
        root=ROOT, root_src=os.path.join(ROOT, "src"),
        grid_shape=tuple(grid_shape), batch=int(batch),
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"mesh sub-bench failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _single_device(n: int) -> dict:
    from repro.core.grid import make_grid
    from repro.core.spectral import SpectralOps

    grid = make_grid(n)
    ops = SpectralOps(grid)
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32)

    def eager(v):
        return ops.div(v), ops.reg_apply(v, 1e-2), ops.laplacian(v)

    def coalesced(v):
        with ops.batch() as sb:
            d, r, l = sb.div(v), sb.reg_apply(v, 1e-2), sb.laplacian(v)
        return d.get(), r.get(), l.get()

    e, c = jax.jit(eager), jax.jit(coalesced)
    de, dc = e(v), c(v)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(de, dc))
    return {
        "n": n,
        "eager_s": time_fn(e, v, iters=5),
        "coalesced_s": time_fn(c, v, iters=5),
        "max_err": err,
    }


def measure(toy: bool = False) -> dict:
    mesh_grid = (8, 8, 16) if toy else (16, 16, 32)
    return {
        "mesh": _mesh_record(mesh_grid, batch=6 if toy else 12),
        "single_device": _single_device(16 if toy else 48),
    }


def write_record(rec: dict, out: str) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(int(os.environ.get("BENCH_FFT_TOY", "0")))
    out = out or os.environ.get("BENCH_FFT_OUT") or (TOY_OUT if toy else DEFAULT_OUT)
    rec = measure(toy=toy)
    write_record(rec, out)

    m = rec["mesh"]
    a2a = m["all_to_alls"]
    emit(
        "fft/mesh_gn_matvec",
        0.0,
        f"a2a_coalesced={a2a['gn_matvec_coalesced']};"
        f"a2a_composed={a2a['gn_matvec_composed']};"
        f"reduction={a2a['gn_matvec_composed'] / max(a2a['gn_matvec_coalesced'], 1):.2f}x",
    )
    pf = m["packed_fwd"]
    emit(
        "fft/mesh_packed_fwd",
        pf["packed_s"] * 1e6,
        f"unpacked={pf['unpacked_s']*1e6:.0f}us;"
        f"bytes={pf['a2a_bytes_packed']}/{pf['a2a_bytes_unpacked']}",
    )
    for row in m["chunks"]:
        emit(f"fft/mesh_chunk_{row['label']}", row["roundtrip_s"] * 1e6,
             f"chunk={row['chunk']};err={row['fwd_max_err']:.1e}")
    sd = rec["single_device"]
    emit(
        f"fft/local_N{sd['n']}",
        sd["coalesced_s"] * 1e6,
        f"eager={sd['eager_s']*1e6:.0f}us;"
        f"speedup={sd['eager_s']/max(sd['coalesced_s'], 1e-12):.2f}x",
    )

    # the tentpole's structural claims, enforced on every run (incl. toy)
    assert 2 * a2a["gn_matvec_coalesced"] <= a2a["gn_matvec_composed"], a2a
    assert 2 * a2a["stage_a_coalesced"] <= a2a["stage_a_eager"], a2a
    assert m["gn_matvec_rel_err"] < 1e-3, m["gn_matvec_rel_err"]
    assert pf["a2a_bytes_packed"] < pf["a2a_bytes_unpacked"], pf
    for row in m["chunks"]:
        assert row["fwd_max_err"] < 1e-3, row
    assert sd["max_err"] < 1e-3, sd
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()

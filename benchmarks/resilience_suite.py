"""Resilience suite: recovery overhead vs healthy baseline + ckpt/resume cost.

    PYTHONPATH=src python -m benchmarks.run --suite resilience

Two cells, written to ``BENCH_resilience.json``:

* ``chaos`` — the same job stream served twice: healthy, then with a NaN
  injected into one job's iterate mid-flight under
  ``RetryPolicy(max_attempts=2)``.  The record pins the three invariants
  the subsystem exists for — every un-faulted job's velocity is
  BIT-IDENTICAL to the healthy run (``unfaulted_bit_identical``), the
  faulted job completes through the degraded retry
  (``faulted_completed``), and the whole chaos session still compiles ONE
  executable (the beta-only rung re-uses the primary bucket's program) —
  plus the measured recovery overhead (``overhead_ratio``: faulted wall /
  healthy wall, the cost of the retry attempt).
* ``ckpt`` — the same stream with periodic checkpointing: an
  uninterrupted reference run, a run killed mid-stream
  (``KillAt`` -> ``SimulatedCrash``), and the resume from the latest
  snapshot.  Pins that the resume re-serves ONLY the unfinished jobs and
  reproduces the reference bit-identically with billing preserved, and
  records the cost split: checkpointing overhead
  (``checkpoint_overhead_ratio`` vs the un-checkpointed healthy wall) and
  the resume's wall as a fraction of the full run
  (``resume_wall_fraction`` — the work the snapshot saved).

``BENCH_RESILIENCE_TOY=1`` (used by ``scripts/smoke.sh``) shrinks the
problem and writes ``results/BENCH_resilience_toy.json`` instead of the
committed record.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks import common
from benchmarks.common import emit
from repro import telemetry
from repro.core import gauss_newton as gn
from repro.data import synthetic

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_resilience.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_resilience_toy.json")


def _jobs(n, amps, n_t):
    from repro.launch.reg_serve import RegJob

    jobs, grid = [], None
    for j, a in enumerate(amps):
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(n, n_t=n_t, amplitude=a)
        jobs.append(RegJob(job_id=f"job{j}", rho_R=rho_R, rho_T=rho_T))
    return jobs, grid


def measure_chaos(n: int = 24, amps=(0.3, 0.6, 0.9, 1.2), n_t: int = 4,
                  beta: float = 1e-2, gtol: float = 1e-2, max_newton: int = 12,
                  max_cg: int = 50, slots: int = 2, fault_job: str = "job1",
                  fault_iteration: int = 1) -> dict:
    """Healthy serve vs the same stream with one NaN-poisoned iterate."""
    import numpy as np

    from repro.launch.reg_serve import serve_jobs
    from repro.resilience import health
    from repro.resilience.faults import NaNInjector
    from repro.resilience.policy import RetryPolicy

    cfg = gn.GNConfig(beta=beta, n_t=n_t, max_newton=max_newton, gtol=gtol,
                      max_cg=max_cg)

    jobs, _ = _jobs(n, amps, n_t)
    t0 = time.time()
    healthy = serve_jobs(jobs, cfg, slots=slots)
    t_healthy = time.time() - t0
    ref = {r.job_id: r for r in healthy["results"]}

    jobs, _ = _jobs(n, amps, n_t)
    fault = NaNInjector(job_id=fault_job, field="v", at_iteration=fault_iteration)
    t0 = time.time()
    chaos = serve_jobs(jobs, cfg, slots=slots,
                       retry=RetryPolicy(max_attempts=2), faults=[fault])
    t_chaos = time.time() - t0
    res = {r.job_id: r for r in chaos["results"]}

    unfaulted = sorted(set(ref) - {fault_job})
    bit_identical = all(
        np.array_equal(res[j].v, ref[j].v)
        and res[j].hessian_matvecs == ref[j].hessian_matvecs
        for j in unfaulted
    )
    rec = {
        "problem": {"grid": [n, n, n], "beta": beta, "gtol": gtol, "n_t": n_t,
                    "amplitudes": list(amps), "jobs": len(amps),
                    "slots": slots, "fault_job": fault_job,
                    "fault_iteration": fault_iteration},
        "healthy": {
            "wall_s": t_healthy,
            "cohort_iterations": _iterations(healthy),
            "compiled_executables": healthy["compiled_executables"],
        },
        "faulted": {
            "wall_s": t_chaos,
            "cohort_iterations": _iterations(chaos),
            "compiled_executables": chaos["compiled_executables"],
            "per_job": [
                {"job_id": r.job_id, "status": r.status,
                 "attempts": int(r.attempts),
                 "newton_iters": r.newton_iters,
                 "fine_equiv_matvecs": r.fine_equiv_matvecs}
                for r in sorted(chaos["results"], key=lambda r: r.job_id)
            ],
        },
        "overhead_ratio": t_chaos / max(t_healthy, 1e-30),
        "unfaulted_bit_identical": bit_identical,
        "faulted_completed": (
            res[fault_job].attempts == 2
            and res[fault_job].status not in health.FAILED_NAMES
            and bool(np.isfinite(res[fault_job].v).all())
        ),
    }
    # the invariants the suite exists to record
    assert fault.fired
    assert rec["unfaulted_bit_identical"], "fault leaked into healthy lanes"
    assert rec["faulted_completed"], res[fault_job].status
    assert chaos["compiled_executables"] == 1, chaos["compiled_executables"]
    return rec


def _iterations(out: dict) -> int:
    return sum(st["cohort_iterations"] for st in out["buckets"].values())


def measure_ckpt(n: int = 24, amps=(0.3, 0.6, 0.9, 1.2), n_t: int = 4,
                 beta: float = 1e-2, gtol: float = 1e-2, max_newton: int = 12,
                 max_cg: int = 50, slots: int = 2, checkpoint_every: int = 2,
                 kill_at: int = 4) -> dict:
    """Checkpointed run, kill mid-stream, resume from the latest snapshot."""
    import numpy as np

    from repro.launch.reg_serve import serve_jobs
    from repro.resilience.faults import KillAt, SimulatedCrash

    cfg = gn.GNConfig(beta=beta, n_t=n_t, max_newton=max_newton, gtol=gtol,
                      max_cg=max_cg)

    with tempfile.TemporaryDirectory() as tmp:
        jobs, _ = _jobs(n, amps, n_t)
        t0 = time.time()
        plain = serve_jobs(jobs, cfg, slots=slots)
        t_plain = time.time() - t0

        jobs, _ = _jobs(n, amps, n_t)
        t0 = time.time()
        ref_out = serve_jobs(jobs, cfg, slots=slots,
                             checkpoint=os.path.join(tmp, "ref"),
                             checkpoint_every=checkpoint_every)
        t_ref = time.time() - t0
        ref = {r.job_id: r for r in ref_out["results"]}

        ck = os.path.join(tmp, "ck")
        jobs, _ = _jobs(n, amps, n_t)
        kill = KillAt(at_iteration=kill_at)
        t0 = time.time()
        try:
            serve_jobs(jobs, cfg, slots=slots, checkpoint=ck,
                       checkpoint_every=checkpoint_every, faults=[kill])
            raise RuntimeError("KillAt never fired")
        except SimulatedCrash:
            pass
        t_killed = time.time() - t0

        with telemetry.ListSink() as sink:
            t0 = time.time()
            out2 = serve_jobs([], cfg, slots=slots, checkpoint=ck,
                              checkpoint_every=checkpoint_every, resume=True)
            t_resume = time.time() - t0
        res = {r.job_id: r for r in out2["results"]}
        recov = next(r for r in sink.records
                     if r["kind"] == "recovery"
                     and r["action"] == "resume_from_checkpoint")

    preserved = set(res) == set(ref) and all(
        np.array_equal(res[j].v, ref[j].v)
        and res[j].hessian_matvecs == ref[j].hessian_matvecs
        and res[j].status == ref[j].status
        for j in ref
    )
    rec = {
        "problem": {"grid": [n, n, n], "jobs": len(amps), "slots": slots,
                    "checkpoint_every": checkpoint_every, "kill_at": kill_at},
        "wall_s_plain": t_plain,
        "wall_s_checkpointed": t_ref,
        "checkpoint_overhead_ratio": t_ref / max(t_plain, 1e-30),
        "wall_s_killed": t_killed,
        "wall_s_resume": t_resume,
        "resume_wall_fraction": t_resume / max(t_ref, 1e-30),
        "resumed_from_step": recov["step"],
        "completed_in_snapshot": recov["attrs"]["completed"],
        "reserved_unfinished": recov["attrs"]["unfinished"],
        "resume_bit_identical": preserved,
    }
    assert kill.fired
    assert rec["resume_bit_identical"], "resume drifted from the reference run"
    assert recov["attrs"]["completed"] + recov["attrs"]["unfinished"] == len(amps)
    return rec


def write_record(rec: dict, out: str = DEFAULT_OUT) -> None:
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(os.environ.get("BENCH_RESILIENCE_TOY"))
    out = out or (TOY_OUT if toy else DEFAULT_OUT)
    if toy:
        kw = dict(n=12, amps=(0.4, 0.8, 1.2), n_t=2, max_newton=6, max_cg=15)
        rec = {"chaos": measure_chaos(**kw),
               "ckpt": measure_ckpt(kill_at=3, **kw)}
    else:
        rec = {"chaos": measure_chaos(), "ckpt": measure_ckpt()}
    write_record(rec, out)
    ch, ck = rec["chaos"], rec["ckpt"]
    emit("resilience/chaos_serve", ch["faulted"]["wall_s"] * 1e6,
         f"overhead={ch['overhead_ratio']:.3f};"
         f"bit_identical={ch['unfaulted_bit_identical']};"
         f"executables={ch['faulted']['compiled_executables']}")
    emit("resilience/ckpt_resume", ck["wall_s_resume"] * 1e6,
         f"ckpt_overhead={ck['checkpoint_overhead_ratio']:.3f};"
         f"resume_fraction={ck['resume_wall_fraction']:.3f};"
         f"reserved={ck['reserved_unfinished']}")


if __name__ == "__main__":
    main()

"""Coarse-to-fine vs single-level Gauss-Newton: the grid-continuation table.

    PYTHONPATH=src python -m benchmarks.run --suite multilevel

Solves the paper's synthetic problem once at fixed (fine) resolution and
once through the ``repro.multilevel`` ladder, at the same convergence
tolerance (the warm-started fine level terminates against the cold-start
fine gradient norm), and emits ``BENCH_multilevel.json``: per-level Hessian
matvecs, fine-grid-equivalent matvecs (matvecs weighted by level/fine point
ratio — the paper's Table V cost metric made resolution-aware), and
wall-clock, next to the single-level baseline column.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit
from repro.core import gauss_newton as gn
from repro.data import synthetic


DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_multilevel.json")


def measure(n: int = 24, beta: float = 1e-2, gtol: float = 1e-2, n_levels: int = 2,
            max_newton: int = 12, max_cg: int = 50) -> dict:
    """Run the single-level baseline and the C2F ladder; return the record."""
    from repro import multilevel
    from repro.multilevel.hierarchy import MultilevelConfig

    rho_R, rho_T, _, grid = synthetic.synthetic_problem(n)
    base = gn.GNConfig(beta=beta, n_t=4, max_newton=max_newton, gtol=gtol, max_cg=max_cg)

    t0 = time.time()
    single = gn.solve(rho_R, rho_T, grid, base)
    t_single = time.time() - t0

    mlcfg = MultilevelConfig(solver=base, n_levels=n_levels)
    t0 = time.time()
    ml = multilevel.solve(rho_R, rho_T, grid, mlcfg)
    t_ml = time.time() - t0

    return {
        "problem": {"fine_grid": list(grid.shape), "beta": beta, "gtol": gtol,
                    "levels": ml["grids"]},
        "single_level": {
            "newton_iters": single["newton_iters"],
            "hessian_matvecs": single["hessian_matvecs"],
            "fine_equiv_matvecs": float(single["hessian_matvecs"]),
            "rel_gnorm": single["history"][-1]["rel_gnorm"],
            "wall_s": t_single,
        },
        "multilevel": {
            "levels": ml["levels"],
            "newton_iters": ml["newton_iters"],
            "fine_grid_matvecs": ml["fine_matvecs"],
            "fine_equiv_matvecs": ml["fine_equiv_matvecs"],
            "rel_gnorm": ml["history"][-1]["rel_gnorm"],
            "wall_s": t_ml,
        },
    }


def write_record(rec: dict, out: str = DEFAULT_OUT) -> None:
    with open(out + ".tmp", "w") as f:
        json.dump(rec, f, indent=1)
    os.replace(out + ".tmp", out)


def main(out: str = DEFAULT_OUT):
    rec = measure()
    write_record(rec, out)
    s, m = rec["single_level"], rec["multilevel"]
    emit("multilevel/single_level", s["wall_s"] * 1e6,
         f"matvecs={s['hessian_matvecs']};fine_equiv={s['fine_equiv_matvecs']:.1f}")
    emit("multilevel/coarse_to_fine", m["wall_s"] * 1e6,
         f"fine_matvecs={m['fine_grid_matvecs']};fine_equiv={m['fine_equiv_matvecs']:.1f}")
    for lv in m["levels"]:
        emit(f"multilevel/level_{'x'.join(map(str, lv['shape']))}", lv["wall_s"] * 1e6,
             f"matvecs={lv['hessian_matvecs']};fine_equiv={lv['fine_equiv_matvecs']:.1f}")
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()

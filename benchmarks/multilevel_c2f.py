"""Coarse-to-fine and multigrid-preconditioner suite: the grid-continuation
table plus the preconditioner beta sweep.

    PYTHONPATH=src python -m benchmarks.run --suite multilevel

Two measurements, both written (merged) into ``BENCH_multilevel.json``:

* ``measure`` — the paper's synthetic problem solved once at fixed (fine)
  resolution and once through the ``repro.multilevel`` ladder, at the same
  convergence tolerance (the warm-started fine level terminates against
  the cold-start fine gradient norm).  Emits per-level Hessian matvecs,
  fine-grid-equivalent matvecs (matvecs weighted by level/fine point
  ratio — the paper's Table V cost metric made resolution-aware), and
  wall-clock, next to the single-level baseline column.  Feeds
  EXPERIMENTS.md §Multilevel (table "coarse-to-fine vs single-level").
* ``precond_sweep`` — the preconditioner A/B at beta in {1e-2, 1e-3,
  1e-4} on ONE fixed 3-level ladder: the paper's spectral
  ``(beta Lap^2)^{-1}`` vs the PR-2 two-level scheme vs the recursive
  Galerkin V-cycle (``repro.multilevel.precond``).  Columns record the
  outer fine-grid matvecs AND the preconditioner-internal coarse matvecs
  (``precond_fine_equiv``), so ``total_fine_equiv`` is the honest cost.
  Feeds EXPERIMENTS.md §Multilevel (table "preconditioner beta sweep",
  the Table V analogue).

``BENCH_ML_TOY=1`` (used by ``scripts/smoke.sh``) shrinks both to toy
size and writes ``results/BENCH_multilevel_toy.json`` instead of the
committed record.
"""
from __future__ import annotations

import os
import time

from benchmarks import common
from benchmarks.common import emit
from repro.core import gauss_newton as gn
from repro.data import synthetic


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_multilevel.json")
TOY_OUT = os.path.join(ROOT, "results", "BENCH_multilevel_toy.json")


def measure(n: int = 24, beta: float = 1e-2, gtol: float = 1e-2, n_levels: int = 2,
            max_newton: int = 12, max_cg: int = 50) -> dict:
    """Run the single-level baseline and the C2F ladder; return the record."""
    from repro import multilevel
    from repro.multilevel.hierarchy import MultilevelConfig

    rho_R, rho_T, _, grid = synthetic.synthetic_problem(n)
    base = gn.GNConfig(beta=beta, n_t=4, max_newton=max_newton, gtol=gtol, max_cg=max_cg)

    t0 = time.time()
    single = gn.solve(rho_R, rho_T, grid, base)
    t_single = time.time() - t0

    mlcfg = MultilevelConfig(solver=base, n_levels=n_levels)
    t0 = time.time()
    ml = multilevel.solve(rho_R, rho_T, grid, mlcfg)
    t_ml = time.time() - t0

    return {
        "problem": {"fine_grid": list(grid.shape), "beta": beta, "gtol": gtol,
                    "levels": ml["grids"]},
        "single_level": {
            "newton_iters": single["newton_iters"],
            "hessian_matvecs": single["hessian_matvecs"],
            "fine_equiv_matvecs": float(single["hessian_matvecs"]),
            "rel_gnorm": single["history"][-1]["rel_gnorm"],
            "wall_s": t_single,
        },
        "multilevel": {
            "levels": ml["levels"],
            "newton_iters": ml["newton_iters"],
            "fine_grid_matvecs": ml["fine_matvecs"],
            "fine_equiv_matvecs": ml["fine_equiv_matvecs"],
            "rel_gnorm": ml["history"][-1]["rel_gnorm"],
            "wall_s": t_ml,
        },
    }


# --------------------------------------------------------------------------- #
# preconditioner beta sweep: spectral vs two-level vs V-cycle
# --------------------------------------------------------------------------- #
SCHEMES = ("spectral", "two_level", "vcycle")


def precond_cell(rho_R, rho_T, grid, scheme: str, beta: float, *, n_levels: int = 3,
                 gtol: float = 1e-2, max_newton: int = 6, max_cg: int = 200) -> dict:
    """One C2F solve on a fixed ladder, varying only the preconditioner."""
    from repro import multilevel
    from repro.multilevel.hierarchy import MultilevelConfig

    base = gn.GNConfig(beta=beta, n_t=4, max_newton=max_newton, gtol=gtol, max_cg=max_cg)
    cfg = MultilevelConfig(
        solver=base,
        n_levels=n_levels,
        precond={"spectral": "none"}.get(scheme, scheme),
    )
    t0 = time.time()
    out = multilevel.solve(rho_R, rho_T, grid, cfg)
    return {
        "fine_matvecs": out["fine_matvecs"],
        "fine_equiv_matvecs": out["fine_equiv_matvecs"],
        "precond_fine_equiv_matvecs": out["precond_fine_equiv_matvecs"],
        "total_fine_equiv_matvecs": out["total_fine_equiv_matvecs"],
        "newton_iters": out["newton_iters"],
        "rel_gnorm": out["history"][-1]["rel_gnorm"],
        "levels": out["grids"],
        "wall_s": time.time() - t0,
    }


def precond_sweep(n: int = 32, betas=(1e-2, 1e-3, 1e-4), n_levels: int = 3,
                  gtol: float = 1e-2) -> dict:
    """The Table V analogue: matvec counts vs beta per preconditioner."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(n)
    rows = []
    for beta in betas:
        row = {"beta": beta}
        for scheme in SCHEMES:
            row[scheme] = precond_cell(rho_R, rho_T, grid, scheme, beta,
                                       n_levels=n_levels, gtol=gtol)
        rows.append(row)
    return {
        "fine_grid": list(grid.shape),
        "n_levels": n_levels,
        "gtol": gtol,
        "schemes": list(SCHEMES),
        "rows": rows,
    }


def write_record(rec: dict, out: str = DEFAULT_OUT) -> None:
    """Merge ``rec``'s top-level keys into the existing record (so the C2F
    table and the precond sweep can be refreshed independently)."""
    common.write_record(rec, out)


def main(out: str | None = None):
    toy = bool(os.environ.get("BENCH_ML_TOY"))
    out = out or (TOY_OUT if toy else DEFAULT_OUT)
    rec = measure(n=16 if toy else 24)
    rec["precond_sweep"] = (
        precond_sweep(n=16, betas=(1e-2, 1e-4), n_levels=2)
        if toy
        else precond_sweep()
    )
    write_record(rec, out)
    s, m = rec["single_level"], rec["multilevel"]
    emit("multilevel/single_level", s["wall_s"] * 1e6,
         f"matvecs={s['hessian_matvecs']};fine_equiv={s['fine_equiv_matvecs']:.1f}")
    emit("multilevel/coarse_to_fine", m["wall_s"] * 1e6,
         f"fine_matvecs={m['fine_grid_matvecs']};fine_equiv={m['fine_equiv_matvecs']:.1f}")
    for lv in m["levels"]:
        emit(f"multilevel/level_{'x'.join(map(str, lv['shape']))}", lv["wall_s"] * 1e6,
             f"matvecs={lv['hessian_matvecs']};fine_equiv={lv['fine_equiv_matvecs']:.1f}")
    for row in rec["precond_sweep"]["rows"]:
        for scheme in SCHEMES:
            c = row[scheme]
            emit(f"multilevel/precond_{scheme}_beta{row['beta']:.0e}", c["wall_s"] * 1e6,
                 f"fine_matvecs={c['fine_matvecs']};total_fine_equiv="
                 f"{c['total_fine_equiv_matvecs']:.1f}")
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()

"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + SHARED attention block every
9th slot (6 invocations of one weight set). [arXiv:2411.15242; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "zamba2-2.7b"

_PATTERN = ("shared",) + ("mamba",) * 8  # 54 layers = 6 groups x 9


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv=32, head_dim=80,
        d_ff=10240, vocab=32000,
        mlp="swiglu", tie_embeddings=True,
        layer_pattern=_PATTERN,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        notes="shared block reuses one param set across its 6 invocations "
        "(per-invocation LoRA deltas of the hf model omitted); each "
        "invocation keeps its own KV cache.",
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

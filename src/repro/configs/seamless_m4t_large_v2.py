"""seamless-m4t-large-v2 [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206; the speech frontend is a
STUB (input_specs supplies frame embeddings). [arXiv:2308.11596; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "seamless-m4t-large-v2"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="encdec",
        n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
        head_dim=64, d_ff=8192, vocab=256206,
        mlp="swiglu", tie_embeddings=True,
        layer_pattern=("attn",),
        notes="vocab 256206 padded to 256256 for 16-way TP divisibility.",
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

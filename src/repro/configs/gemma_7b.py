"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000; GeGLU, head_dim=256, scaled embeddings, tied head.
[arXiv:2403.08295; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "gemma-7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv=16, head_dim=256,
        d_ff=24576, vocab=256000,
        mlp="geglu", embed_scale=True, tie_embeddings=True,
        layer_pattern=("attn",), rope_theta=10_000.0,
        notes="MQA appears on gemma-2b only; 7b is full 16/16 MHA.",
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

"""Config registry: ``get_config("gemma-7b")`` / ``list_archs()``."""
from __future__ import annotations

from repro.configs import (
    gemma3_1b,
    gemma_7b,
    mamba2_130m,
    minitron_4b,
    moonshot_v1_16b_a3b,
    qwen2_vl_72b,
    qwen3_1_7b,
    qwen3_moe_235b_a22b,
    seamless_m4t_large_v2,
    zamba2_2_7b,
)
from repro.configs.claire_registration import GRIDS as REGISTRATION_GRIDS

_MODULES = {
    m.ARCH_ID: m
    for m in (
        gemma_7b,
        gemma3_1b,
        minitron_4b,
        qwen3_1_7b,
        mamba2_130m,
        qwen2_vl_72b,
        seamless_m4t_large_v2,
        moonshot_v1_16b_a3b,
        qwen3_moe_235b_a22b,
        zamba2_2_7b,
    )
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str):
    return _MODULES[arch_id].config()


def get_smoke_config(arch_id: str):
    return _MODULES[arch_id].smoke_config()


def list_archs():
    return list(ARCH_IDS)

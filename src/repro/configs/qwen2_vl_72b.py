"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; 3-section M-RoPE (t/h/w), dynamic-resolution ViT frontend is a
STUB (input_specs supplies patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=29568, vocab=152064,
        mlp="swiglu", tie_embeddings=False,
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        layer_pattern=("attn",),
        notes="LM shape cells drive the text backbone; text tokens use "
        "(t,t,t) M-RoPE positions. Vision patches enter as embeds overrides.",
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

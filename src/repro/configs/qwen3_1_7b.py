"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936; per-head RMS qk-norm, SwiGLU. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "qwen3-1.7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv=8, head_dim=128,
        d_ff=6144, vocab=151936,
        mlp="swiglu", qk_norm=True, tie_embeddings=True,
        layer_pattern=("attn",), rope_theta=1_000_000.0,
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=163840,
        mlp="swiglu", tie_embeddings=True,
        n_experts=64, top_k=6, layer_pattern=("attn_moe",),
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

"""The paper's own 'architecture': CLAIRE-style diffeomorphic registration.

Grid-size configs used by the dry-run and benchmarks: the paper's scaling
study covers 64^3 .. 1024^3 (Tables I/II) plus the 256x300x256 brain pair
(Table IV; padded to 256x304x256 for the 16x16 pencil mesh).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RegConfig:
    name: str
    grid: tuple
    beta: float = 1e-2
    n_t: int = 4
    incompressible: bool = False
    halo: int = 8


GRIDS = {
    "claire-64": RegConfig("claire-64", (64, 64, 64)),
    "claire-128": RegConfig("claire-128", (128, 128, 128)),
    "claire-256": RegConfig("claire-256", (256, 256, 256)),
    "claire-512": RegConfig("claire-512", (512, 512, 512)),
    "claire-1024": RegConfig("claire-1024", (1024, 1024, 1024)),
    "claire-256-inc": RegConfig("claire-256-inc", (256, 256, 256), incompressible=True),
    "claire-brain": RegConfig("claire-brain", (256, 304, 256), beta=1e-4),
}

"""The paper's own 'architecture': CLAIRE-style diffeomorphic registration.

Grid-size configs used by the dry-run and benchmarks: the paper's scaling
study covers 64^3 .. 1024^3 (Tables I/II) plus the 256x300x256 brain pair
(Table IV; padded to 256x304x256 for the 16x16 pencil mesh).

``levels`` configures coarse-to-fine grid continuation (repro.multilevel):
an ordered coarse-to-fine ladder whose last entry equals ``grid``.  Every
ladder entry must satisfy the pencil-mesh divisibility constraints (which
rules out a brain-pair ladder: 304/2 = 152 is not divisible by 16).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RegConfig:
    name: str
    grid: tuple
    beta: float = 1e-2
    n_t: int = 4
    incompressible: bool = False
    halo: int = 8
    levels: tuple | None = None  # coarse->fine ladder; None = single level


def _cubic_ladder(n: int, n_levels: int = 3, floor: int = 64) -> tuple:
    sizes = [n]
    while len(sizes) < n_levels and sizes[-1] // 2 >= floor:
        sizes.append(sizes[-1] // 2)
    return tuple((s, s, s) for s in reversed(sizes))


GRIDS = {
    "claire-64": RegConfig("claire-64", (64, 64, 64)),
    "claire-128": RegConfig("claire-128", (128, 128, 128)),
    "claire-256": RegConfig("claire-256", (256, 256, 256)),
    "claire-512": RegConfig("claire-512", (512, 512, 512)),
    "claire-1024": RegConfig("claire-1024", (1024, 1024, 1024)),
    "claire-256-inc": RegConfig("claire-256-inc", (256, 256, 256), incompressible=True),
    "claire-brain": RegConfig("claire-brain", (256, 304, 256), beta=1e-4),
    # coarse-to-fine ladders (repro.multilevel): 64^3 -> 128^3 -> 256^3 etc.
    "claire-256-ml": RegConfig("claire-256-ml", (256, 256, 256), levels=_cubic_ladder(256)),
    "claire-512-ml": RegConfig("claire-512-ml", (512, 512, 512), levels=_cubic_ladder(512)),
}

"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8, qk-norm.
[hf:Qwen/Qwen3-235B-A22B family; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv=4, head_dim=128,
        d_ff=1536, vocab=151936,
        mlp="swiglu", qk_norm=True, tie_embeddings=False,
        n_experts=128, top_k=8, layer_pattern=("attn_moe",),
        rope_theta=1_000_000.0,
        notes="kv=4 heads cannot split 16-way TP: ShardRules falls back to "
        "replicated kv (logged); cache shards over batch instead.",
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

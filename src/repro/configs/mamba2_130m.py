"""mamba2-130m [ssm] — 24L d_model=768, attention-free, vocab=50280,
ssm_state=128; SSD (state-space duality), headdim 64, expand 2.
[arXiv:2405.21060; unverified]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "mamba2-130m"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv=0, head_dim=0,
        d_ff=0, vocab=50280,
        tie_embeddings=True, layer_pattern=("mamba",),
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

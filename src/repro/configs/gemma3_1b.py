"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1, MQA) d_ff=6912
vocab=262144; 5 local(sliding 512):1 global pattern, qk-norm, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "gemma3-1b"

# every 6th layer is global attention; 26 layers -> 22 local + 4 global
_PATTERN = tuple("global" if (i + 1) % 6 == 0 else "local" for i in range(26))


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv=1, head_dim=256,
        d_ff=6912, vocab=262144,
        mlp="geglu", embed_scale=True, tie_embeddings=True, qk_norm=True,
        sliding_window=512, layer_pattern=_PATTERN, rope_theta=1_000_000.0,
        notes="single rope_theta used for local+global (hf uses 10k local/1M global); "
        "pattern unrolled in one scan group (26 layers, small model).",
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

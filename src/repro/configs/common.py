"""Shared config machinery: assigned input shapes, smoke reduction, specs.

The four assigned LM shape cells (per architecture):
    train_4k     seq 4096,   global batch 256   -> train_step
    prefill_32k  seq 32768,  global batch 32    -> forward (prefill)
    decode_32k   seq 32768,  global batch 128   -> serve_step (1 new token)
    long_500k    seq 524288, global batch 1     -> serve_step; sub-quadratic
                                                   archs only (see DESIGN §5)

``input_specs`` returns ShapeDtypeStruct stand-ins (never allocates) plus
PartitionSpecs for each input — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

# archs whose attention is sub-quadratic (SSM / hybrid / mostly-windowed):
# the only ones that run long_500k (DESIGN.md §5 records the skips).
LONG_CONTEXT_OK = {"mamba2-130m", "zamba2-2.7b", "gemma3-1b"}


def is_cell_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "skipped: pure full attention (quadratic prefill at 512k)"
    return True, ""


def batch_spec(mesh, size: int | None = None) -> P:
    """Batch-dim spec over (pod, data), dropped when ``size`` won't divide."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if size is not None and axes:
        total = 1
        for a in axes:
            total *= int(mesh.shape[a])
        if size % total != 0:
            axes = tuple(a for a in axes if size % int(mesh.shape[a]) == 0)[:1]
        if size == 1:
            axes = ()
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def token_inputs(cfg: ArchConfig, shape: dict, mesh):
    """ShapeDtypeStructs + PartitionSpecs for one shape cell's data inputs."""
    b, s = shape["batch"], shape["seq"]
    bspec = batch_spec(mesh, b)
    sd = jax.ShapeDtypeStruct
    if cfg.family in ("encdec", "audio") and cfg.enc_layers:
        s_enc, s_dec = s // 2, s // 2
        specs = {
            "frames": sd((b, s_enc, cfg.d_model), jnp.bfloat16),
            "tokens": sd((b, s_dec), jnp.int32),
            "labels": sd((b, s_dec), jnp.int32),
        }
        shardings = {
            "frames": P(*bspec, None, None),
            "tokens": P(*bspec, None),
            "labels": P(*bspec, None),
        }
    else:
        specs = {"tokens": sd((b, s), jnp.int32), "labels": sd((b, s), jnp.int32)}
        shardings = {"tokens": P(*bspec, None), "labels": P(*bspec, None)}
    return specs, shardings


def smoke_reduce(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config: CPU-runnable forward/train smoke tests."""
    pattern = cfg.layer_pattern
    if cfg.name.startswith("gemma3"):
        pattern = ("local",) * 2 + ("global",)
    elif cfg.name.startswith("zamba2"):
        pattern = ("shared", "mamba", "mamba")
    n_layers = len(pattern) * 2
    changes = dict(
        n_layers=n_layers,
        layer_pattern=pattern,
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=503,  # deliberately not a multiple of the pad -> exercises padding
        vocab_pad_multiple=64,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        enc_layers=2 if cfg.enc_layers else 0,
        sliding_window=8 if cfg.sliding_window else None,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else None,
        dtype=jnp.float32,
        remat=False,
        name=cfg.name + "-smoke",
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)

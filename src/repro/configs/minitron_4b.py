"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000; pruned nemotron: squared-ReLU MLP, untied embeddings.
[arXiv:2407.14679; hf]"""
from repro.configs.common import smoke_reduce
from repro.models.common import ArchConfig

ARCH_ID = "minitron-4b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv=8, head_dim=128,
        d_ff=9216, vocab=256000,
        mlp="relu2", tie_embeddings=False,
        layer_pattern=("attn",), rope_theta=10_000.0,
    )


def smoke_config() -> ArchConfig:
    return smoke_reduce(config())

"""Coarse-to-fine grid continuation driver.

``multilevel.solve`` restricts the image pair down the ladder, runs the
Gauss-Newton-Krylov solver per level (coarsest first), and prolongs each
level's velocity as the warm start of the next — interleaving the beta-
continuation schedule across levels (coarse levels absorb the large-beta
solves).  Convergence of warm-started levels is measured against the
*cold-start* gradient norm of that level, so the finest level terminates
at exactly the tolerance a single-level solve would — just with most of
the Newton progress already bought at 8-64x cheaper matvecs.

With ``MultilevelConfig(precond=...)`` every warm-started level's PCG is
preconditioned through the coarser part of the ladder — the fixed
two-level scheme or the recursive Galerkin V-cycle of
``repro.multilevel.precond`` — and the coarse matvecs spent inside the
preconditioner are charged into ``precond_fine_equiv_matvecs`` /
``total_fine_equiv_matvecs`` next to the outer counts.

Runs single-device (``SpectralOps`` per level) or on the production mesh:
pass the fine ``DistContext`` and every coarse level derives its own
context on the same mesh (``ctx.coarsen``), with the spectral transfer
re-sharding through the pencil FFTs.  Either way each level's solver gets
a plan-aware interp (``kernels.ops.Interp`` locally, the halo-exchange
interp of the level's context on a mesh), so the per-iteration
``InterpPlan`` weight cache and the batched multi-field transport calls
of ``core.semilag`` are active at every level of the ladder.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import gauss_newton as gn
from repro.core import objective as obj
from repro.core.grid import Grid
from repro.core.spectral import SpectralOps
from repro.multilevel import transfer
from repro.multilevel.hierarchy import GridHierarchy, MultilevelConfig
from repro.multilevel.precond import make_two_level_precond, make_vcycle_precond


def _cold_gradient_norm(rho_R, rho_T, grid, lcfg, ops, interp):
    """|g(v=0)| — beta-independent (the reg term vanishes at v=0)."""
    prob = obj.Problem(
        grid=grid, rho_R=rho_R, rho_T=rho_T, beta=lcfg.beta, n_t=lcfg.n_t,
        incompressible=lcfg.incompressible,
    )
    state = jax.jit(
        lambda v: obj.newton_state(v, prob, ops, interp)
    )(jnp.zeros((3,) + grid.shape, grid.dtype))
    return float(jnp.sqrt(grid.norm_sq(state.g)))


def solve(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    grid: Grid,
    cfg: MultilevelConfig,
    *,
    ops: SpectralOps | None = None,
    ctx=None,
    v0: jnp.ndarray | None = None,
    verbose: bool = False,
    callback=None,
):
    """Coarse-to-fine registration solve; returns the ``gn.solve`` dict plus
    per-level statistics (``levels``, ``fine_matvecs``, ``fine_equiv_matvecs``)."""
    hier = GridHierarchy(grid, cfg)
    n_levels = len(hier)

    if ctx is not None:
        contexts = [
            ctx if g.shape == grid.shape else ctx.coarsen(g.shape) for g in hier.grids
        ]
        level_ops = [c.ops for c in contexts]
        level_interp = [c.interp for c in contexts]
    else:
        fine_ops = ops or SpectralOps(grid)
        level_ops = [
            fine_ops if g.shape == grid.shape else SpectralOps(g) for g in hier.grids
        ]
        level_interp = [None] * n_levels

    fine_ops = level_ops[-1]
    restrict_images = transfer.smooth_restrict if cfg.presmooth else transfer.restrict

    history: list[dict] = []
    levels: list[dict] = []
    v = v0
    for lv in range(n_levels):
        lgrid, lops, linterp = hier.grids[lv], level_ops[lv], level_interp[lv]
        lcfg = hier.level_config(lv)
        if lgrid.shape == grid.shape:
            rho_R_l, rho_T_l = rho_R, rho_T
        else:
            rho_R_l = restrict_images(rho_R, fine_ops, lops)
            rho_T_l = restrict_images(rho_T, fine_ops, lops)

        warm = v is not None
        if warm and lv > 0:
            v = transfer.prolong(v, level_ops[lv - 1], lops)
        elif warm and lgrid.shape != grid.shape:
            v = transfer.restrict(v, fine_ops, lops)  # fine-grid v0 caller input
        g0_ref = (
            _cold_gradient_norm(rho_R_l, rho_T_l, lgrid, lcfg, lops, linterp)
            if warm
            else None
        )

        precond = None
        if cfg.precond_kind != "none" and lv > 0:
            prob_l = obj.Problem(
                grid=lgrid, rho_R=rho_R_l, rho_T=rho_T_l, beta=lcfg.beta,
                n_t=lcfg.n_t, incompressible=lcfg.incompressible,
            )
            if cfg.precond_kind == "two_level":
                precond = make_two_level_precond(
                    prob_l, lops, level_ops[lv - 1],
                    n_cg=cfg.precond_cg_iters,
                    interp_coarse=level_interp[lv - 1],
                    galerkin=cfg.galerkin_resolved,
                )
            else:  # full V-cycle through every coarser ladder level
                precond = make_vcycle_precond(
                    prob_l, level_ops[: lv + 1],
                    level_interp=level_interp[: lv + 1],
                    n_cg=cfg.precond_cg_iters,
                    n_cg_coarse=cfg.precond_coarse_cg_iters,
                    galerkin=cfg.galerkin_resolved,
                    min_size=cfg.precond_min_size,
                )

        def level_cb(it, rec, _lv=lv, _shape=lgrid.shape):
            rec["level"] = _lv
            rec["shape"] = list(_shape)
            if callback:
                callback(it, rec)

        telemetry.emit(
            telemetry.LevelStartEvent(
                level=lv,
                n_levels=n_levels,
                shape=list(lgrid.shape),
                betas=[float(b) for b in hier.betas[lv]],
                warm_start=warm,
            ),
            echo=verbose,
        )
        t0 = time.time()
        with telemetry.span("multilevel.level", level=lv, shape=list(lgrid.shape)) as sp:
            out = gn.solve(
                rho_R_l, rho_T_l, lgrid, lcfg,
                ops=lops, v0=v, verbose=verbose, callback=level_cb, interp=linterp,
                precond=precond, g0_ref=g0_ref,
            )
            sp.sync(out["v"])
        wall = time.time() - t0
        v = out["v"]
        history.extend(out["history"])
        # preconditioner-internal coarse matvecs, charged in LADDER-fine units
        # (gn.solve reports them relative to the level's own grid)
        pc_fe = out.get("precond_fine_equiv_matvecs", 0.0) * hier.fine_equiv_weight(lv)
        level_rec = {
            "level": lv,
            "shape": list(lgrid.shape),
            "betas": [float(b) for b in hier.betas[lv]],
            "warm_start": warm,
            "newton_iters": out["newton_iters"],
            "hessian_matvecs": out["hessian_matvecs"],
            "fine_equiv_matvecs": out["hessian_matvecs"] * hier.fine_equiv_weight(lv),
            "precond_fine_equiv_matvecs": pc_fe,
            "wall_s": wall,
            "rel_gnorm": out["history"][-1]["rel_gnorm"] if out["history"] else None,
        }
        levels.append(level_rec)
        telemetry.emit(telemetry.LevelEvent(**level_rec))

    fine_equiv = sum(l["fine_equiv_matvecs"] for l in levels)
    precond_fe = sum(l["precond_fine_equiv_matvecs"] for l in levels)
    telemetry.emit(
        telemetry.SolveEvent(
            source="multilevel.solve",
            newton_iters=sum(l["newton_iters"] for l in levels),
            hessian_matvecs=sum(l["hessian_matvecs"] for l in levels),
            fine_equiv_matvecs=fine_equiv,
            precond_fine_equiv_matvecs=precond_fe,
            wall_s=sum(l["wall_s"] for l in levels),
        )
    )
    return {
        "v": v,
        "history": history,
        "newton_iters": sum(l["newton_iters"] for l in levels),
        "hessian_matvecs": sum(l["hessian_matvecs"] for l in levels),
        "fine_matvecs": levels[-1]["hessian_matvecs"],
        "fine_equiv_matvecs": fine_equiv,
        "precond_fine_equiv_matvecs": precond_fe,
        "total_fine_equiv_matvecs": fine_equiv + precond_fe,
        "levels": levels,
        "grids": [list(g.shape) for g in hier.grids],
    }

"""Spectral restriction/prolongation between periodic grids.

On the paper's spectral discretization, grid transfer is *exact* Fourier
mode selection: restriction truncates the fine spectrum to the coarse
grid's modes, prolongation zero-pads the coarse spectrum into the fine
layout.  With the grids' cell-volume-weighted inner products the two are
exact adjoints of each other, and ``restrict(prolong(g)) == g`` for every
coarse field with zero Nyquist content (both operators symmetrically drop
the coarse Nyquist plane, whose fine counterpart ±M/2 is ambiguous).

The operators are generic over the ``SpectralOps`` FFT backend: with two
``LocalFFT`` backends they are rfft truncation on one device; with two
``PencilFFT`` backends (``DistContext.ops``) the truncation happens on the
k-space pencils right after the forward transform and the coarse inverse
transform re-shards onto the coarse context's mesh layout — no gather of
the fine field ever materializes.

Because a coarse mode set is two *contiguous* runs per axis (positive
head, negative tail — see ``spectral.mode_indices``), both directions are
expressed as slices + concatenation rather than gather/scatter.  That is
not just cosmetic: GSPMD lowers the slice/concat zero-pad to the sharded
all-to-all re-distribution on every mesh layout, where the old
``.at[idx].set`` scatter all-gathered the whole coarse spectrum per chip
on folded multi-pod pencil axes (74 MB/chip at 256^3 on 2x16x16 —
EXPERIMENTS §Dry-run; pinned by ``tests/test_coalesce.py``).  The padded
result additionally carries the backend's k-space sharding hint
(``PencilFFT.constrain_k``) so the propagation pass cannot fall back to
replication.

The spectrum-level halves (``restrict_spec`` / ``pad_spec``) are exposed
for callers that already hold a spectrum: the V-cycle preconditioner
(``multilevel/precond.py``) splits a residual into coarse + high-mode
parts and reassembles the correction with ONE fine forward and ONE fine
inverse per application instead of four.

Normalization: ``restrict`` samples the band-limited interpolant on the
coarse grid (exact on resolved modes), ``prolong`` is exact band-limited
interpolation (a grid function round-trips bit-for-bit through
``restrict(prolong(.))``).  Leading batch axes (vector components, time
series) pass straight through both backends.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.spectral import LocalFFT, SpectralOps, nyquist_mask


def _layout(ops: SpectralOps) -> bool:
    """True when the backend stores an rfft (half-spectrum) last axis."""
    return isinstance(ops.fft, LocalFFT)


def _check_pair(fine_ops: SpectralOps, coarse_ops: SpectralOps) -> bool:
    if _layout(fine_ops) != _layout(coarse_ops):
        raise ValueError(
            "transfer requires matching spectrum layouts (both LocalFFT or both "
            f"pencil backends); got {type(fine_ops.fft).__name__} -> "
            f"{type(coarse_ops.fft).__name__}"
        )
    return _layout(fine_ops)


def _mask(fine_ops: SpectralOps, coarse_ops: SpectralOps, rfft: bool) -> jnp.ndarray:
    fine, coarse = fine_ops.grid.shape, coarse_ops.grid.shape
    m1, m2, m3 = (nyquist_mask(fine[a], coarse[a], rfft=(rfft and a == 2)) for a in range(3))
    return jnp.asarray(m1[:, None, None] * m2[None, :, None] * m3[None, None, :])


def _head_tail(n_fine: int, n_coarse: int, rfft: bool) -> tuple[int, int]:
    """Lengths of the two contiguous mode runs of a coarse axis inside a
    fine axis (positive head, negative tail; tail = 0 for rfft axes)."""
    if rfft:
        return n_coarse // 2 + 1, 0
    return n_coarse - n_coarse // 2, n_coarse // 2


def _zero_pad(x, axis: int, lo: int, hi: int):
    """lax.pad with zeros on one axis — the one spectrum-surgery primitive
    the SPMD partitioner handles shard-locally (a concatenate or scatter
    along a sharded dimension makes GSPMD replicate the operand first:
    the all-gather/all-reduce pathologies this module exists to avoid)."""
    cfg = [(0, 0, 0)] * x.ndim
    cfg[axis % x.ndim] = (lo, hi, 0)
    return lax.pad(x, jnp.zeros((), x.dtype), cfg)


def _truncate_axis(spec, axis: int, n_fine: int, n_coarse: int, rfft: bool):
    if n_coarse == n_fine:
        return spec
    n_pos, n_neg = _head_tail(n_fine, n_coarse, rfft)
    head = lax.slice_in_dim(spec, 0, n_pos, axis=axis)
    if n_neg == 0:
        return head
    tail = lax.slice_in_dim(spec, n_fine - n_neg, n_fine, axis=axis)
    # [head | tail] via two shard-local zero-pads + add (no concat)
    return _zero_pad(head, axis, 0, n_neg) + _zero_pad(tail, axis, n_pos, 0)


def _pad_axis(spec, axis: int, n_fine: int, n_coarse: int, rfft: bool):
    if n_coarse == n_fine:
        return spec
    n_pos, n_neg = _head_tail(n_fine, n_coarse, rfft)
    size_f = n_fine // 2 + 1 if rfft else n_fine
    head = lax.slice_in_dim(spec, 0, n_pos, axis=axis)
    out = _zero_pad(head, axis, 0, size_f - n_pos)
    if n_neg:
        tail = lax.slice_in_dim(spec, n_pos, n_pos + n_neg, axis=axis)
        out = out + _zero_pad(tail, axis, size_f - n_neg, 0)
    return out


def restrict_spec(
    spec: jnp.ndarray, fine_ops: SpectralOps, coarse_ops: SpectralOps
) -> jnp.ndarray:
    """Truncate a fine-layout spectrum to the coarse layout (mask + the
    restriction normalization applied): ``restrict = coarse.inv o this o
    fine.fwd``."""
    rfft = _check_pair(fine_ops, coarse_ops)
    fine, coarse = fine_ops.grid.shape, coarse_ops.grid.shape
    constrain = getattr(coarse_ops.fft, "constrain_k", lambda s: s)
    for a, axis in enumerate((-3, -2, -1)):
        # re-pin the pencil sharding after every axis (each intermediate
        # keeps both sharded k axes divisible, so the hint is always valid;
        # without it GSPMD's cost model may replicate small spectra)
        spec = constrain(_truncate_axis(spec, axis, fine[a], coarse[a], rfft and a == 2))
    scale = coarse_ops.grid.num_points / fine_ops.grid.num_points
    return spec * (_mask(fine_ops, coarse_ops, rfft) * scale)


def pad_spec(
    spec: jnp.ndarray, coarse_ops: SpectralOps, fine_ops: SpectralOps
) -> jnp.ndarray:
    """Zero-pad a coarse-layout spectrum into the fine layout (mask + the
    prolongation normalization applied): ``prolong = fine.inv o this o
    coarse.fwd``.  Slices + ``lax.pad`` + add only (sharded-friendly; see
    module docstring), with the fine backend's k-space sharding hint
    re-applied after every axis."""
    rfft = _check_pair(fine_ops, coarse_ops)
    fine, coarse = fine_ops.grid.shape, coarse_ops.grid.shape
    scale = fine_ops.grid.num_points / coarse_ops.grid.num_points
    spec = spec * (_mask(fine_ops, coarse_ops, rfft) * scale)
    constrain = getattr(fine_ops.fft, "constrain_k", lambda s: s)
    for a, axis in enumerate((-3, -2, -1)):
        spec = constrain(_pad_axis(spec, axis, fine[a], coarse[a], rfft and a == 2))
    return spec


def restrict(f: jnp.ndarray, fine_ops: SpectralOps, coarse_ops: SpectralOps) -> jnp.ndarray:
    """Sample ``f``'s band-limited interpolant on the coarse grid.

    ``f``: (..., N1, N2, N3) on ``fine_ops.grid``; returns (..., M1, M2, M3).
    """
    return coarse_ops.fft.inv(restrict_spec(fine_ops.fft.fwd(f), fine_ops, coarse_ops))


def prolong(g: jnp.ndarray, coarse_ops: SpectralOps, fine_ops: SpectralOps) -> jnp.ndarray:
    """Band-limited interpolation of a coarse field onto the fine grid.

    ``g``: (..., M1, M2, M3) on ``coarse_ops.grid``; returns (..., N1, N2, N3).
    """
    return fine_ops.fft.inv(pad_spec(coarse_ops.fft.fwd(g), coarse_ops, fine_ops))


def smooth_restrict(
    f: jnp.ndarray, fine_ops: SpectralOps, coarse_ops: SpectralOps
) -> jnp.ndarray:
    """Gaussian pre-smoothing at the coarse grid's bandwidth, then restrict.

    The sharp cutoff alone is alias-free on a spectral grid but rings on
    images with near-Nyquist content; smoothing at one *coarse* cell width
    (the same filter ``register()`` applies at the fine bandwidth) is
    CLAIRE's coarse-image construction.  One fine ride pair: the Gaussian
    multiplier rides the restriction's own forward transform.
    """
    _check_pair(fine_ops, coarse_ops)
    spec = fine_ops.fft.fwd(f) * fine_ops._smooth_scale(coarse_ops.grid.spacing)
    return coarse_ops.fft.inv(restrict_spec(spec, fine_ops, coarse_ops))

"""Spectral restriction/prolongation between periodic grids.

On the paper's spectral discretization, grid transfer is *exact* Fourier
mode selection: restriction truncates the fine spectrum to the coarse
grid's modes, prolongation zero-pads the coarse spectrum into the fine
layout.  With the grids' cell-volume-weighted inner products the two are
exact adjoints of each other, and ``restrict(prolong(g)) == g`` for every
coarse field with zero Nyquist content (both operators symmetrically drop
the coarse Nyquist plane, whose fine counterpart ±M/2 is ambiguous).

The operators are generic over the ``SpectralOps`` FFT backend: with two
``LocalFFT`` backends they are rfft truncation on one device; with two
``PencilFFT`` backends (``DistContext.ops``) the truncation happens on the
k-space pencils right after the forward transform and the coarse inverse
transform re-shards onto the coarse context's mesh layout — no gather of
the fine field ever materializes.

Normalization: ``restrict`` samples the band-limited interpolant on the
coarse grid (exact on resolved modes), ``prolong`` is exact band-limited
interpolation (a grid function round-trips bit-for-bit through
``restrict(prolong(.))``).  Leading batch axes (vector components, time
series) pass straight through both backends.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.spectral import LocalFFT, SpectralOps, mode_indices, nyquist_mask


def _layout(ops: SpectralOps) -> bool:
    """True when the backend stores an rfft (half-spectrum) last axis."""
    return isinstance(ops.fft, LocalFFT)


def _plan(fine_ops: SpectralOps, coarse_ops: SpectralOps):
    """Static per-axis index arrays + combined Nyquist mask (numpy)."""
    fine, coarse = fine_ops.grid.shape, coarse_ops.grid.shape
    if _layout(fine_ops) != _layout(coarse_ops):
        raise ValueError(
            "transfer requires matching spectrum layouts (both LocalFFT or both "
            f"pencil backends); got {type(fine_ops.fft).__name__} -> "
            f"{type(coarse_ops.fft).__name__}"
        )
    rfft = _layout(fine_ops)
    idx = [mode_indices(fine[a], coarse[a], rfft=(rfft and a == 2)) for a in range(3)]
    m1, m2, m3 = (nyquist_mask(fine[a], coarse[a], rfft=(rfft and a == 2)) for a in range(3))
    mask = m1[:, None, None] * m2[None, :, None] * m3[None, None, :]
    return idx, jnp.asarray(mask)


def restrict(f: jnp.ndarray, fine_ops: SpectralOps, coarse_ops: SpectralOps) -> jnp.ndarray:
    """Sample ``f``'s band-limited interpolant on the coarse grid.

    ``f``: (..., N1, N2, N3) on ``fine_ops.grid``; returns (..., M1, M2, M3).
    """
    idx, mask = _plan(fine_ops, coarse_ops)
    spec = fine_ops.fft.fwd(f)
    spec = jnp.take(spec, idx[0], axis=-3)
    spec = jnp.take(spec, idx[1], axis=-2)
    spec = jnp.take(spec, idx[2], axis=-1)
    scale = coarse_ops.grid.num_points / fine_ops.grid.num_points
    return coarse_ops.fft.inv(spec * (mask * scale))


def prolong(g: jnp.ndarray, coarse_ops: SpectralOps, fine_ops: SpectralOps) -> jnp.ndarray:
    """Band-limited interpolation of a coarse field onto the fine grid.

    ``g``: (..., M1, M2, M3) on ``coarse_ops.grid``; returns (..., N1, N2, N3).
    """
    idx, mask = _plan(fine_ops, coarse_ops)
    spec = coarse_ops.fft.fwd(g)
    scale = fine_ops.grid.num_points / coarse_ops.grid.num_points
    spec = spec * (mask * scale)
    kshape = _kspace_shape(fine_ops)
    fine_spec = jnp.zeros(spec.shape[:-3] + kshape, spec.dtype)
    fine_spec = fine_spec.at[
        ..., idx[0][:, None, None], idx[1][None, :, None], idx[2][None, None, :]
    ].set(spec)
    return fine_ops.fft.inv(fine_spec)


def _kspace_shape(ops: SpectralOps) -> tuple[int, int, int]:
    n1, n2, n3 = ops.grid.shape
    return (n1, n2, n3 // 2 + 1) if _layout(ops) else (n1, n2, n3)


def smooth_restrict(
    f: jnp.ndarray, fine_ops: SpectralOps, coarse_ops: SpectralOps
) -> jnp.ndarray:
    """Gaussian pre-smoothing at the coarse grid's bandwidth, then restrict.

    The sharp cutoff alone is alias-free on a spectral grid but rings on
    images with near-Nyquist content; smoothing at one *coarse* cell width
    (the same filter ``register()`` applies at the fine bandwidth) is
    CLAIRE's coarse-image construction.
    """
    return restrict(fine_ops.smooth(f, sigma=coarse_ops.grid.spacing), fine_ops, coarse_ops)

"""The level ladder: GridHierarchy + MultilevelConfig.

A hierarchy is an ordered coarse-to-fine tuple of ``Grid``s whose finest
entry is the problem grid (e.g. 64^3 -> 128^3 -> 256^3).  Each level gets
its own ``SpectralOps`` (or, distributed, its own ``DistContext`` derived
from the fine one on the same mesh) and a ``GNConfig`` assembled from the
base solver config plus per-level overrides; the beta-continuation
schedule is spread across the ladder so coarse levels absorb the large-
beta warm-up solves and the finest level runs the target beta.
"""
from __future__ import annotations

import dataclasses

from repro.core import gauss_newton as gn
from repro.core.grid import Grid, make_grid


@dataclasses.dataclass(frozen=True)
class MultilevelConfig:
    """Coarse-to-fine continuation settings (wraps a base ``GNConfig``)."""

    solver: gn.GNConfig = dataclasses.field(default_factory=gn.GNConfig)
    n_levels: int = 2  # used when shapes is None: halve per level
    min_size: int = 8  # don't auto-coarsen below this many points per axis
    shapes: tuple | None = None  # explicit coarse->fine ladder; last == fine grid
    presmooth: bool = True  # Gaussian at each level's bandwidth before restriction
    level_overrides: tuple = ()  # coarse->fine dicts of GNConfig field replacements
    # -- multigrid preconditioner (repro.multilevel.precond) ----------------
    # "none" | "two_level" (fixed one-coarse-level scheme, PR 2) | "vcycle"
    # (recursive cycle over every coarser ladder level, Galerkin-consistent
    # coarse Hessians).  Applied at every warm-started level, not just the
    # finest: level l is preconditioned through levels 0..l-1.
    precond: str = "none"
    two_level_precond: bool = False  # back-compat alias for precond="two_level"
    precond_cg_iters: int = 4  # inner CG iterations per intermediate level
    precond_coarse_cg_iters: int = 10  # (near-)exact coarsest-level CG solve
    precond_min_size: int = 8  # V-cycle recursion floor (points per axis)
    # None resolves per scheme: "vcycle" restricts the Hessian's state fields
    # (Galerkin), "two_level" keeps the PR-2 re-linearized coarse images.
    galerkin_coarse: bool | None = None

    def __post_init__(self):
        if self.precond not in ("none", "two_level", "vcycle"):
            raise ValueError(
                f"unknown precond {self.precond!r}: choose 'none', 'two_level', "
                "or 'vcycle'"
            )

    @property
    def precond_kind(self) -> str:
        if self.precond == "none" and self.two_level_precond:
            return "two_level"
        return self.precond

    @property
    def galerkin_resolved(self) -> bool:
        if self.galerkin_coarse is None:
            return self.precond_kind == "vcycle"
        return self.galerkin_coarse


def _halved(shape: tuple[int, int, int], levels: int, min_size: int):
    ladder = [tuple(shape)]
    for _ in range(levels - 1):
        cand = tuple(n // 2 for n in ladder[-1])
        if min(cand) < min_size or any(n % 2 for n in ladder[-1]):
            break
        ladder.append(cand)
    return tuple(reversed(ladder))


class GridHierarchy:
    """Ordered coarse-to-fine grids with per-level solver configs."""

    def __init__(self, fine_grid: Grid, cfg: MultilevelConfig):
        if cfg.shapes is not None:
            shapes = tuple(tuple(int(x) for x in s) for s in cfg.shapes)
            if shapes[-1] != fine_grid.shape:
                raise ValueError(f"finest ladder entry {shapes[-1]} != grid {fine_grid.shape}")
        else:
            shapes = _halved(fine_grid.shape, cfg.n_levels, cfg.min_size)
        for lo, hi in zip(shapes, shapes[1:]):
            if any(a > b for a, b in zip(lo, hi)):
                raise ValueError(f"ladder not coarse-to-fine: {lo} -> {hi}")
        self.cfg = cfg
        self.grids = tuple(
            fine_grid if s == fine_grid.shape else make_grid(s, fine_grid.dtype)
            for s in shapes
        )
        self.betas = split_beta_schedule(
            tuple(cfg.solver.beta_continuation) + (cfg.solver.beta,), len(self.grids)
        )

    def __len__(self) -> int:
        return len(self.grids)

    @property
    def fine(self) -> Grid:
        return self.grids[-1]

    def level_config(self, level: int) -> gn.GNConfig:
        """Base GNConfig + this level's beta chunk + explicit overrides."""
        chunk = self.betas[level]
        cfg = dataclasses.replace(
            self.cfg.solver, beta=chunk[-1], beta_continuation=tuple(chunk[:-1])
        )
        overrides = (
            self.cfg.level_overrides[level] if level < len(self.cfg.level_overrides) else None
        )
        return dataclasses.replace(cfg, **overrides) if overrides else cfg

    def fine_equiv_weight(self, level: int) -> float:
        """Cost of this level's Hessian matvec in fine-grid-matvec units."""
        return self.grids[level].num_points / self.fine.num_points


def split_beta_schedule(schedule: tuple[float, ...], n_levels: int) -> tuple[tuple[float, ...], ...]:
    """Spread a beta-continuation schedule over the level ladder.

    Contiguous chunks, coarse levels first; when the schedule is shorter
    than the ladder, coarse levels repeat the leading (largest) beta so
    every level still runs a solve.  The finest level always ends on the
    target beta (the schedule's last entry).
    """
    schedule = tuple(float(b) for b in schedule)
    if n_levels <= 1:
        return (schedule,)
    if len(schedule) < n_levels:
        schedule = (schedule[0],) * (n_levels - len(schedule)) + schedule
    base, extra = divmod(len(schedule), n_levels)
    chunks, pos = [], 0
    for lv in range(n_levels):
        size = base + (1 if lv >= n_levels - extra else 0)
        chunks.append(schedule[pos : pos + size])
        pos += size
    return tuple(chunks)

"""Multigrid preconditioners for the Gauss-Newton PCG: recursive V-cycle
(Galerkin-consistent coarse operators) and the legacy two-level scheme.

The paper's ``(beta Lap^2)^{-1}`` preconditioner is mesh- but not
beta-independent (Table V): as beta shrinks, the data term dominates the
low-frequency block of the Hessian and CG iteration counts grow.  The
classic multilevel fix (CLAIRE, 1808.04487 §3; inexact Newton-Krylov,
1408.6299) solves that block on coarser grids where matvecs are 8-64x
cheaper.  Every level applies the same *exact spectral splitting*

    M_l^{-1} r  =  P_l (coarse solve on R_l r)  +  (beta Lap^2)^{-1} r_high,
    r_high      =  r - P_l R_l r,

where ``restrict``/``prolong`` are sharp Fourier projections, so the two
halves act on L2-orthogonal subspaces: the coarse solve captures the
data-dominated low modes, the spectral inverse is near-exact on the
regularization-dominated high modes — and costs ZERO matvecs at the level
being preconditioned.

**V-cycle** (``make_vcycle_precond``): the coarse block is solved by a few
CG iterations on ``H_{l-1}``, themselves preconditioned by the *same
splitting one level down* — the recursion visits every level of the
``GridHierarchy`` once per application (coarsest level last, solved
(near-)exactly by ``n_cg_coarse`` spectral-preconditioned CG iterations).
This is the Krylov-smoothed V-cycle (a K-cycle in the multigrid
literature): the per-level CG sweeps are the smoother, the spectral
high-mode inverse handles what smoothing cannot, and the cycle's
contraction factor is grid-independent because the coarse operators are
Galerkin-consistent (below).

**Galerkin-consistent coarse operators** (``restrict_state``): the GN
Hessian closes over per-Newton-iteration state — ``grad rho(t_k)``, the
SL plan's departure displacement fields, ``div v``.  Re-linearizing from
re-restricted *images* (the PR-2 two-level construction, kept as
``galerkin=False``) re-runs forward+adjoint transports at every level and
yields a coarse operator that only *approximates* the restriction of the
fine one.  Restricting the state fields themselves makes the coarse
Hessian (to interpolation-discretization error) the actual Galerkin
product ``R H P``: no coarse transport solves at all, and the coarse
correction stays aligned with the fine operator as the grid is refined —
the property that makes the cycle's iteration count level-independent
(pinned by ``tests/test_multilevel.py::test_vcycle_grid_independence``).

Cost accounting: all coarse-level matvecs run inside the preconditioner,
invisible to the outer PCG counter.  Each factory therefore exposes
``fine_equiv_cost`` — the *fine-grid-equivalent* matvec cost of one
application, computed statically from the ladder's point-count ratios and
the fixed inner iteration counts — which ``gn.solve`` multiplies by the
number of applications into ``precond_fine_equiv_matvecs`` (the honest
column of ``BENCH_multilevel.json`` / EXPERIMENTS §Multilevel).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import telemetry
from repro.core import gauss_newton as gn
from repro.core import objective as obj
from repro.core.planner import SLPlan
from repro.core.spectral import SpectralOps
from repro.kernels import ref
from repro.multilevel import transfer


def restrict_state(
    state: obj.NewtonState,
    prob: obj.Problem,
    fine_ops: SpectralOps,
    coarse_ops: SpectralOps,
    interp_coarse=None,
):
    """Galerkin-consistent coarse ``(NewtonState, Problem)`` pair.

    Restricts exactly the fields ``obj.gn_hessian_matvec`` closes over:
    the cached spectral gradients ``grad rho(t_k)`` (one batched spectral
    truncation over all time slices), the SL plan's departure displacement
    fields (rescaled into coarse grid units; the ``InterpPlan`` operators
    are rebuilt elementwise from the restricted displacements), and
    ``div v`` for the compressible source terms.  No transport solves and
    no image re-differentiation happen at the coarse level — the coarse
    Hessian *is* the restriction of the fine one, up to the coarse grid's
    interpolation discretization error.

    Fields the GN matvec never reads (``rho_series``, ``lam_series``, the
    gradient/objective diagnostics, the images) are left ``None``.
    Composable: restricting an already-restricted state walks the Galerkin
    ladder down exactly (spectral truncations compose).
    """
    fine, coarse = fine_ops.grid.shape, coarse_ops.grid.shape

    def R(f):
        return transfer.restrict(f, fine_ops, coarse_ops)

    # displacements are stored in grid-index units: physical displacement is
    # disp * h, and h doubles per coarsening, so grid-unit values scale by
    # the per-axis point ratio under restriction.
    ratio = jnp.asarray(
        [c / f for c, f in zip(coarse, fine)], dtype=state.plan.disp_fwd.dtype
    ).reshape(3, 1, 1, 1)
    disp_fwd = R(state.plan.disp_fwd) * ratio
    disp_adj = None if state.plan.disp_adj is None else R(state.plan.disp_adj) * ratio
    divv = None if state.plan.divv is None else R(state.plan.divv)
    planner = (
        ref.make_interp_plan
        if interp_coarse is None
        else getattr(interp_coarse, "make_plan", None)
    )
    plan_c = SLPlan(
        disp_fwd=disp_fwd,
        disp_adj=disp_adj,
        divv=divv,
        dt=state.plan.dt,
        n_t=state.plan.n_t,
        iplan_fwd=planner(disp_fwd) if planner is not None else None,
        iplan_adj=planner(disp_adj) if planner is not None and disp_adj is not None else None,
    )
    state_c = obj.NewtonState(
        v=None,
        plan=plan_c,
        rho_series=None,
        grad_rho_series=R(state.grad_rho_series),
        lam_series=None,
        g=None,
        misfit=None,
        reg=None,
        j_val=None,
    )
    prob_c = obj.Problem(
        grid=coarse_ops.grid,
        rho_R=None,  # never read by the Hessian matvec
        rho_T=None,
        beta=prob.beta,
        n_t=prob.n_t,
        incompressible=prob.incompressible,
    )
    return state_c, prob_c


def _precond_fine_equiv_cost(level_ops, n_cg: int, n_cg_coarse: int) -> float:
    """Static fine-equivalent matvec cost of ONE preconditioner application.

    An application at level ``l`` runs ``iters`` inner CG iterations on
    ``H_{l-1}`` (``iters`` level-(l-1) matvecs, charged at the level's
    point-count ratio) with ``iters + 1`` applications of the level-(l-1)
    preconditioner (the spectral inverse — free in matvec units — at the
    coarsest level, the recursion otherwise).
    """
    n_fine = level_ops[-1].grid.num_points
    w = [ops.grid.num_points / n_fine for ops in level_ops]

    def apply_cost(l: int) -> float:
        iters = n_cg_coarse if l - 1 == 0 else n_cg
        below = 0.0 if l - 1 == 0 else apply_cost(l - 1)
        return iters * w[l - 1] + (iters + 1) * below

    return apply_cost(len(level_ops) - 1)


def make_vcycle_precond(
    prob: obj.Problem,
    level_ops,
    *,
    level_interp=None,
    n_cg: int = 4,
    n_cg_coarse: int = 10,
    galerkin: bool = True,
    min_size: int = 8,
):
    """Build the V-cycle ``precond`` factory for ``gn.newton_iteration``.

    ``level_ops`` is the coarse-to-fine ``SpectralOps`` ladder whose LAST
    entry is the level being preconditioned (>= 2 entries; exactly 2 gives
    the two-level scheme).  ``level_interp`` supplies the matching interp
    callables (``None`` entries use the local oracle).  ``prob`` is the
    fine-level problem: with ``galerkin=True`` only its scalars
    (beta/n_t/incompressible) matter — the coarse operators come from
    restricting the runtime ``NewtonState``; with ``galerkin=False`` its
    images are smooth-restricted once per ladder level here and every
    coarse Hessian is re-linearized from the restricted velocity per Newton
    iteration (the PR-2 construction, kept for A/B benchmarking).

    ``min_size`` floors the recursion: ladder levels with fewer grid points
    per axis are dropped from the cycle (a 4^3 "Hessian" is all pseudo-
    spectral aliasing — its correction misdirects the level above; on the
    production 64^3->256^3 ladders the floor never binds).  At least the
    immediate coarse level is always kept.

    The returned factory carries ``fine_equiv_cost`` (see module
    docstring); ``gn.solve`` reads it for the honest matvec accounting.
    """
    level_ops = list(level_ops)
    if len(level_ops) < 2:
        raise ValueError("V-cycle needs at least 2 levels (coarse + fine)")
    level_interp = list(level_interp) if level_interp is not None else [None] * len(level_ops)
    # recursion floor: drop unresolvable leading (coarsest) levels
    keep = [
        i for i, ops in enumerate(level_ops)
        if min(ops.grid.shape) >= min_size or i >= len(level_ops) - 2
    ]
    level_ops = [level_ops[i] for i in keep]
    level_interp = [level_interp[i] for i in keep]
    n_levels = len(level_ops)
    fine_ops = level_ops[-1]

    images = None
    if not galerkin:
        # legacy path: smooth-restrict the images once, down the ladder
        images, rR, rT = [], prob.rho_R, prob.rho_T
        for lo, hi in zip(reversed(level_ops[:-1]), reversed(level_ops[1:])):
            rR = transfer.smooth_restrict(rR, hi, lo)
            rT = transfer.smooth_restrict(rT, hi, lo)
            images.append((rR, rT))
        images = list(reversed(images))  # coarse -> fine-1

    def factory(state: obj.NewtonState, prob_rt: obj.Problem):
        # ---- per-Newton-iteration coarse operator ladder (fine -> coarse)
        states: list = [None] * n_levels
        probs: list = [None] * n_levels
        states[-1], probs[-1] = state, prob_rt
        for l in range(n_levels - 2, -1, -1):
            if galerkin:
                states[l], probs[l] = restrict_state(
                    states[l + 1], probs[l + 1], level_ops[l + 1], level_ops[l],
                    level_interp[l],
                )
            else:
                rR, rT = images[l]
                probs[l] = obj.Problem(
                    grid=level_ops[l].grid, rho_R=rR, rho_T=rT, beta=prob_rt.beta,
                    n_t=prob_rt.n_t, incompressible=prob_rt.incompressible,
                )
                v_c = transfer.restrict(states[l + 1].v, level_ops[l + 1], level_ops[l])
                states[l] = obj.newton_state(v_c, probs[l], level_ops[l], level_interp[l])

        def matvec(l):
            return lambda p: obj.gn_hessian_matvec(
                p, states[l], probs[l], level_ops[l], level_interp[l]
            )

        def spectral(l):
            ops = level_ops[l]

            def apply(r):
                # one coalesced ride pair: P (beta Lap^2)^{-1}
                return ops.precond_project(r, prob_rt.beta, prob_rt.incompressible)

            return apply

        def apply_at(l):
            """M_l^{-1}: exact spectral split + recursive coarse-block solve.

            The split and the correction assembly work on *spectra*
            (``transfer.restrict_spec`` / ``pad_spec``): one fine forward of
            ``r``, one coarse inverse for the coarse residual, one coarse
            forward of the coarse solution, one fine inverse of the combined
            correction — with the Leray projection and the high-mode
            spectral inverse applied as k-space multipliers in between.
            That is 2 fine + 2 coarse transform rides per application where
            the field-level composition (restrict, prolong, precond_apply,
            leray as separate round trips) cost 7 fine + 4 coarse — at every
            level of the recursion.
            """
            ops_f, ops_c = level_ops[l], level_ops[l - 1]
            inner_pc = spectral(0) if l - 1 == 0 else apply_at(l - 1)
            iters = n_cg_coarse if l - 1 == 0 else n_cg
            mv_c = matvec(l - 1)

            @telemetry.annotate(f"precond.vcycle_l{l}")
            def apply(r):
                spec = ops_f.fwd_real(r)  # (3, fine-k): the ONE fine forward
                spec_c = transfer.restrict_spec(spec, ops_f, ops_c)
                # exact spectral split BEFORE any projection of the coarse
                # half: low = P R r in the fine layout, r_high = r - low
                spec_high = spec - transfer.pad_spec(spec_c, ops_c, ops_f)
                if prob_rt.incompressible:
                    spec_c = ops_c._leray_spec(spec_c)
                r_c = ops_c.inv_real(spec_c)
                sol = gn.pcg(mv_c, r_c, inner_pc, ops_c.grid.inner, 0.0, iters)
                # correction: prolonged coarse solve + spectral inverse on
                # the high-mode complement (+ Leray), combined in k-space
                zspec = transfer.pad_spec(ops_c.fwd_real(sol.x), ops_c, ops_f)
                zspec = zspec + ops_f._precond_scale(prob_rt.beta) * spec_high
                if prob_rt.incompressible:
                    zspec = ops_f._leray_spec(zspec)
                return ops_f.inv_real(zspec)  # the ONE fine inverse

            return apply

        return apply_at(n_levels - 1)

    factory.fine_equiv_cost = _precond_fine_equiv_cost(level_ops, n_cg, n_cg_coarse)
    factory.n_levels = n_levels
    return factory


def make_two_level_precond(
    prob: obj.Problem,
    fine_ops: SpectralOps,
    coarse_ops: SpectralOps,
    *,
    n_cg: int = 4,
    interp_coarse=None,
    galerkin: bool = False,
):
    """The fixed two-level scheme (PR 2) as a V-cycle special case.

    Kept as the A/B baseline of the benchmark sweep: one coarse level,
    ``n_cg`` inner CG iterations, and (by default) the legacy
    re-linearized coarse Hessian — restricted images re-transported at the
    coarse level per Newton iteration — rather than the Galerkin-restricted
    state fields (``galerkin=True`` upgrades just that part).
    """
    return make_vcycle_precond(
        prob,
        [coarse_ops, fine_ops],
        level_interp=[interp_coarse, None],
        n_cg=n_cg,
        n_cg_coarse=n_cg,
        galerkin=galerkin,
    )

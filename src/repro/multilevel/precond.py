"""Two-level PCG preconditioner: coarse-grid Hessian solve + spectral smoother.

The paper's ``(beta Lap^2)^{-1}`` preconditioner is mesh- but not
beta-independent (Table V): as beta shrinks, the data term dominates the
low-frequency block of the Hessian and CG iteration counts grow.  The
classic two-level fix (CLAIRE, 1808.04487 §3) solves that block on a
coarse grid where matvecs are 8-64x cheaper:

    M^{-1} r  =  P H_c^{-1} R r_low  +  (beta Lap^2)^{-1} r_high

Because ``restrict``/``prolong`` are sharp spectral projections, the
splitting ``r = r_low + r_high`` with ``r_low = P R r`` is exact and the
two halves act on L2-orthogonal subspaces: the coarse solve captures the
data-dominated low modes, the spectral smoother is near-exact on the
regularization-dominated high modes.  ``H_c`` is the Gauss-Newton Hessian
of the *restricted* problem at the *restricted* velocity, rebuilt from the
fresh ``NewtonState`` once per Newton iteration (the factory protocol of
``gn.newton_iteration``), and applied inexactly by a fixed, small number
of inner CG iterations — cheap enough to amortize, accurate enough that
the slight nonlinearity does not disturb the outer PCG in practice.
"""
from __future__ import annotations

from repro.core import gauss_newton as gn
from repro.core import objective as obj
from repro.core.spectral import SpectralOps
from repro.multilevel import transfer


def make_two_level_precond(
    prob: obj.Problem,
    fine_ops: SpectralOps,
    coarse_ops: SpectralOps,
    *,
    n_cg: int = 4,
    interp_coarse=None,
):
    """Build the ``precond`` factory for ``gn.newton_iteration``.

    ``prob`` supplies the fine-level images (restricted once, here); the
    coarse Hessian is re-linearized per Newton iteration from the restricted
    current velocity, at the beta of the *runtime* ``Problem`` the factory
    receives — the continuation schedule changes beta between the sub-solves
    of a level, and a preconditioner frozen at the level's final beta would
    be misscaled by orders of magnitude on the warm-up solves.
    """
    coarse_grid = coarse_ops.grid
    rho_R_c = transfer.smooth_restrict(prob.rho_R, fine_ops, coarse_ops)
    rho_T_c = transfer.smooth_restrict(prob.rho_T, fine_ops, coarse_ops)

    def factory(state: obj.NewtonState, prob_rt: obj.Problem):
        prob_c = obj.Problem(
            grid=coarse_grid,
            rho_R=rho_R_c,
            rho_T=rho_T_c,
            beta=prob_rt.beta,
            n_t=prob_rt.n_t,
            incompressible=prob_rt.incompressible,
        )
        v_c = transfer.restrict(state.v, fine_ops, coarse_ops)
        state_c = obj.newton_state(v_c, prob_c, coarse_ops, interp_coarse)

        def matvec_c(p):
            return obj.gn_hessian_matvec(p, state_c, prob_c, coarse_ops, interp_coarse)

        def precond_c(r):
            z = coarse_ops.precond_apply(r, prob_c.beta)
            return coarse_ops.leray(z) if prob_c.incompressible else z

        def apply(r):
            r_c = transfer.restrict(r, fine_ops, coarse_ops)
            # exact spectral split BEFORE any projection of the coarse half
            r_high = r - transfer.prolong(r_c, coarse_ops, fine_ops)
            if prob_c.incompressible:
                r_c = coarse_ops.leray(r_c)
            # coarse block: a few CG iterations on H_c z_c = R r
            sol = gn.pcg(matvec_c, r_c, precond_c, coarse_grid.inner, 0.0, n_cg)
            z_low = transfer.prolong(sol.x, coarse_ops, fine_ops)
            # smoother block: spectral inverse on the unresolved complement
            z_high = fine_ops.precond_apply(r_high, prob_rt.beta)
            z = z_low + z_high
            return fine_ops.leray(z) if prob_rt.incompressible else z

        return apply

    return factory

"""repro.multilevel — coarse-to-fine grid continuation for the GN-Krylov solver.

The paper solves at a fixed grid; its successors (CLAIRE, 1808.04487;
inexact Newton-Krylov, 1408.6299) buy most of the nonlinear progress at
coarse resolution where every Hessian matvec is 8-64x cheaper.  This
package adds that layer on top of ``repro.core``:

    transfer.py   spectral restriction/prolongation between Grids
    hierarchy.py  GridHierarchy / MultilevelConfig (the level ladder)
    driver.py     multilevel.solve(): restrict -> solve -> prolong warm start
    precond.py    multigrid PCG preconditioners: recursive V-cycle with
                  Galerkin-consistent coarse Hessians + the two-level scheme
"""
from repro.multilevel.driver import solve
from repro.multilevel.hierarchy import GridHierarchy, MultilevelConfig
from repro.multilevel.precond import make_two_level_precond, make_vcycle_precond, restrict_state
from repro.multilevel.transfer import prolong, restrict

__all__ = [
    "solve",
    "GridHierarchy",
    "MultilevelConfig",
    "make_two_level_precond",
    "make_vcycle_precond",
    "restrict_state",
    "prolong",
    "restrict",
]

"""Fault-tolerant checkpointing: atomic, keep-k, async, elastic restore.

* **Atomic**: writes go to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
* **Keep-k**: old steps are garbage-collected after a successful save.
* **Async**: ``save(..., blocking=False)`` snapshots to host (device_get)
  synchronously — cheap — and writes on a daemon thread, overlapping the
  next training steps (the paper's equivalent concern: checkpointing the
  space-time fields without stalling the solver).
* **Elastic**: checkpoints store *logical* PartitionSpecs, not device
  layouts.  ``restore(..., mesh=new_mesh, specs=...)`` re-device_puts every
  leaf onto the new mesh — restart on 256 chips from a 512-chip run (or on
  1 CPU from anything) works as long as dims divide.
"""
from __future__ import annotations

import json
import os
import pickle
import re
import threading
import time

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def _step_dirs(self) -> list[tuple[int, str]]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_step(self) -> int | None:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, metadata: dict | None = None, blocking: bool = True):
        """``tree`` is any pytree of arrays (params/opt state/rng...)."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def _write():
            tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
            os.makedirs(tmp, exist_ok=True)
            leaves, treedef = jax.tree.flatten(host_tree)
            np.savez(os.path.join(tmp, "arrays.npz"), *leaves)
            with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                pickle.dump(treedef, f)
            meta = {"step": step, "time": time.time(), **(metadata or {})}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):  # overwrite-safe
                os.replace(tmp, final + ".old")
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _gc(self):
        dirs = self._step_dirs()
        for _, path in dirs[: -self.keep] if self.keep else []:
            import shutil

            shutil.rmtree(path, ignore_errors=True)
        for name in os.listdir(self.dir):
            if name.endswith(".old"):
                import shutil

                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(self, step: int | None = None, mesh=None, specs=None):
        """Returns (tree, meta).  With mesh+specs: elastic re-shard on load."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[k] for k in data.files]
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        tree = jax.tree.unflatten(treedef, leaves)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            arrs, tdef = jax.tree.flatten(tree)
            spec_leaves = jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0]
            assert len(arrs) == len(spec_leaves), (len(arrs), len(spec_leaves))
            tree = tdef.unflatten(
                [jax.device_put(a, NamedSharding(mesh, s)) for a, s in zip(arrs, spec_leaves)]
            )
        return tree, meta

"""Fault-tolerant checkpointing: atomic, keep-k, async, verified, elastic.

* **Atomic**: writes go to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
* **Keep-k**: old steps are garbage-collected after a successful save.
* **Async**: ``save(..., blocking=False)`` snapshots to host (device_get)
  synchronously — cheap — and writes on a daemon thread, overlapping the
  next training steps (the paper's equivalent concern: checkpointing the
  space-time fields without stalling the solver).  Overlapping ``save``
  calls serialize on an internal lock, and ``close()`` (or using the
  manager as a context manager) joins the writer thread, so a process
  that exits right after an async save still lands a complete step.
* **Verified**: every leaf's CRC-32 is stored in ``meta.json`` and checked
  on ``restore`` — a torn/bit-rotted step is detected instead of silently
  resuming from garbage, and the restore *falls back* to the newest
  intact step (counted as ``ckpt.corrupt_step`` + a ``RecoveryEvent``).
  Checkpoints written before this scheme (no ``checksums`` key) load
  unverified.
* **Elastic**: checkpoints store *logical* PartitionSpecs, not device
  layouts.  ``restore(..., mesh=new_mesh, specs=...)`` re-device_puts every
  leaf onto the new mesh — restart on 256 chips from a 512-chip run (or on
  1 CPU from anything) works as long as dims divide.
"""
from __future__ import annotations

import json
import os
import pickle
import re
import threading
import time
import zlib

import jax
import numpy as np

from repro import telemetry


class CheckpointCorrupt(RuntimeError):
    """A step directory failed checksum verification or did not load."""


def _leaf_crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ #
    def _step_dirs(self) -> list[tuple[int, str]]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_step(self) -> int | None:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def wait(self):
        with self._lock:
            thread = self._thread
            self._thread = None
        if thread is not None:
            thread.join()

    def close(self):
        """Join any in-flight async writer.  Idempotent."""
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, metadata: dict | None = None, blocking: bool = True):
        """``tree`` is any pytree of arrays (params/opt state/rng...)."""
        with self._lock:
            self.wait()
            host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

            def _write():
                tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
                os.makedirs(tmp, exist_ok=True)
                leaves, treedef = jax.tree.flatten(host_tree)
                np.savez(os.path.join(tmp, "arrays.npz"), *leaves)
                with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                    pickle.dump(treedef, f)
                meta = {
                    "step": step,
                    "time": time.time(),
                    "checksums": [_leaf_crc(a) for a in leaves],
                    **(metadata or {}),
                }
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(final):  # overwrite-safe
                    os.replace(final, final + ".old")
                os.replace(tmp, final)
                self._gc()

            if blocking:
                _write()
            else:
                self._thread = threading.Thread(target=_write, daemon=True)
                self._thread.start()

    def _gc(self):
        dirs = self._step_dirs()
        for _, path in dirs[: -self.keep] if self.keep else []:
            import shutil

            shutil.rmtree(path, ignore_errors=True)
        for name in os.listdir(self.dir):
            if name.endswith(".old"):
                import shutil

                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def _load_step(self, step: int, mesh=None, specs=None):
        """Load + verify one step directory; raises CheckpointCorrupt."""
        path = os.path.join(self.dir, f"step_{step}")
        try:
            data = np.load(os.path.join(path, "arrays.npz"))
            leaves = [data[k] for k in data.files]
            with open(os.path.join(path, "treedef.pkl"), "rb") as f:
                treedef = pickle.load(f)
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except Exception as e:  # unreadable npz/pickle/json = corruption
            raise CheckpointCorrupt(f"step_{step}: unreadable ({e})") from e
        sums = meta.get("checksums")
        if sums is not None:  # pre-checksum checkpoints load unverified
            if len(sums) != len(leaves):
                raise CheckpointCorrupt(
                    f"step_{step}: {len(leaves)} leaves vs {len(sums)} checksums"
                )
            for i, (a, want) in enumerate(zip(leaves, sums)):
                got = _leaf_crc(a)
                if got != want:
                    raise CheckpointCorrupt(
                        f"step_{step}: leaf {i} crc32 {got:#010x} != {want:#010x}"
                    )
        tree = jax.tree.unflatten(treedef, leaves)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            arrs, tdef = jax.tree.flatten(tree)
            spec_leaves = jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0]
            assert len(arrs) == len(spec_leaves), (len(arrs), len(spec_leaves))
            tree = tdef.unflatten(
                [jax.device_put(a, NamedSharding(mesh, s)) for a, s in zip(arrs, spec_leaves)]
            )
        return tree, meta

    def restore(self, step: int | None = None, mesh=None, specs=None):
        """Returns (tree, meta).  With mesh+specs: elastic re-shard on load.

        An explicit ``step`` is verified and raises ``CheckpointCorrupt``
        on mismatch.  With ``step=None`` the newest step is tried first
        and corruption falls back to the next-newest intact step — each
        skip counted (``ckpt.corrupt_step``) and emitted as a
        ``RecoveryEvent(action="ckpt_fallback")``.  ``(None, None)`` only
        when the directory holds no checkpoints at all; all-corrupt
        raises.
        """
        self.wait()
        if step is not None:
            return self._load_step(step, mesh=mesh, specs=specs)
        dirs = self._step_dirs()
        if not dirs:
            return None, None
        errors = []
        for st, _path in reversed(dirs):
            try:
                tree, meta = self._load_step(st, mesh=mesh, specs=specs)
            except CheckpointCorrupt as e:
                errors.append(str(e))
                telemetry.counter("ckpt.corrupt_step")
                telemetry.emit(
                    telemetry.RecoveryEvent(
                        action="ckpt_fallback", step=st, attrs={"error": str(e)}
                    )
                )
                continue
            return tree, meta
        raise CheckpointCorrupt(
            "every checkpoint failed verification: " + "; ".join(errors)
        )

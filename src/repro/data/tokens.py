"""Deterministic synthetic token pipeline (restart/rescale-reproducible).

Batches are a pure function of (seed, step, global shape): any restart —
including an *elastic* restart on a different mesh — replays the identical
stream, which the bit-exact-resume test relies on.  Structured "documents"
(Zipf unigrams + local bigram mixing) give a learnable signal so the
quickstart's loss visibly drops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def batch_at_step(seed: int, step: int, batch: int, seq: int, vocab: int):
    """Returns dict(tokens, labels) — next-token prediction."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), 7)
    k1, k2 = jax.random.split(key)
    # Zipf-ish marginal via exponential transform of uniforms
    u = jax.random.uniform(k1, (batch, seq + 1), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(jnp.exp(jnp.log(vocab) * u)).astype(jnp.int32) % vocab
    # local structure: every other token repeats its neighbor (bigrams)
    flip = jax.random.bernoulli(k2, 0.5, (batch, seq + 1))
    toks = jnp.where(flip, ranks, jnp.roll(ranks, 1, axis=1))
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenStream:
    def __init__(self, seed: int, batch: int, seq: int, vocab: int):
        self.seed, self.batch, self.seq, self.vocab = seed, batch, seq, vocab

    def __call__(self, step: int):
        return batch_at_step(self.seed, step, self.batch, self.seq, self.vocab)

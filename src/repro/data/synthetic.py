"""Synthetic registration problems (paper §IV-A1) + NIREP-like brain phantoms.

Paper's scaling-study problem:
    rho_T(x)  = (sin^2 x1 + sin^2 x2 + sin^2 x3) / 3
    v*(x)     = (cos x1 sin x2, cos x2 sin x1, cos x1 sin x3)
    rho_R     = solution of the state equation (2b) with v*.

The incompressible variant uses an analytically divergence-free v*
(footnote 5: "a similar but divergence free velocity field").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semilag
from repro.core.grid import Grid, make_grid
from repro.core.planner import make_plan
from repro.core.spectral import SpectralOps


def paper_template(grid: Grid) -> jnp.ndarray:
    x = grid.coords_jnp()
    return (jnp.sin(x[0]) ** 2 + jnp.sin(x[1]) ** 2 + jnp.sin(x[2]) ** 2) / 3.0


def paper_velocity(grid: Grid, amplitude: float = 1.0) -> jnp.ndarray:
    x = grid.coords_jnp()
    return amplitude * jnp.stack(
        [
            jnp.cos(x[0]) * jnp.sin(x[1]),
            jnp.cos(x[1]) * jnp.sin(x[0]),
            jnp.cos(x[0]) * jnp.sin(x[2]),
        ]
    )


def paper_velocity_divfree(grid: Grid, amplitude: float = 1.0) -> jnp.ndarray:
    """div v = 0 analytically: each component independent of its own coord."""
    x = grid.coords_jnp()
    return amplitude * jnp.stack(
        [jnp.sin(x[1]) * jnp.cos(x[2]), jnp.sin(x[2]) * jnp.cos(x[0]), jnp.sin(x[0]) * jnp.cos(x[1])]
    )


def synthetic_problem(n, n_t: int = 4, incompressible: bool = False, amplitude: float = 1.0):
    """Build (rho_R, rho_T, v_star, grid) with rho_R = forward-transported rho_T."""
    grid = make_grid(n)
    ops = SpectralOps(grid)
    rho_T = paper_template(grid)
    v_star = (
        paper_velocity_divfree(grid, amplitude) if incompressible else paper_velocity(grid, amplitude)
    )
    plan = make_plan(v_star, grid, ops, n_t, incompressible)
    rho_R = semilag.transport_state(rho_T, plan)[-1]
    return rho_R, rho_T, v_star, grid


def brain_like(n, seed: int = 0, n_blobs: int = 24, subject_jitter: float = 0.15):
    """NIREP-like multi-subject phantom pair: two 'individuals' built from the
    same anatomical blob layout with subject-specific jitter + a cortical
    shell, spectrally smoothed (stand-in for the na01/na02 MRI pair)."""
    grid = make_grid(n)
    ops = SpectralOps(grid)
    rng = np.random.default_rng(seed)
    x = np.asarray(grid.coords)

    centers = rng.uniform(np.pi * 0.4, np.pi * 1.6, (n_blobs, 3))
    widths = rng.uniform(0.15, 0.5, n_blobs)
    amps = rng.uniform(0.3, 1.0, n_blobs)

    def subject(jit_rng):
        img = np.zeros(grid.shape, np.float32)
        for c, w, a in zip(centers, widths, amps):
            cj = c + jit_rng.normal(0, subject_jitter, 3)
            d2 = sum((np.minimum(np.abs(x[i] - cj[i]), 2 * np.pi - np.abs(x[i] - cj[i]))) ** 2 for i in range(3))
            img += a * np.exp(-d2 / (2 * w**2))
        # cortical shell
        r = np.sqrt(sum((x[i] - np.pi) ** 2 for i in range(3)))
        img += 0.8 * np.exp(-((r - 1.8) ** 2) / 0.08)
        return img / img.max()

    ref = subject(np.random.default_rng(seed + 1))
    tmpl = subject(np.random.default_rng(seed + 2))
    return ops.smooth(jnp.asarray(ref)), ops.smooth(jnp.asarray(tmpl)), grid

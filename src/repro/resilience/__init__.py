"""``repro.resilience`` — fault-tolerant solve & serve (ISSUE 10 tentpole).

Four legs, threaded through the solver, server, and blocks layers:

* **In-graph health guards** (``health.py``): per-subject status codes
  computed inside the jitted Newton step — NaN/Inf detection, line-search
  divergence vs stagnation, PCG breakdown — with sick subjects frozen at
  their last good iterate.  All traced ops: the guard cannot recompile a
  serving bucket.
* **Retry with graceful degradation** (``policy.py``): failed jobs are
  re-admitted under a backoff ladder of safer knobs (larger beta, f32
  fields, deeper line search, exact gather interp).  A beta-only rung
  re-uses the failing bucket's compiled executable.
* **Checkpointed job streams**: ``launch.reg_serve.serve_jobs`` snapshots
  its servers through ``ckpt.manager.CheckpointManager`` and resumes a
  killed stream re-serving only unfinished jobs (billing preserved).
* **Fault injection** (``faults.py``): deterministic NaN injection,
  kill-at-step, and halo-budget overflow — the chaos harness behind
  ``tests/test_resilience.py`` and ``--suite resilience``.

``atomic.py`` is the shared crash-safe JSON writer (unique temp + fsync +
``os.replace``) adopted by the tuning cache and the benchmark records.
"""
from repro.resilience import health
from repro.resilience.atomic import atomic_write_json
from repro.resilience.faults import (
    KillAt,
    NaNInjector,
    SimulatedCrash,
    overflow_displacement,
)
from repro.resilience.policy import DEFAULT_LADDER, DegradeRung, RetryPolicy, static_key

__all__ = [
    "health",
    "atomic_write_json",
    "KillAt",
    "NaNInjector",
    "SimulatedCrash",
    "overflow_displacement",
    "DEFAULT_LADDER",
    "DegradeRung",
    "RetryPolicy",
    "static_key",
]

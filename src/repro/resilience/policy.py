"""Retry with graceful degradation: the backoff ladder for failed solves.

GPU-CLAIRE (arXiv 2401.17493) recovers from line-search stagnation and
ill-conditioned Hessians by *parameter continuation/backoff* — re-solving
under safer knobs instead of failing the job.  ``RetryPolicy`` is that
machinery for the serving path: a failed job (``JobResult.status`` in
``retry_on``) is re-admitted up to ``max_attempts`` times, each attempt
under the next **rung** of a degradation ladder:

* ``beta_scale`` — a larger regularization weight (better-conditioned
  Hessian, smoother velocity; the primary CLAIRE backoff lever).  Because
  ``beta`` is a *traced* scalar of the cohort step, a beta-only rung
  re-uses the failing bucket's compiled executable — retry churn never
  recompiles (pinned by ``tests/test_resilience.py``).
* ``field_dtype`` — force full-f32 fields (undo a bf16 storage knob that
  may have underflowed/overflowed).
* ``max_line_search`` — a deeper Armijo backtracking budget (tighter
  line search: smaller accepted steps become reachable).
* ``interp_method="ref"`` — the exact global-gather interpolation path
  (the planned "gather" fallback of the halo budget), immune to
  halo-budget overflow for any displacement.

Rungs are expressed relative to the job's *base* config, not cumulatively,
so ``degraded(cfg, attempt)`` is a pure function — the checkpoint/resume
path re-derives a degraded bucket's config from ``(base cfg, attempt)``
alone.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.resilience import health


@dataclasses.dataclass(frozen=True)
class DegradeRung:
    """One ladder step: knob overrides applied to the base ``GNConfig``.

    ``None`` leaves the base value alone.  ``beta_scale`` multiplies the
    base beta (and each entry of ``beta_continuation``, though served
    configs reject continuation anyway)."""

    beta_scale: float = 10.0
    field_dtype: str | None = None
    interp_method: str | None = None
    max_line_search: int | None = None
    max_cg: int | None = None


#: attempt 2: safer beta only — shares the primary bucket's executable.
#: attempt 3+: full degradation — f32 fields, exact gather interp, deeper
#: line search (a new, deliberately conservative executable).
DEFAULT_LADDER = (
    DegradeRung(beta_scale=10.0),
    DegradeRung(
        beta_scale=100.0,
        field_dtype="float32",
        interp_method="ref",
        max_line_search=20,
    ),
)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How failed jobs are re-admitted.

    ``retry_on`` are the ``JobResult.status`` strings that trigger a
    retry; anything else (``converged``, ``stagnated`` by default) retires
    normally.  ``warm_start=True`` seeds the retry from the failed
    attempt's last good iterate when it is finite (the freeze guard makes
    it so unless the job was poisoned before its first step), else from
    the job's original ``v0``.
    """

    max_attempts: int = 2
    retry_on: tuple[str, ...] = health.FAILED_NAMES + ("max_newton",)
    ladder: tuple[DegradeRung, ...] = DEFAULT_LADDER
    warm_start: bool = True

    def rung(self, attempt: int) -> DegradeRung:
        """Ladder rung for ``attempt`` (attempt 1 is the undegraded solve)."""
        if attempt < 2:
            raise ValueError(f"attempt {attempt} is not a retry")
        return self.ladder[min(attempt - 2, len(self.ladder) - 1)]

    def degraded(self, cfg: Any, attempt: int) -> Any:
        """The ``GNConfig`` for retry ``attempt`` of a job served under
        ``cfg``.  Pure in ``(cfg, attempt)`` — resume re-derives it."""
        if attempt <= 1:
            return cfg
        rung = self.rung(attempt)
        updates: dict[str, Any] = {
            "beta": cfg.beta * rung.beta_scale,
            "beta_continuation": tuple(
                b * rung.beta_scale for b in cfg.beta_continuation
            ),
        }
        if rung.field_dtype is not None:
            updates["field_dtype"] = rung.field_dtype
        if rung.interp_method is not None:
            updates["interp_method"] = rung.interp_method
        if rung.max_line_search is not None:
            updates["max_line_search"] = max(cfg.max_line_search, rung.max_line_search)
        if rung.max_cg is not None:
            updates["max_cg"] = rung.max_cg
        return dataclasses.replace(cfg, **updates)


def static_key(cfg: Any) -> Any:
    """Executable-identity key of a ``GNConfig``: everything *compiled into*
    the cohort step.  ``beta`` is a traced argument of the step, so two
    configs differing only in beta share one compiled executable — the
    serve layer keys its ``step_fn`` cache on this, which is what lets a
    beta-only degrade rung retry through the original program."""
    return dataclasses.replace(cfg, beta=0.0, beta_continuation=())

"""Deterministic fault injection: the chaos harness behind the resilience
tests, the smoke chaos cell, and ``--suite resilience``.

Faults are host-side **server hooks**: a ``CohortServer`` calls every
entry of ``server.hooks`` at the top of each ``step()``, so an injector
can mutate slot state (NaN-poison an iterate or an image) or abort the
loop (simulated process kill) at an exact, reproducible iteration —
without touching the compiled step program (the one-executable pin holds
under injection).  Every firing emits a typed ``FaultEvent`` plus a
``resilience.faults_injected`` counter, so a chaos trace is auditable.

``overflow_displacement`` manufactures the third ISSUE fault — a
semi-Lagrangian displacement that exceeds a given halo budget — for the
``make_checked_interp`` overflow tests (NaN-poison event + exact gather
fallback, ``tests/test_dist_interp.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro import telemetry

COUNTER_INJECTED = "resilience.faults_injected"


class SimulatedCrash(RuntimeError):
    """Raised by ``KillAt`` — stands in for a killed serve process."""


@dataclasses.dataclass
class NaNInjector:
    """Poison one subject's state at one exact server iteration.

    ``field``: ``"v"`` (the slot iterate — a mid-flight corruption),
    ``"rho_R"`` / ``"rho_T"`` (a bad input image).  ``element=None``
    poisons the whole field; an index tuple poisons one entry (enough —
    any NaN trips the in-graph guard).  Fires once.
    """

    job_id: Any
    field: str = "v"
    at_iteration: int = 1
    element: tuple | None = None
    fired: bool = dataclasses.field(default=False, init=False)

    def __call__(self, server) -> None:
        if self.fired or server.iterations != self.at_iteration:
            return
        slot = next(
            (
                s
                for s, job in enumerate(server._jobs)
                if job is not None and job.job_id == self.job_id
            ),
            None,
        )
        if slot is None:
            return
        import jax.numpy as jnp

        attr = {"v": "_v", "rho_R": "_rho_R", "rho_T": "_rho_T"}[self.field]
        arr = getattr(server, attr)
        if self.element is None:
            arr = arr.at[slot].set(jnp.nan)
        else:
            arr = arr.at[(slot,) + tuple(self.element)].set(jnp.nan)
        setattr(server, attr, arr)
        self.fired = True
        telemetry.emit(
            telemetry.FaultEvent(
                fault="nan_injection",
                target=str(self.job_id),
                iteration=int(server.iterations),
                attrs={"field": self.field, "slot": slot,
                       "element": list(self.element) if self.element else None},
            )
        )
        telemetry.counter(COUNTER_INJECTED, fault="nan_injection")


@dataclasses.dataclass
class KillAt:
    """Abort the serve loop at an exact iteration (after any checkpoint of
    the previous step has been written) by raising ``SimulatedCrash`` —
    the deterministic stand-in for ``kill -9`` mid-stream.  The resume
    test restarts from the latest snapshot and must re-serve only the
    jobs the checkpoint had not completed."""

    at_iteration: int
    fired: bool = dataclasses.field(default=False, init=False)

    def __call__(self, server) -> None:
        if self.fired or server.iterations < self.at_iteration:
            return
        self.fired = True
        telemetry.emit(
            telemetry.FaultEvent(
                fault="kill", target="serve_loop", iteration=int(server.iterations)
            )
        )
        telemetry.counter(COUNTER_INJECTED, fault="kill")
        raise SimulatedCrash(f"simulated kill at serve iteration {server.iterations}")


def overflow_displacement(shape, halo: int, excess: float = 2.5, dtype=np.float32):
    """A smooth constant displacement whose magnitude exceeds ``halo`` by
    ``excess`` voxels on every axis — guaranteed to trip the dynamic halo
    budget (``ceil(max|disp|) > halo``) while staying exactly
    interpolable by the global-gather fallback (periodic wrap)."""
    mag = float(halo) + float(excess)
    d = np.full((3,) + tuple(shape), mag, dtype=dtype)
    telemetry.emit(
        telemetry.FaultEvent(
            fault="halo_overflow",
            target=f"halo={halo}",
            attrs={"magnitude": mag, "shape": list(shape)},
        )
    )
    telemetry.counter(COUNTER_INJECTED, fault="halo_overflow")
    return d

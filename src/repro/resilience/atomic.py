"""Crash-safe JSON writes: unique temp file + fsync + ``os.replace``.

The one atomic-write idiom behind every durable JSON artifact
(``autotune/cache.py``, ``benchmarks/common.write_record``, checkpoint
metadata).  Two hazards the naive ``open(path, "w")`` — and even the
fixed-name ``path + ".tmp"`` pattern — leave open:

* a killed process truncates/tears the REAL file (naive write), or two
  concurrent writers share one temp name and one promotes the other's
  half-written bytes (fixed-name temp) — either way the next run reads
  torn JSON and counts it as corrupt (``autotune.cache_invalid``);
* a replace without ``fsync`` can be reordered by the filesystem so the
  rename lands before the data blocks, leaving an empty file after a
  power cut.

``atomic_write_json`` sidesteps both: the temp name is pid-unique, the
file is fsynced before the rename, the rename is atomic, and the temp is
unlinked on any failure.  Stdlib-only on purpose — importable from the
dependency-light leaves (``repro.autotune.cache`` allows itself nothing
beyond the stdlib + telemetry).
"""
from __future__ import annotations

import json
import os


def atomic_write_json(
    path: str,
    payload,
    *,
    indent: int | None = 2,
    sort_keys: bool = False,
    default=None,
    trailing_newline: bool = False,
) -> None:
    """Serialize ``payload`` to ``path`` so that ``path`` always holds
    either its previous contents or the complete new JSON — never a torn
    intermediate, regardless of kills or concurrent writers."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as fh:
            json.dump(payload, fh, indent=indent, sort_keys=sort_keys, default=default)
            if trailing_newline:
                fh.write("\n")
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    finally:
        # exception path (serialization error, kill between write and
        # replace on THIS code path cannot be caught — but its leftover is
        # the pid-unique temp, never the real file)
        if os.path.exists(tmp):
            try:
                os.unlink(tmp)
            except OSError:
                pass

"""In-graph health guards: per-subject status codes for the GN iteration.

CLAIRE (arXiv 1808.04487) documents the solver's real-world failure
modes — line-search stagnation, ill-conditioned Hessians at small beta,
non-finite fields from bad inputs — and its GPU successor (2401.17493)
handles them with parameter continuation/backoff.  This module is the
detection half of that machinery for our cohort-served path: a small set
of integer **status codes** computed *inside* the jitted Newton step
(``gn.newton_iteration`` / ``newton_iteration_cohort``), so that

* a subject whose gradient/objective/iterate goes NaN/Inf is caught the
  same iteration (``NONFINITE``) and **frozen at its last good iterate**
  (``freeze``) instead of propagating NaNs through the shared transform
  rides of the cohort;
* an exhausted Armijo search is split into benign ``STAGNATED`` (no
  usable decrease left) vs ``DIVERGED`` (objective *increased* past
  ``DIVERGE_RTOL`` even at the smallest trial step — the silent
  max_newton spin the ISSUE motivation names);
* a PCG recursion that broke down (non-finite direction or residual from
  an indefinite/ill-conditioned system) is tagged ``PCG_BREAKDOWN``.

Everything here is traced ``jnp`` ops on values the step already
computes — no new static arguments, no host round trips — so adding the
guard cannot recompile a serving bucket (the one-executable pin of
``tests/test_cohort.py`` / ``tests/test_resilience.py``).

The host side (``gn.solve``/``solve_cohort`` drivers, the
``launch.reg_serve.CohortServer`` retirement loop) reads the codes off
``NewtonLog.status`` and maps them to the string reasons carried by
``JobResult.status`` / ``JobEvent.status`` — which is what the retry
machinery (``repro.resilience.policy``) triggers on.
"""
from __future__ import annotations

import jax.numpy as jnp

# ---- status codes (int32 in-graph; stable contract for telemetry) ---------
OK = 0  # still iterating
CONVERGED = 1  # rel gradient norm under gtol (host-side test)
STAGNATED = 2  # zero-step exit: Armijo exhausted without a decrease
MAX_NEWTON = 3  # iteration cap reached without convergence (host-side)
NONFINITE = 4  # NaN/Inf in gradient/objective/iterate
DIVERGED = 5  # Armijo exhausted AND the objective increased
PCG_BREAKDOWN = 6  # non-finite Newton direction / PCG residual

STATUS_NAMES = {
    OK: "in_progress",
    CONVERGED: "converged",
    STAGNATED: "stagnated",
    MAX_NEWTON: "max_newton",
    NONFINITE: "nonfinite",
    DIVERGED: "diverged",
    PCG_BREAKDOWN: "pcg_breakdown",
}

# statuses that mean "this solve went wrong", not "this solve finished":
# the default retry triggers (max_newton added by RetryPolicy.retry_on)
FAILED_NAMES = ("nonfinite", "diverged", "pcg_breakdown")
FAILED_CODES = (NONFINITE, DIVERGED, PCG_BREAKDOWN)

# relative objective increase at the last Armijo trial above which an
# exhausted line search counts as divergence rather than stagnation
# (roundoff-level increases at a converged point must stay STAGNATED)
DIVERGE_RTOL = 1e-3


def status_name(code) -> str:
    return STATUS_NAMES.get(int(code), f"status{int(code)}")


def is_failure(code) -> bool:
    return int(code) in FAILED_CODES


def _all_finite(x, axes):
    """Per-subject (or scalar) all-finite reduction over ``axes``."""
    return jnp.all(jnp.isfinite(x), axis=axes)


def classify(
    *,
    v_in,
    v_out,
    j_val,
    j_new,
    gnorm,
    pcg_x,
    pcg_rel,
    accepted,
    active=True,
    axes=None,
):
    """Traced status classification for one Newton step.

    Shape-polymorphic: with ``axes=None`` every reduction is global and
    the result is a scalar status (the single-solve path); with
    ``axes=(1, 2, 3, 4)`` reductions keep the leading subjects axis and
    the result is a per-subject ``(S,)`` int32 vector (the cohort path).

    Precedence (strongest wins): NONFINITE > PCG_BREAKDOWN > DIVERGED >
    STAGNATED > OK.  Convergence and the iteration cap are host-side
    decisions (they need ``g0``/``max_newton`` bookkeeping the step does
    not carry) — the host maps them onto CONVERGED / MAX_NEWTON.
    """
    active = jnp.asarray(active, bool)
    state_finite = jnp.isfinite(j_val) & jnp.isfinite(gnorm) & _all_finite(v_in, axes)
    pcg_finite = _all_finite(pcg_x, axes) & jnp.isfinite(pcg_rel)
    out_finite = _all_finite(v_out, axes) & jnp.isfinite(j_new)

    # exhausted line search: accepted==False always comes from an Armijo
    # loop that hit its cap (a satisfied Armijo condition with a descent
    # direction implies a decrease, hence acceptance)
    scale = jnp.maximum(jnp.abs(j_val), 1e-30)
    increased = (j_new - j_val) > DIVERGE_RTOL * scale

    status = jnp.where(
        active & ~accepted, jnp.where(increased, DIVERGED, STAGNATED), OK
    )
    status = jnp.where(active & state_finite & ~pcg_finite, PCG_BREAKDOWN, status)
    status = jnp.where(
        active & ~(state_finite & out_finite), NONFINITE, status
    )
    return status.astype(jnp.int32)


def freeze(v_new, v_old, status):
    """Freeze unhealthy subjects at their last good iterate.

    ``v_new`` already equals ``v_old`` for a rejected step; this guard
    additionally reverts any iterate that picked up a non-finite value
    through an *accepted* step, so downstream consumers (shared transform
    rides, the blend of ``repro.blocks``) never see NaN/Inf from a sick
    subject.  No-op (bitwise) for healthy subjects.
    """
    sick = status == NONFINITE
    sick = sick.reshape(sick.shape + (1,) * (v_new.ndim - sick.ndim))
    return jnp.where(sick, v_old, v_new)

"""Cohort registration server: keep one jitted Newton step hot, stream jobs
through its subject slots.

    PYTHONPATH=src python -m repro.launch.reg_serve --jobs 6 --slots 3 \
        --size 16 --beta 1e-2 --max-newton 8

The economics (ROADMAP "solves/second" item): on a mesh, one registration
solve pays a fixed collective-latency bill per Newton iteration (ghost
exchanges + pencil all-to-alls) that is independent of how many subjects
ride the batched kernels.  ``gn.solve_cohort`` amortizes that bill across a
fixed cohort; this driver amortizes it across an UNBOUNDED job stream:

* jobs are bucketed by ``(image shape, GNConfig)`` — each bucket owns ONE
  ``gn.make_cohort_step`` executable (image stacks, the continuation beta,
  per-subject forcing references, and the active mask are all traced
  arguments, so admissions/retirements NEVER recompile; pinned by
  ``tests/test_cohort.py``);
* each bucket runs an S-slot cohort: per-subject masked termination retires
  a converged subject mid-flight and its slot is refilled from the queue on
  the next iteration, so the executable keeps running near-full cohorts
  instead of waiting for stragglers;
* per-subject accounting: every job is billed exactly the Hessian matvecs
  its own masked PCG consumed (``fine_equiv_matvecs``; a slot's meter is
  zero while it hosts a retired/free subject), so the cohort batching is
  cost-transparent per job — the paper's Table V metric, per subject.

Slot refills require every subject in a bucket to share one regularization
scalar per step (``beta`` is a single traced scalar, not per-subject), so a
server config must not use ``beta_continuation`` — run continuation as
separate buckets, coarse-beta bucket feeding the fine-beta bucket's queue.

Resilience (ISSUE 10): every retirement carries an explicit ``status``
reason read off the in-graph health guard (``repro.resilience.health``);
``serve_jobs(retry=RetryPolicy(...))`` re-admits failed jobs under a
degradation ladder (a beta-only rung re-uses the failing bucket's compiled
executable); ``serve_jobs(checkpoint=dir)`` snapshots the whole session
through ``ckpt.manager.CheckpointManager`` and ``resume=True`` restarts a
killed stream re-serving only unfinished jobs with per-job billing
preserved.  ``CohortServer.hooks`` is the fault-injection surface
(``repro.resilience.faults``).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import gauss_newton as gn
from repro.core.grid import Grid, make_grid
from repro.core.spectral import SpectralOps
from repro.resilience import health
from repro.resilience import policy as res_policy

_FORCING_SENTINEL = 1e-30  # first iteration of a subject: eta = eta_max


@dataclasses.dataclass
class RegJob:
    """One registration request: a reference/template image pair.

    ``v0`` optionally warm-starts the slot (``repro.blocks`` admits every
    tile with the prolonged global coarse velocity); ``g0_ref`` optionally
    fixes the CONVERGENCE reference gradient norm — a warm-started job
    passes its cold-start norm so it terminates at the same absolute
    tolerance a cold solve would, exactly the ``gn.solve(g0_ref=...)``
    semantics of the multilevel ladder (the Eisenstat-Walker forcing
    reference stays decoupled: it is always the slot's first iterate).
    ``block`` tags the job's tile index for per-block ``JobEvent`` billing.
    """

    job_id: Any
    rho_R: jnp.ndarray  # (N1, N2, N3)
    rho_T: jnp.ndarray
    v0: jnp.ndarray | None = None  # (3, N..) warm start; None = zero
    g0_ref: float | None = None
    block: tuple | None = None
    attempt: int = 1  # 1 = original admission; >1 = a degraded retry


@dataclasses.dataclass
class JobResult:
    job_id: Any
    v: np.ndarray  # (3, N..) converged velocity
    newton_iters: int
    hessian_matvecs: int
    fine_equiv_matvecs: float  # single level: == hessian_matvecs
    rel_gnorm: float
    converged: bool  # rel_gnorm <= gtol (kept for back-compat with status)
    # explicit retirement reason — what ``converged=False`` used to
    # conflate: "converged" | "stagnated" | "max_newton" | "nonfinite" |
    # "diverged" | "pcg_breakdown" (``repro.resilience.health`` names)
    status: str = ""
    attempts: int = 1  # serve attempt that produced this result


class CohortServer:
    """One executable bucket: an S-slot cohort over a fixed (grid, cfg).

    ``step()`` advances every live slot one masked Newton iteration and
    returns the jobs that retired; ``admit()`` queues jobs; ``run()`` drives
    the loop until queue and slots drain.  Pass ``ops``/``interp`` from a
    ``DistContext`` to serve on a mesh.
    """

    def __init__(self, grid: Grid, cfg: gn.GNConfig, slots: int = 4,
                 ops: SpectralOps | None = None, interp=None, step_fn=None):
        if cfg.beta_continuation:
            raise ValueError(
                "CohortServer slots share one traced beta per step; run "
                "beta continuation as chained server buckets instead"
            )
        self.grid, self.cfg, self.slots = grid, cfg, slots
        self.step_fn = step_fn or gn.make_cohort_step(grid, cfg, ops=ops, interp=interp)
        self.queue: list[RegJob] = []
        self.results: list[JobResult] = []
        S = slots
        self._jobs: list[RegJob | None] = [None] * S
        self._v = jnp.zeros((S, 3) + grid.shape, grid.dtype)
        self._rho_R = jnp.zeros((S,) + grid.shape, grid.dtype)
        self._rho_T = jnp.zeros((S,) + grid.shape, grid.dtype)
        self._g_forcing = np.full(S, _FORCING_SENTINEL, np.float32)
        self._g0 = np.zeros(S, np.float32)  # termination reference per slot
        self._g0_preset = np.zeros(S, bool)  # True: job supplied g0_ref
        self._newton = np.zeros(S, np.int64)
        self._cg = np.zeros(S, np.int64)
        self._rel = np.zeros(S, np.float32)
        self.iterations = 0  # cohort step calls (the shared-cost meter)
        self.refills = 0  # slot fills after a retirement (not initial fills)
        self.admitted = 0  # total jobs ever admitted to this bucket
        self._echo = False  # run(verbose=...) renders retirements via telemetry
        self._enqueued_at: dict[int, int] = {}  # id(job) -> iterations at admit
        self._admitted_at = np.zeros(S, np.int64)  # iterations at slot fill
        self._queue_wait = np.zeros(S, np.int64)  # steps spent queued
        # fault-injection surface: callables invoked with this server at the
        # top of every step() (repro.resilience.faults hooks mutate slot
        # state or abort the loop host-side; the compiled step is untouched)
        self.hooks: list = []

    def admit(self, *jobs: RegJob) -> None:
        for job in jobs:
            self._enqueued_at[id(job)] = self.iterations
        self.admitted += len(jobs)
        self.queue.extend(jobs)

    @property
    def active(self) -> np.ndarray:
        return np.asarray([j is not None for j in self._jobs])

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self._jobs[s] is None and self.queue:
                job = self.queue.pop(0)
                self._jobs[s] = job
                self._v = self._v.at[s].set(
                    0.0 if job.v0 is None else jnp.asarray(job.v0, self.grid.dtype)
                )
                self._rho_R = self._rho_R.at[s].set(jnp.asarray(job.rho_R))
                self._rho_T = self._rho_T.at[s].set(jnp.asarray(job.rho_T))
                self._g_forcing[s] = _FORCING_SENTINEL
                self._g0_preset[s] = job.g0_ref is not None
                self._g0[s] = job.g0_ref if job.g0_ref is not None else 0.0
                self._newton[s] = 0
                self._cg[s] = 0
                if self.iterations > 0:
                    self.refills += 1
                self._admitted_at[s] = self.iterations
                self._queue_wait[s] = self.iterations - self._enqueued_at.pop(
                    id(job), self.iterations
                )

    def _retire(self, s: int, converged: bool, status: str) -> JobResult:
        job = self._jobs[s]
        res = JobResult(
            job_id=job.job_id,
            v=np.asarray(self._v[s]),
            newton_iters=int(self._newton[s]),
            hessian_matvecs=int(self._cg[s]),
            fine_equiv_matvecs=float(self._cg[s]),
            rel_gnorm=float(self._rel[s]),
            converged=converged,
            status=status,
            attempts=int(job.attempt),
        )
        self._jobs[s] = None
        self.results.append(res)
        if status in health.FAILED_NAMES:
            telemetry.counter(
                "resilience.guard_tripped", status=status, source="reg_serve"
            )
        # the per-tenant billing record (the paper's Table V meter, per job)
        telemetry.emit(
            telemetry.JobEvent(
                job_id=str(res.job_id),
                newton_iters=res.newton_iters,
                hessian_matvecs=res.hessian_matvecs,
                fine_equiv_matvecs=res.fine_equiv_matvecs,
                rel_gnorm=res.rel_gnorm,
                converged=res.converged,
                slot=s,
                queue_wait_steps=int(self._queue_wait[s]),
                admitted_step=int(self._admitted_at[s]),
                retired_step=self.iterations,
                block=list(job.block) if job.block is not None else None,
                status=res.status,
                attempts=res.attempts,
            ),
            echo=self._echo,
        )
        return res

    def step(self) -> list[JobResult]:
        """Fill free slots, advance one masked Newton iteration, retire."""
        for hook in list(self.hooks):
            hook(self)
        self._fill_slots()
        active = self.active
        if not active.any():
            return []
        self._v, log = self.step_fn(
            self._v,
            jnp.asarray(self._g_forcing),
            jnp.asarray(active),
            jnp.float32(self.cfg.beta),
            self._rho_R,
            self._rho_T,
        )
        self.iterations += 1
        gnorm = np.asarray(log.gnorm, np.float32)
        step_len = np.asarray(log.step_len)
        code = np.asarray(log.status, np.int64)
        self._newton += active
        self._cg += np.asarray(log.cg_iters, np.int64)
        retired = []
        for s in range(self.slots):
            if not active[s]:
                continue
            # a freshly admitted subject's first iterate fixes its
            # Eisenstat-Walker forcing reference, and — unless the job
            # supplied an explicit g0_ref (warm-started blocks do) — its
            # termination reference (the decoupling of gn.solve, per slot)
            if self._g_forcing[s] == _FORCING_SENTINEL:
                self._g_forcing[s] = gnorm[s]
                if not self._g0_preset[s]:
                    self._g0[s] = gnorm[s]
            self._rel[s] = gnorm[s] / max(self._g0[s], _FORCING_SENTINEL)
            converged = bool(self._rel[s] <= self.cfg.gtol)
            # retirement reason: the in-graph guard decides the failure
            # modes; the host decides converged / stagnated / max_newton
            if int(code[s]) in health.FAILED_CODES:
                status = health.status_name(int(code[s]))
                converged = False
            elif converged:
                status = health.status_name(health.CONVERGED)
            elif step_len[s] == 0.0:
                status = health.status_name(health.STAGNATED)
            elif self._newton[s] >= self.cfg.max_newton:
                status = health.status_name(health.MAX_NEWTON)
            else:
                continue
            retired.append(self._retire(s, converged, status))
        telemetry.emit(
            telemetry.ServeStepEvent(
                iteration=self.iterations,
                slots=self.slots,
                occupancy=int(active.sum()),
                queue_len=len(self.queue),
                refills=self.refills,
            )
        )
        return retired

    def run(self, verbose: bool = False) -> list[JobResult]:
        self._echo = verbose
        try:
            while self.queue or self.active.any():
                self.step()
        finally:
            self._echo = False
        return self.results

    def compiled_executables(self) -> int:
        return int(self.step_fn._cache_size())

    def emit_step_collectives(self, label: str = "cohort_step") -> None:
        """Emit per-kind collective counts for this bucket's step executable.

        Ahead-of-time lowering: does not populate the jit cache, so the
        one-executable pin of ``compiled_executables`` is unaffected.  No-op
        unless a telemetry sink is installed (lowering+compiling a second
        copy of the step is not free).
        """
        if not telemetry.enabled():
            return
        lowered = self.step_fn.lower(
            self._v,
            jnp.asarray(self._g_forcing),
            jnp.asarray(self.active),
            jnp.float32(self.cfg.beta),
            self._rho_R,
            self._rho_T,
        )
        telemetry.emit_collectives(label, lowered)

    # ------------------------------------------------------------------ #
    # checkpointed job streams: the snapshot is standalone — it carries the
    # slot state AND every queued job's images, so ``restore`` needs no
    # access to the original job list (job_ids must be JSON-serializable)
    def snapshot(self) -> tuple[dict, dict]:
        """(tree, meta) for ``ckpt.manager.CheckpointManager.save``: arrays
        in the tree, JSON-able bookkeeping in the meta."""
        zero_v = jnp.zeros((3,) + self.grid.shape, self.grid.dtype)
        tree = {
            "v": self._v,
            "rho_R": self._rho_R,
            "rho_T": self._rho_T,
            "queue_rho_R": [jnp.asarray(j.rho_R) for j in self.queue],
            "queue_rho_T": [jnp.asarray(j.rho_T) for j in self.queue],
            "queue_v0": [
                zero_v if j.v0 is None else jnp.asarray(j.v0) for j in self.queue
            ],
        }

        def _job_meta(job: RegJob) -> dict:
            return {
                "job_id": job.job_id,
                "attempt": int(job.attempt),
                "g0_ref": None if job.g0_ref is None else float(job.g0_ref),
                "block": None if job.block is None else list(job.block),
            }

        meta = {
            "iterations": int(self.iterations),
            "refills": int(self.refills),
            "admitted": int(self.admitted),
            "slot_jobs": [
                None
                if job is None
                else {
                    **_job_meta(job),
                    "g_forcing": float(self._g_forcing[s]),
                    "g0": float(self._g0[s]),
                    "g0_preset": bool(self._g0_preset[s]),
                    "newton": int(self._newton[s]),
                    "cg": int(self._cg[s]),
                    "rel": float(self._rel[s]),
                    "admitted_at": int(self._admitted_at[s]),
                    "queue_wait": int(self._queue_wait[s]),
                }
                for s, job in enumerate(self._jobs)
            ],
            "queue_jobs": [
                {
                    **_job_meta(job),
                    "has_v0": job.v0 is not None,
                    "enqueued_at": int(
                        self._enqueued_at.get(id(job), self.iterations)
                    ),
                }
                for job in self.queue
            ],
        }
        return tree, meta

    @classmethod
    def restore(cls, grid: Grid, cfg: gn.GNConfig, tree: dict, meta: dict,
                ops: SpectralOps | None = None, interp=None, step_fn=None
                ) -> "CohortServer":
        """Rebuild a server mid-stream from a ``snapshot()`` pair.  Slot
        iterates, per-slot billing meters, and queued jobs (images included)
        all resume exactly; only unfinished jobs are re-served."""
        srv = cls(grid, cfg, slots=len(meta["slot_jobs"]), ops=ops,
                  interp=interp, step_fn=step_fn)
        srv._v = jnp.asarray(tree["v"], grid.dtype)
        srv._rho_R = jnp.asarray(tree["rho_R"], grid.dtype)
        srv._rho_T = jnp.asarray(tree["rho_T"], grid.dtype)
        srv.iterations = int(meta["iterations"])
        srv.refills = int(meta["refills"])
        srv.admitted = int(meta["admitted"])
        for s, sm in enumerate(meta["slot_jobs"]):
            if sm is None:
                continue
            srv._jobs[s] = RegJob(
                job_id=sm["job_id"],
                rho_R=srv._rho_R[s],
                rho_T=srv._rho_T[s],
                v0=None,
                g0_ref=sm["g0_ref"],
                block=None if sm["block"] is None else tuple(sm["block"]),
                attempt=int(sm["attempt"]),
            )
            srv._g_forcing[s] = sm["g_forcing"]
            srv._g0[s] = sm["g0"]
            srv._g0_preset[s] = sm["g0_preset"]
            srv._newton[s] = sm["newton"]
            srv._cg[s] = sm["cg"]
            srv._rel[s] = sm["rel"]
            srv._admitted_at[s] = sm["admitted_at"]
            srv._queue_wait[s] = sm["queue_wait"]
        for q, qm in enumerate(meta["queue_jobs"]):
            job = RegJob(
                job_id=qm["job_id"],
                rho_R=jnp.asarray(tree["queue_rho_R"][q], grid.dtype),
                rho_T=jnp.asarray(tree["queue_rho_T"][q], grid.dtype),
                v0=jnp.asarray(tree["queue_v0"][q], grid.dtype)
                if qm["has_v0"]
                else None,
                g0_ref=qm["g0_ref"],
                block=None if qm["block"] is None else tuple(qm["block"]),
                attempt=int(qm["attempt"]),
            )
            srv.queue.append(job)
            srv._enqueued_at[id(job)] = int(qm["enqueued_at"])
        return srv


def _result_meta(res: JobResult) -> dict:
    """JSON-able billing fields of a JobResult (the ``v`` array rides the
    checkpoint tree separately)."""
    return {
        "job_id": res.job_id,
        "newton_iters": int(res.newton_iters),
        "hessian_matvecs": int(res.hessian_matvecs),
        "fine_equiv_matvecs": float(res.fine_equiv_matvecs),
        "rel_gnorm": float(res.rel_gnorm),
        "converged": bool(res.converged),
        "status": res.status,
        "attempts": int(res.attempts),
    }


def serve_jobs(jobs: list[RegJob], cfg: gn.GNConfig, slots: int = 4,
               ops: SpectralOps | None = None, interp=None,
               verbose: bool = False,
               retry: "res_policy.RetryPolicy | None" = None,
               checkpoint: Any = None, checkpoint_every: int = 5,
               resume: bool = False, faults: list | None = None,
               grid_dtype=None) -> dict:
    """Bucket ``jobs`` by (image shape, attempt) and drain every bucket.

    Returns ``{"results": [JobResult...], "buckets": {key: stats},
    "compiled_executables": n}``.  A bucket key is ``tuple(shape)`` for the
    primary attempt and ``tuple(shape) + ("retry<k>",)`` for degraded
    retries; ``compiled_executables`` counts distinct compiled step
    programs over the whole session (1 when every retry rode a beta-only
    rung).

    * ``retry``: a ``repro.resilience.RetryPolicy`` — jobs retiring with a
      status in ``retry.retry_on`` are re-admitted under the degradation
      ladder, warm-started from their last good iterate when finite.
    * ``checkpoint``: a directory (or ``CheckpointManager``) snapshotting
      the whole session every ``checkpoint_every`` serve rounds; with
      ``resume=True`` the latest snapshot is restored and ONLY unfinished
      jobs are re-served (``jobs`` is ignored when a snapshot exists —
      the snapshot carries every queued image and completed result).
    * ``faults``: fault-injection hooks attached to every server
      (``repro.resilience.faults``); deterministic chaos for the tests.
    * ``grid_dtype``: dtype for the per-bucket grids (``repro.blocks``
      serves tiles of the global grid's dtype).
    """
    faults = list(faults or [])
    mgr = None
    if checkpoint is not None:
        from repro.ckpt.manager import CheckpointManager

        mgr = (
            checkpoint
            if isinstance(checkpoint, CheckpointManager)
            else CheckpointManager(checkpoint)
        )

    step_cache: dict = {}  # (shape, static_key(cfg)) -> shared jitted step
    servers: dict[tuple, CohortServer] = {}  # (shape, attempt) -> server
    by_id: dict = {}  # job_id -> RegJob (images for retry re-admission)
    final: list[JobResult] = []  # one final result per job

    def _bucket_cfg(attempt: int) -> gn.GNConfig:
        if retry is not None and attempt > 1:
            return retry.degraded(cfg, attempt)
        return cfg

    def _make_grid(shape):
        return make_grid(shape, grid_dtype) if grid_dtype is not None else make_grid(shape)

    def _get_server(shape, attempt: int) -> CohortServer:
        key = (tuple(shape), int(attempt))
        if key not in servers:
            grid = _make_grid(key[0])
            cfg_a = _bucket_cfg(key[1])
            sk = (key[0], res_policy.static_key(cfg_a))
            if sk not in step_cache:
                step_cache[sk] = gn.make_cohort_step(grid, cfg_a, ops=ops, interp=interp)
            srv = CohortServer(grid, cfg_a, slots=slots, ops=ops, interp=interp,
                               step_fn=step_cache[sk])
            srv.hooks.extend(faults)
            servers[key] = srv
        return servers[key]

    def _restore_server(shape, attempt: int, tree: dict, meta: dict) -> CohortServer:
        key = (tuple(shape), int(attempt))
        grid = _make_grid(key[0])
        cfg_a = _bucket_cfg(key[1])
        sk = (key[0], res_policy.static_key(cfg_a))
        if sk not in step_cache:
            step_cache[sk] = gn.make_cohort_step(grid, cfg_a, ops=ops, interp=interp)
        srv = CohortServer.restore(grid, cfg_a, tree, meta, ops=ops, interp=interp,
                                   step_fn=step_cache[sk])
        srv.hooks.extend(faults)
        servers[key] = srv
        return srv

    # ---- session bring-up: resume from the latest snapshot, or admit jobs
    serve_round = 0
    restored = False
    if resume and mgr is not None and mgr.latest_step() is not None:
        tree, meta = mgr.restore()
        serve_round = int(meta["step"])
        for r_meta, r_v in zip(meta["results"], tree["results_v"]):
            final.append(JobResult(v=np.asarray(r_v), **r_meta))
        for label, bm in meta["buckets"].items():
            _restore_server(tuple(bm["shape"]), int(bm["attempt"]),
                            tree["buckets"][label], bm)
        for srv in servers.values():
            for j in list(srv.queue) + [x for x in srv._jobs if x is not None]:
                by_id.setdefault(j.job_id, j)
        restored = True
        telemetry.emit(
            telemetry.RecoveryEvent(
                action="resume_from_checkpoint",
                step=serve_round,
                attrs={
                    "completed": len(final),
                    "unfinished": sum(
                        len(s.queue) + int(s.active.sum()) for s in servers.values()
                    ),
                },
            ),
            echo=verbose,
        )
        telemetry.counter("resilience.resumes")
    if not restored:
        for job in jobs:
            by_id[job.job_id] = job
            _get_server(np.shape(job.rho_R), job.attempt).admit(job)

    # ---- retirement handling: retry failed jobs through the ladder -------
    def _handle(res: JobResult) -> None:
        if (
            retry is not None
            and res.status in retry.retry_on
            and res.attempts < retry.max_attempts
            and res.job_id in by_id
        ):
            base = by_id[res.job_id]
            v_last = np.asarray(res.v)
            warm = retry.warm_start and bool(np.isfinite(v_last).all())
            nxt = res.attempts + 1
            rj = RegJob(
                job_id=res.job_id,
                rho_R=base.rho_R,
                rho_T=base.rho_T,
                v0=v_last if warm else base.v0,
                g0_ref=base.g0_ref,
                block=base.block,
                attempt=nxt,
            )
            by_id[res.job_id] = rj
            _get_server(np.shape(base.rho_R), nxt).admit(rj)
            telemetry.emit(
                telemetry.RecoveryEvent(
                    action="retry_degraded",
                    job_id=str(res.job_id),
                    attempts=nxt,
                    attrs={"status": res.status, "warm_start": warm},
                ),
                echo=verbose,
            )
            telemetry.counter("resilience.retries", status=res.status)
            return
        if res.status in health.FAILED_NAMES:
            telemetry.counter("resilience.jobs_failed", status=res.status)
        final.append(res)

    def _save_session() -> None:
        tree: dict = {"buckets": {}, "results_v": [jnp.asarray(r.v) for r in final]}
        meta: dict = {"buckets": {}, "results": [_result_meta(r) for r in final]}
        for (shape, attempt), srv in servers.items():
            label = "x".join(map(str, shape)) + f"@a{attempt}"
            t, m = srv.snapshot()
            tree["buckets"][label] = t
            meta["buckets"][label] = {"shape": list(shape), "attempt": attempt, **m}
        mgr.save(serve_round, tree, meta)

    # ---- drain loop: round-robin over buckets, periodic snapshots --------
    def _live(srv: CohortServer) -> bool:
        return bool(srv.queue) or bool(srv.active.any())

    while any(_live(s) for s in servers.values()):
        for key in list(servers):
            srv = servers[key]
            if not _live(srv):
                continue
            srv._echo = verbose
            try:
                for res in srv.step():
                    _handle(res)
            finally:
                srv._echo = False
        serve_round += 1
        if mgr is not None and checkpoint_every and serve_round % checkpoint_every == 0:
            _save_session()
    if mgr is not None:
        _save_session()

    # ---- stats: per-bucket meters + the session-wide executable count ----
    stats: dict = {}
    execs: dict[int, int] = {}
    for (shape, attempt), srv in servers.items():
        key = shape if attempt == 1 else shape + (f"retry{attempt}",)
        if attempt == 1:
            srv.emit_step_collectives(f"cohort_step{shape}")
        stats[key] = {
            "jobs": srv.admitted,
            "attempt": attempt,
            "cohort_iterations": srv.iterations,
            "compiled_executables": srv.compiled_executables(),
        }
        execs[id(srv.step_fn)] = srv.compiled_executables()
    return {
        "results": final,
        "buckets": stats,
        "compiled_executables": sum(execs.values()),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--size", type=int, default=16)
    ap.add_argument("--beta", type=float, default=1e-2)
    ap.add_argument("--n-t", type=int, default=4)
    ap.add_argument("--max-newton", type=int, default=8)
    ap.add_argument("--max-cg", type=int, default=30)
    ap.add_argument("--gtol", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", type=str, default=None,
                    help="write a telemetry JSONL trace to this path "
                         "(render with: python -m repro.analysis.trace_report)")
    args = ap.parse_args()

    from repro.data.synthetic import synthetic_problem

    cfg = gn.GNConfig(beta=args.beta, n_t=args.n_t, max_newton=args.max_newton,
                      max_cg=args.max_cg, gtol=args.gtol)
    rng = np.random.default_rng(args.seed)
    jobs = []
    for j in range(args.jobs):
        amp = float(rng.uniform(0.3, 1.0))
        rho_R, rho_T, _, _ = synthetic_problem(args.size, n_t=args.n_t, amplitude=amp)
        jobs.append(RegJob(job_id=f"job{j}(amp={amp:.2f})", rho_R=rho_R, rho_T=rho_T))

    import contextlib

    sink = telemetry.jsonl_sink(args.trace) if args.trace else contextlib.nullcontext()
    t0 = time.time()
    with sink:
        out = serve_jobs(jobs, cfg, slots=args.slots, verbose=True)
    dt = time.time() - t0
    for shape, st in out["buckets"].items():
        print(
            f"bucket {shape}: {st['jobs']} jobs in {st['cohort_iterations']} cohort "
            f"iterations, {st['compiled_executables']} compiled executable(s)"
        )
    total_mv = sum(r.hessian_matvecs for r in out["results"])
    print(f"served {len(out['results'])} jobs in {dt:.1f}s, {total_mv} matvecs total")


if __name__ == "__main__":
    main()

"""Cohort registration server: keep one jitted Newton step hot, stream jobs
through its subject slots.

    PYTHONPATH=src python -m repro.launch.reg_serve --jobs 6 --slots 3 \
        --size 16 --beta 1e-2 --max-newton 8

The economics (ROADMAP "solves/second" item): on a mesh, one registration
solve pays a fixed collective-latency bill per Newton iteration (ghost
exchanges + pencil all-to-alls) that is independent of how many subjects
ride the batched kernels.  ``gn.solve_cohort`` amortizes that bill across a
fixed cohort; this driver amortizes it across an UNBOUNDED job stream:

* jobs are bucketed by ``(image shape, GNConfig)`` — each bucket owns ONE
  ``gn.make_cohort_step`` executable (image stacks, the continuation beta,
  per-subject forcing references, and the active mask are all traced
  arguments, so admissions/retirements NEVER recompile; pinned by
  ``tests/test_cohort.py``);
* each bucket runs an S-slot cohort: per-subject masked termination retires
  a converged subject mid-flight and its slot is refilled from the queue on
  the next iteration, so the executable keeps running near-full cohorts
  instead of waiting for stragglers;
* per-subject accounting: every job is billed exactly the Hessian matvecs
  its own masked PCG consumed (``fine_equiv_matvecs``; a slot's meter is
  zero while it hosts a retired/free subject), so the cohort batching is
  cost-transparent per job — the paper's Table V metric, per subject.

Slot refills require every subject in a bucket to share one regularization
scalar per step (``beta`` is a single traced scalar, not per-subject), so a
server config must not use ``beta_continuation`` — run continuation as
separate buckets, coarse-beta bucket feeding the fine-beta bucket's queue.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import gauss_newton as gn
from repro.core.grid import Grid, make_grid
from repro.core.spectral import SpectralOps

_FORCING_SENTINEL = 1e-30  # first iteration of a subject: eta = eta_max


@dataclasses.dataclass
class RegJob:
    """One registration request: a reference/template image pair.

    ``v0`` optionally warm-starts the slot (``repro.blocks`` admits every
    tile with the prolonged global coarse velocity); ``g0_ref`` optionally
    fixes the CONVERGENCE reference gradient norm — a warm-started job
    passes its cold-start norm so it terminates at the same absolute
    tolerance a cold solve would, exactly the ``gn.solve(g0_ref=...)``
    semantics of the multilevel ladder (the Eisenstat-Walker forcing
    reference stays decoupled: it is always the slot's first iterate).
    ``block`` tags the job's tile index for per-block ``JobEvent`` billing.
    """

    job_id: Any
    rho_R: jnp.ndarray  # (N1, N2, N3)
    rho_T: jnp.ndarray
    v0: jnp.ndarray | None = None  # (3, N..) warm start; None = zero
    g0_ref: float | None = None
    block: tuple | None = None


@dataclasses.dataclass
class JobResult:
    job_id: Any
    v: np.ndarray  # (3, N..) converged velocity
    newton_iters: int
    hessian_matvecs: int
    fine_equiv_matvecs: float  # single level: == hessian_matvecs
    rel_gnorm: float
    converged: bool  # rel_gnorm <= gtol (False: zero-step/max_newton exit)


class CohortServer:
    """One executable bucket: an S-slot cohort over a fixed (grid, cfg).

    ``step()`` advances every live slot one masked Newton iteration and
    returns the jobs that retired; ``admit()`` queues jobs; ``run()`` drives
    the loop until queue and slots drain.  Pass ``ops``/``interp`` from a
    ``DistContext`` to serve on a mesh.
    """

    def __init__(self, grid: Grid, cfg: gn.GNConfig, slots: int = 4,
                 ops: SpectralOps | None = None, interp=None, step_fn=None):
        if cfg.beta_continuation:
            raise ValueError(
                "CohortServer slots share one traced beta per step; run "
                "beta continuation as chained server buckets instead"
            )
        self.grid, self.cfg, self.slots = grid, cfg, slots
        self.step_fn = step_fn or gn.make_cohort_step(grid, cfg, ops=ops, interp=interp)
        self.queue: list[RegJob] = []
        self.results: list[JobResult] = []
        S = slots
        self._jobs: list[RegJob | None] = [None] * S
        self._v = jnp.zeros((S, 3) + grid.shape, grid.dtype)
        self._rho_R = jnp.zeros((S,) + grid.shape, grid.dtype)
        self._rho_T = jnp.zeros((S,) + grid.shape, grid.dtype)
        self._g_forcing = np.full(S, _FORCING_SENTINEL, np.float32)
        self._g0 = np.zeros(S, np.float32)  # termination reference per slot
        self._g0_preset = np.zeros(S, bool)  # True: job supplied g0_ref
        self._newton = np.zeros(S, np.int64)
        self._cg = np.zeros(S, np.int64)
        self._rel = np.zeros(S, np.float32)
        self.iterations = 0  # cohort step calls (the shared-cost meter)
        self.refills = 0  # slot fills after a retirement (not initial fills)
        self._echo = False  # run(verbose=...) renders retirements via telemetry
        self._enqueued_at: dict[int, int] = {}  # id(job) -> iterations at admit
        self._admitted_at = np.zeros(S, np.int64)  # iterations at slot fill
        self._queue_wait = np.zeros(S, np.int64)  # steps spent queued

    def admit(self, *jobs: RegJob) -> None:
        for job in jobs:
            self._enqueued_at[id(job)] = self.iterations
        self.queue.extend(jobs)

    @property
    def active(self) -> np.ndarray:
        return np.asarray([j is not None for j in self._jobs])

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self._jobs[s] is None and self.queue:
                job = self.queue.pop(0)
                self._jobs[s] = job
                self._v = self._v.at[s].set(
                    0.0 if job.v0 is None else jnp.asarray(job.v0, self.grid.dtype)
                )
                self._rho_R = self._rho_R.at[s].set(jnp.asarray(job.rho_R))
                self._rho_T = self._rho_T.at[s].set(jnp.asarray(job.rho_T))
                self._g_forcing[s] = _FORCING_SENTINEL
                self._g0_preset[s] = job.g0_ref is not None
                self._g0[s] = job.g0_ref if job.g0_ref is not None else 0.0
                self._newton[s] = 0
                self._cg[s] = 0
                if self.iterations > 0:
                    self.refills += 1
                self._admitted_at[s] = self.iterations
                self._queue_wait[s] = self.iterations - self._enqueued_at.pop(
                    id(job), self.iterations
                )

    def _retire(self, s: int, converged: bool) -> JobResult:
        job = self._jobs[s]
        res = JobResult(
            job_id=job.job_id,
            v=np.asarray(self._v[s]),
            newton_iters=int(self._newton[s]),
            hessian_matvecs=int(self._cg[s]),
            fine_equiv_matvecs=float(self._cg[s]),
            rel_gnorm=float(self._rel[s]),
            converged=converged,
        )
        self._jobs[s] = None
        self.results.append(res)
        # the per-tenant billing record (the paper's Table V meter, per job)
        telemetry.emit(
            telemetry.JobEvent(
                job_id=str(res.job_id),
                newton_iters=res.newton_iters,
                hessian_matvecs=res.hessian_matvecs,
                fine_equiv_matvecs=res.fine_equiv_matvecs,
                rel_gnorm=res.rel_gnorm,
                converged=res.converged,
                slot=s,
                queue_wait_steps=int(self._queue_wait[s]),
                admitted_step=int(self._admitted_at[s]),
                retired_step=self.iterations,
                block=list(job.block) if job.block is not None else None,
            ),
            echo=self._echo,
        )
        return res

    def step(self) -> list[JobResult]:
        """Fill free slots, advance one masked Newton iteration, retire."""
        self._fill_slots()
        active = self.active
        if not active.any():
            return []
        self._v, log = self.step_fn(
            self._v,
            jnp.asarray(self._g_forcing),
            jnp.asarray(active),
            jnp.float32(self.cfg.beta),
            self._rho_R,
            self._rho_T,
        )
        self.iterations += 1
        gnorm = np.asarray(log.gnorm, np.float32)
        step_len = np.asarray(log.step_len)
        self._newton += active
        self._cg += np.asarray(log.cg_iters, np.int64)
        retired = []
        for s in range(self.slots):
            if not active[s]:
                continue
            # a freshly admitted subject's first iterate fixes its
            # Eisenstat-Walker forcing reference, and — unless the job
            # supplied an explicit g0_ref (warm-started blocks do) — its
            # termination reference (the decoupling of gn.solve, per slot)
            if self._g_forcing[s] == _FORCING_SENTINEL:
                self._g_forcing[s] = gnorm[s]
                if not self._g0_preset[s]:
                    self._g0[s] = gnorm[s]
            self._rel[s] = gnorm[s] / max(self._g0[s], _FORCING_SENTINEL)
            converged = self._rel[s] <= self.cfg.gtol
            if converged or step_len[s] == 0.0 or self._newton[s] >= self.cfg.max_newton:
                retired.append(self._retire(s, converged))
        telemetry.emit(
            telemetry.ServeStepEvent(
                iteration=self.iterations,
                slots=self.slots,
                occupancy=int(active.sum()),
                queue_len=len(self.queue),
                refills=self.refills,
            )
        )
        return retired

    def run(self, verbose: bool = False) -> list[JobResult]:
        self._echo = verbose
        try:
            while self.queue or self.active.any():
                self.step()
        finally:
            self._echo = False
        return self.results

    def compiled_executables(self) -> int:
        return int(self.step_fn._cache_size())

    def emit_step_collectives(self, label: str = "cohort_step") -> None:
        """Emit per-kind collective counts for this bucket's step executable.

        Ahead-of-time lowering: does not populate the jit cache, so the
        one-executable pin of ``compiled_executables`` is unaffected.  No-op
        unless a telemetry sink is installed (lowering+compiling a second
        copy of the step is not free).
        """
        if not telemetry.enabled():
            return
        lowered = self.step_fn.lower(
            self._v,
            jnp.asarray(self._g_forcing),
            jnp.asarray(self.active),
            jnp.float32(self.cfg.beta),
            self._rho_R,
            self._rho_T,
        )
        telemetry.emit_collectives(label, lowered)


def serve_jobs(jobs: list[RegJob], cfg: gn.GNConfig, slots: int = 4,
               ops: SpectralOps | None = None, interp=None,
               verbose: bool = False) -> dict:
    """Bucket ``jobs`` by image shape and drain each bucket's server.

    Returns ``{"results": [JobResult...], "buckets": {shape: stats}}`` where
    each bucket reports its cohort step count and executable count (the
    one-executable invariant across all admissions).
    """
    buckets: dict[tuple, list[RegJob]] = {}
    for job in jobs:
        buckets.setdefault(tuple(job.rho_R.shape), []).append(job)
    results, stats = [], {}
    for shape, group in buckets.items():
        server = CohortServer(make_grid(shape), cfg, slots=slots, ops=ops, interp=interp)
        server.admit(*group)
        results += server.run(verbose=verbose)
        server.emit_step_collectives(f"cohort_step{shape}")
        stats[shape] = {
            "jobs": len(group),
            "cohort_iterations": server.iterations,
            "compiled_executables": server.compiled_executables(),
        }
    return {"results": results, "buckets": stats}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--size", type=int, default=16)
    ap.add_argument("--beta", type=float, default=1e-2)
    ap.add_argument("--n-t", type=int, default=4)
    ap.add_argument("--max-newton", type=int, default=8)
    ap.add_argument("--max-cg", type=int, default=30)
    ap.add_argument("--gtol", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", type=str, default=None,
                    help="write a telemetry JSONL trace to this path "
                         "(render with: python -m repro.analysis.trace_report)")
    args = ap.parse_args()

    from repro.data.synthetic import synthetic_problem

    cfg = gn.GNConfig(beta=args.beta, n_t=args.n_t, max_newton=args.max_newton,
                      max_cg=args.max_cg, gtol=args.gtol)
    rng = np.random.default_rng(args.seed)
    jobs = []
    for j in range(args.jobs):
        amp = float(rng.uniform(0.3, 1.0))
        rho_R, rho_T, _, _ = synthetic_problem(args.size, n_t=args.n_t, amplitude=amp)
        jobs.append(RegJob(job_id=f"job{j}(amp={amp:.2f})", rho_R=rho_R, rho_T=rho_T))

    import contextlib

    sink = telemetry.jsonl_sink(args.trace) if args.trace else contextlib.nullcontext()
    t0 = time.time()
    with sink:
        out = serve_jobs(jobs, cfg, slots=args.slots, verbose=True)
    dt = time.time() - t0
    for shape, st in out["buckets"].items():
        print(
            f"bucket {shape}: {st['jobs']} jobs in {st['cohort_iterations']} cohort "
            f"iterations, {st['compiled_executables']} compiled executable(s)"
        )
    total_mv = sum(r.hessian_matvecs for r in out["results"])
    print(f"served {len(out['results'])} jobs in {dt:.1f}s, {total_mv} matvecs total")


if __name__ == "__main__":
    main()

"""Production training driver: registration solves and LM training.

Fault-tolerant by construction:
  * checkpoints every --ckpt-every steps (atomic, keep-k, async),
  * auto-resumes from the latest checkpoint (bit-exact: data order is a
    pure function of step),
  * straggler watchdog: logs any step slower than ``--straggler-factor x``
    the EWMA step time and forces an immediate checkpoint (preempt-aware
    behavior on real clusters),
  * elastic: ``--mesh`` can change between restarts; the checkpoint stores
    logical specs and is re-sharded on load.

    PYTHONPATH=src python -m repro.launch.train --mode registration --grid 32
    PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen3-1.7b \
        --smoke --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic
from repro.data.tokens import TokenStream
from repro.models.common import ShardRules
from repro.optim import adamw
from repro.train.steps import build_model, make_train_step


def run_registration(args):
    if args.brain:
        rho_R, rho_T, grid = synthetic.brain_like(args.grid)
    else:
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(
            args.grid, incompressible=args.incompressible
        )
    cfg = RegistrationConfig(
        solver=gn.GNConfig(
            beta=args.beta,
            n_t=args.nt,
            incompressible=args.incompressible,
            max_newton=args.steps,
            gtol=args.gtol,
        )
    )
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    v0 = None
    if mgr and mgr.latest_step() is not None:
        state, meta = mgr.restore()
        v0 = state["v"]
        print(f"[resume] registration from Newton iter {meta['step']}")

    def cb(it, rec):
        if mgr and (it + 1) % args.ckpt_every == 0:
            mgr.save(it + 1, {"v": out_v[0]}, metadata=rec, blocking=False)

    out_v = [v0]
    t0 = time.time()
    out = register(rho_R, rho_T, cfg, grid=grid, verbose=True, v0=v0)
    out_v[0] = out["v"]
    if mgr:
        mgr.save(out["newton_iters"], {"v": out["v"]}, blocking=True)
    print(
        f"done in {time.time()-t0:.1f}s: newton={out['newton_iters']} "
        f"matvecs={out['hessian_matvecs']} residual_rel={out['residual_rel']:.4f} "
        f"det(grad y) in [{out['det_min']:.3f}, {out['det_max']:.3f}]"
    )
    return out


def run_lm(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rules = ShardRules(mesh)
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1), total_steps=args.steps,
        compress_grads=args.compress_grads,
    )
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    stream = TokenStream(seed=args.seed, batch=args.batch, seq=args.seq, vocab=cfg.vocab)

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if mgr and mgr.latest_step() is not None:
        state, meta = mgr.restore()
        params, opt_state = state["params"], state["opt"]
        start = meta["step"]
        print(f"[resume] from step {start}")
    else:
        params, _ = model.init(jax.random.PRNGKey(args.seed), rules)
        opt_state = adamw.init_state(params)

    ewma = None
    for s in range(start, args.steps):
        t0 = time.time()
        params, opt_state, m = step_fn(params, opt_state, stream(s))
        if s % args.log_every == 0:
            print(f"step {s:5d} loss {float(m['loss']):.4f} lr {float(m['lr']):.2e} "
                  f"gnorm {float(m['grad_norm']):.3f}")
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > args.straggler_factor * ewma and s > start + 5:
            print(f"[watchdog] step {s} took {dt:.2f}s (ewma {ewma:.2f}s) — "
                  f"checkpointing defensively")
            if mgr:
                mgr.save(s + 1, {"params": params, "opt": opt_state}, blocking=False)
        elif mgr and (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, {"params": params, "opt": opt_state}, blocking=False)
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state}, blocking=True)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["registration", "lm"], default="registration")
    # registration
    ap.add_argument("--grid", type=int, default=32)
    ap.add_argument("--beta", type=float, default=1e-2)
    ap.add_argument("--nt", type=int, default=4)
    ap.add_argument("--gtol", type=float, default=1e-2)
    ap.add_argument("--incompressible", action="store_true")
    ap.add_argument("--brain", action="store_true")
    # lm
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress-grads", action="store_true")
    # common
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args()
    if args.mode == "registration":
        run_registration(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()

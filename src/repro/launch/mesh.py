"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model") — for the
registration solver this IS the paper's p1 x p2 pencil grid; for the LM
architectures it is (data parallel+FSDP) x (tensor/expert parallel).

Multi-pod: 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an extra data-parallel dimension (LMs) / an ensemble axis of
independent registration problems (the paper's embarrassingly-parallel
multi-subject dimension).

Defined as functions (never module-level constants) so importing this
module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to materialize placeholder devices.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests (e.g. (2,4)/("data","model") on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.shape else 1


def mesh_axes_size(mesh, ax) -> int:
    """Device count behind one pencil dimension; the axis entry may be a
    tuple of mesh axis names, e.g. ("pod", "data")."""
    names = ax if isinstance(ax, tuple) else (ax,)
    size = 1
    for n in names:
        size *= int(mesh.shape[n])
    return size


def validate_mesh_for_grid(mesh, grid_shape, axes=("data", "model")) -> None:
    """Pencil decomposition requires the first two grid dims to divide."""
    p1, p2 = mesh_axes_size(mesh, axes[0]), mesh_axes_size(mesh, axes[1])
    n1, n2, n3 = grid_shape
    if n1 % p1 or n2 % p2:
        raise ValueError(f"grid {grid_shape} not divisible by pencil mesh ({p1},{p2})")
    # FFT transposes additionally need (paper Fig. 4 layout):
    if n2 % p1 or n3 % p2:
        raise ValueError(
            f"transposed pencil layout needs N2 % p1 == 0 and N3 % p2 == 0; "
            f"got grid {grid_shape}, mesh ({p1},{p2})"
        )

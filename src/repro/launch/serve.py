"""Batched serving driver: prefill + decode loop over the zoo.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 12 --gen-len 32

Production shapes are exercised via the dry-run (decode_32k / long_500k
cells); this driver runs reduced configs end-to-end on local devices.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.common import ShardRules
from repro.train.steps import build_model, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rules = ShardRules(mesh)
    params, _ = model.init(jax.random.PRNGKey(args.seed), rules)

    rng = np.random.default_rng(args.seed)
    b, pl_, gl = args.batch, args.prompt_len, args.gen_len
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, pl_)), jnp.int32)
    caches, _ = model.cache_init(b, pl_ + gl, rules)
    serve = jax.jit(make_serve_step(model))

    nxt = prompt[:, :1]
    for t in range(pl_):  # prefill (token-wise; batched prefill via forward())
        nxt, caches = serve(params, prompt[:, t : t + 1], jnp.int32(t), caches)
    t0 = time.time()
    out = []
    tok = nxt
    for t in range(pl_, pl_ + gl):
        tok, caches = serve(params, tok, jnp.int32(t), caches)
        out.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    print(f"arch={cfg.name} decoded {gl} tok/seq x {b} seqs in {dt:.2f}s "
          f"({b * gl / dt:.1f} tok/s)")
    print("seq0 token ids:", [int(x) for x in np.stack(out, 1)[0]])


if __name__ == "__main__":
    main()

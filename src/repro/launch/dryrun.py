"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage (CPU container; 512 placeholder devices):
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --registration --multi-pod

For each cell: ``jit(step).lower(**input_specs).compile()`` on the
production mesh (16x16 single-pod / 2x16x16 multi-pod), then prints
``compiled.memory_analysis()`` (fits-in-HBM proof) and harvests
``cost_analysis()`` + the HLO collective schedule for EXPERIMENTS
§Dry-run / §Roofline.  ShapeDtypeStructs only — nothing is allocated.
"""
# The first two statements MUST precede any jax import: jax locks the
# device count at first init.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs import REGISTRATION_GRIDS, get_config, list_archs
from repro.configs.common import SHAPES, batch_spec, is_cell_supported, token_inputs
from repro.launch.mesh import make_production_mesh
from repro.models.common import ShardRules
from repro.optim import adamw
from repro.train.steps import build_model, make_prefill_step, make_serve_step, make_train_step


def _with_sharding(shapes, specs, mesh):
    """Attach NamedShardings onto a ShapeDtypeStruct tree."""
    flat_s, tdef = jax.tree.flatten(shapes)
    flat_p = jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0]
    assert len(flat_s) == len(flat_p), (len(flat_s), len(flat_p))
    out = [
        jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=NamedSharding(mesh, p))
        for a, p in zip(flat_s, flat_p)
    ]
    return tdef.unflatten(out)


def _eval_shape_with_specs(fn, *args):
    """eval_shape a (tree, specs) returning fn; specs captured statically."""
    box = {}

    def wrapper(*a):
        tree, specs = fn(*a)
        box["specs"] = specs
        return tree

    shapes = jax.eval_shape(wrapper, *args)
    return shapes, box["specs"]


# --------------------------------------------------------------------------- #
# sharding/dispatch profiles (EXPERIMENTS §Perf hillclimbs)
# --------------------------------------------------------------------------- #
# "baseline" = the paper-faithful-by-default FSDP+TP rules.
# "optimized" = per-arch fixes found by the hypothesis->measure loop:
#   * dense <=8B archs: drop FSDP (params fit model-sharded); kills GSPMD's
#     contracting-dim activation all-reduces (the 2-9 TB/chip pathologies).
#   * qwen3-moe: 2-D expert weights (E over model, d_ff over data) +
#     token-sharded dispatch groups + explicit group-sharding hints ->
#     dispatch lowers to all-to-all instead of data-axis all-reduce.
#   * gemma3: block-local sliding-window attention is always on (exact);
#     the profile additionally drops FSDP.
PROFILES: dict = {
    "baseline": {},
    "optimized": {
        "gemma-7b": {"rules": {"fsdp": None}, "cfg": {"remat_policy": "dots"}},
        "gemma3-1b": {"rules": {"fsdp": None}},
        "minitron-4b": {"rules": {"fsdp": None}},
        "qwen3-1.7b": {"rules": {"fsdp": None}},
        "mamba2-130m": {"rules": {"fsdp": None}},
        "seamless-m4t-large-v2": {"rules": {"fsdp": None}},
        "zamba2-2.7b": {"rules": {"fsdp": None}},
        "moonshot-v1-16b-a3b": {
            "rules": {"fsdp": None, "moe_embed": None, "moe_ff": "data"},
            "cfg": {"moe_token_shard": 16},
        },
        "qwen3-moe-235b-a22b": {
            "rules": {"fsdp": None, "moe_embed": None, "moe_ff": "data"},
            "cfg": {"moe_token_shard": 16},
        },
    },
}


# --------------------------------------------------------------------------- #
# LM cells
# --------------------------------------------------------------------------- #
def _lower_one(cfg, shape, mesh, kind, rule_overrides=None):
    """Lower+compile one step program for a given depth-variant config."""
    rules = ShardRules(mesh, overrides=rule_overrides)
    model = build_model(cfg)
    pshapes, pspecs = _eval_shape_with_specs(
        lambda k: model.init(k, rules), jax.random.PRNGKey(0)
    )
    params_in = _with_sharding(pshapes, pspecs, mesh)
    inp_shapes, inp_specs = token_inputs(cfg, shape, mesh)
    batch_in = _with_sharding(inp_shapes, inp_specs, mesh)

    if kind == "train":
        opt_shapes = jax.eval_shape(adamw.init_state, pshapes)
        opt_in = _with_sharding(opt_shapes, adamw.state_specs(pspecs), mesh)
        step = make_train_step(model, adamw.AdamWConfig())
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params_in, opt_in, batch_in)
    elif kind == "prefill":
        step = make_prefill_step(model)
        lowered = jax.jit(step).lower(params_in, batch_in)
    else:  # decode: one new token against a seq_len-long cache
        b, s = shape["batch"], shape["seq"]
        if cfg.enc_layers:
            cshapes, cspecs = _eval_shape_with_specs(
                lambda: model.cache_init(b, s // 2, rules, enc_len=s // 2)
            )
        else:
            cshapes, cspecs = _eval_shape_with_specs(lambda: model.cache_init(b, s, rules))
        caches_in = _with_sharding(cshapes, cspecs, mesh)
        bspec = batch_spec(mesh, b)
        tok_in = jax.ShapeDtypeStruct(
            (b, 1), jnp.int32, sharding=NamedSharding(mesh, P(*bspec, None))
        )
        pos_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
        step = make_serve_step(model)
        lowered = jax.jit(step, donate_argnums=(3,)).lower(params_in, tok_in, pos_in, caches_in)
    return lowered.compile(), rules


def _depth_variant(cfg, n_groups: int):
    """Shallow UNROLLED variant: scan would hide per-layer cost again."""
    import dataclasses

    return dataclasses.replace(cfg, n_layers=len(cfg.layer_pattern) * n_groups,
                               enc_layers=(n_groups if cfg.enc_layers else 0),
                               scan_layers=False)


def lower_lm_cell(
    arch: str, shape_name: str, multi_pod: bool, verbose: bool = True, profile: str = "baseline"
) -> dict:
    import dataclasses as _dc

    from repro.models import hints

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = get_config(arch)
    prof = PROFILES.get(profile, {}).get(arch, {})
    rule_overrides = prof.get("rules")
    if prof.get("cfg"):
        cfg = _dc.replace(cfg, **prof["cfg"])
    hints.set_mesh(mesh if profile != "baseline" else None)
    shape = SHAPES[shape_name]
    ok, why = is_cell_supported(cfg, shape_name)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape["kind"],
        "profile": profile,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    kind = shape["kind"]
    n_active = cfg.active_param_count()
    tokens_per_step = shape["batch"] * shape["seq"]
    if kind == "train":
        model_flops = 6.0 * n_active * tokens_per_step
    elif kind == "prefill":
        model_flops = 2.0 * n_active * tokens_per_step
    else:
        model_flops = 2.0 * n_active * shape["batch"]

    t0 = time.time()
    # full-depth compile: THE dry-run artifact (sharding validity + memory)
    compiled, rules = _lower_one(cfg, shape, mesh, kind, rule_overrides)
    t_compile = time.time() - t0

    # XLA cost_analysis counts while-loop bodies ONCE (not x trip count), so
    # scanned layer stacks are undercounted.  All our stacks are homogeneous
    # per pattern group => two-point depth extrapolation is exact:
    #   cost(G) = cost(G=1) + (G-1) * [cost(G=2) - cost(G=1)]
    g_full = cfg.n_groups
    enc_scale = cfg.enc_layers if cfg.enc_layers else None
    g_full = enc_scale or g_full
    # depth probes feed the roofline table, which is single-pod only; the
    # multi-pod pass only has to prove the "pod" axis shards + memory fits.
    if g_full > 1 and not multi_pod:
        c1, _ = _lower_one(_depth_variant(cfg, 1), shape, mesh, kind, rule_overrides)
        c2, _ = _lower_one(_depth_variant(cfg, 2), shape, mesh, kind, rule_overrides)
        r1, coll1 = rl.analyze_compiled(c1, chips=chips)
        r2, coll2 = rl.analyze_compiled(c2, chips=chips)
        flops = r1.flops + (g_full - 1) * max(r2.flops - r1.flops, 0.0)
        nbytes = r1.hbm_bytes + (g_full - 1) * max(r2.hbm_bytes - r1.hbm_bytes, 0.0)
        cbytes = r1.collective_bytes + (g_full - 1) * max(
            r2.collective_bytes - r1.collective_bytes, 0.0
        )
        coll = {
            k: {
                "bytes": int(
                    coll1[k]["bytes"] + (g_full - 1) * max(coll2[k]["bytes"] - coll1[k]["bytes"], 0)
                ),
                "count": coll1[k]["count"]
                + (g_full - 1) * max(coll2[k]["count"] - coll1[k]["count"], 0),
            }
            for k in coll1
            if isinstance(coll1[k], dict)
        }
        roof = rl.Roofline(
            flops=flops, hbm_bytes=nbytes, collective_bytes=cbytes,
            chips=chips, model_flops=model_flops,
            hbm_bytes_model=rl.analytic_memory_bytes(cfg, shape, chips),
        )
    else:
        roof, coll = rl.analyze_compiled(compiled, chips=chips, model_flops=model_flops)
        roof.hbm_bytes_model = rl.analytic_memory_bytes(cfg, shape, chips)
    mem = rl.memory_analysis_dict(compiled)
    rec.update(
        {
            "status": "ok",
            "t_compile_s": round(t_compile, 2),
            "params_total": cfg.param_count(),
            "params_active": n_active,
            "sharding_fallbacks": [f"{l}:{d}" for l, d, _ in rules.fallbacks],
            "memory": mem,
            "collectives": {
                k: v for k, v in coll.items() if isinstance(v, dict) and v["count"]
            },
            "roofline": roof.to_dict(),
        }
    )
    if verbose:
        print(f"--- {arch} x {shape_name} on {rec['mesh']} ---")
        print("memory_analysis:", mem)
        print(
            f"cost: flops/chip={roof.flops:.3e} bytes/chip={roof.hbm_bytes:.3e} "
            f"coll_bytes/chip={roof.collective_bytes:.3e}"
        )
        print(
            f"roofline: compute={roof.t_compute:.4f}s memory={roof.t_memory_model:.4f}s "
            f"(xla-ub {roof.t_memory:.4f}s) collective={roof.t_collective:.4f}s "
            f"-> {roof.bottleneck}"
            f" | useful-flops={roof.useful_flops_ratio:.3f} mfu_bound={roof.mfu_bound:.3f}"
        )
    return rec


# --------------------------------------------------------------------------- #
# registration cells (the paper's own workload)
# --------------------------------------------------------------------------- #
def _reg_component_costs(grid, ctx, rcfg, mesh, chips):
    """Per-component roofline via n_t two-point extrapolation.

    XLA's cost analysis gives FFTs zero flops and counts scan bodies once,
    so: (i) bytes & collective bytes come from compiling the gradient eval
    and one GN Hessian matvec at n_t=1 and n_t=2 and extrapolating to the
    paper's n_t=4; (ii) flops use the paper's analytic model
    (§III-C4: a 3-D FFT is 2.5 * 3 * N^3 log2 N flops, interpolation is
    ~600 flops/point).  Collective bytes split all-to-all (FFT transpose)
    vs collective-permute (interpolation halo) — the paper's own
    FFT-comm / interp-comm table columns.
    """
    import dataclasses as _dc

    from repro.core import objective as obj

    sshape = jax.ShapeDtypeStruct(grid.shape, jnp.float32, sharding=ctx.scalar_sharding())
    vshape = jax.ShapeDtypeStruct((3,) + grid.shape, jnp.float32, sharding=ctx.vector_sharding())

    def costs_at(n_t: int):
        prob_kw = dict(grid=grid, beta=rcfg.beta, incompressible=rcfg.incompressible)

        def grad_eval(v, rho_R, rho_T):
            prob = obj.Problem(rho_R=rho_R, rho_T=rho_T, n_t=n_t, **prob_kw)
            st = obj.newton_state(v, prob, ctx.ops, ctx.interp)
            return st.g

        def matvec(vt, v, rho_R, rho_T):
            prob = obj.Problem(rho_R=rho_R, rho_T=rho_T, n_t=n_t, **prob_kw)
            st = obj.newton_state(v, prob, ctx.ops, ctx.interp)
            return obj.gn_hessian_matvec(vt, st, prob, ctx.ops, ctx.interp)

        cg = jax.jit(grad_eval).lower(vshape, sshape, sshape).compile()
        cm = jax.jit(matvec).lower(vshape, vshape, sshape, sshape).compile()
        rg, collg = rl.analyze_compiled(cg, chips=chips)
        rm, collm = rl.analyze_compiled(cm, chips=chips)
        # matvec-only = (state+matvec) - state
        return rg, collg, rm, collm

    g1, cg1, m1, cm1 = costs_at(1)
    g2, cg2, m2, cm2 = costs_at(2)
    nt = rcfg.n_t

    def extrap(a, b):
        return a + (nt - 1) * max(b - a, 0.0)

    def extrap_coll(c1, c2):
        return {
            k: {
                "bytes": int(c1[k]["bytes"] + (nt - 1) * max(c2[k]["bytes"] - c1[k]["bytes"], 0)),
                "count": c1[k]["count"] + (nt - 1) * max(c2[k]["count"] - c1[k]["count"], 0),
            }
            for k in c1
            if isinstance(c1[k], dict)
        }

    grad_bytes = extrap(g1.hbm_bytes, g2.hbm_bytes)
    grad_coll = extrap_coll(cg1, cg2)
    mv_bytes = extrap(m1.hbm_bytes, m2.hbm_bytes) - grad_bytes  # isolate the matvec
    mv_coll = {
        k: {
            "bytes": max(extrap_coll(cm1, cm2)[k]["bytes"] - grad_coll[k]["bytes"], 0),
            "count": max(extrap_coll(cm1, cm2)[k]["count"] - grad_coll[k]["count"], 0),
        }
        for k in grad_coll
    }

    # paper's analytic flops (per chip): gradient ~ 2 transports + elliptic
    # ops; matvec ~ 8 n_t FFTs + 4 n_t interpolations (§III-C4)
    n3 = grid.num_points
    log_n = max(grid.shape[0].bit_length() - 1, 1)
    fft_flops = 7.5 * n3 * log_n  # one 3-D FFT (paper's constant)
    interp_flops = 600.0 * n3
    mv_flops = (8 * nt * fft_flops + 4 * nt * interp_flops) / chips
    grad_flops = (6 * nt * fft_flops + 2 * nt * interp_flops + 8 * fft_flops) / chips
    return {
        "gradient": {
            "flops_analytic_per_chip": grad_flops,
            "hbm_bytes_per_chip": grad_bytes,
            "collectives": grad_coll,
            "t_compute_s": grad_flops / rl.PEAK_FLOPS,
            "t_memory_s": grad_bytes / rl.HBM_BW,
            "t_collective_s": sum(v["bytes"] for v in grad_coll.values()) / rl.ICI_BW,
        },
        "hessian_matvec": {
            "flops_analytic_per_chip": mv_flops,
            "hbm_bytes_per_chip": mv_bytes,
            "collectives": mv_coll,
            "t_compute_s": mv_flops / rl.PEAK_FLOPS,
            "t_memory_s": mv_bytes / rl.HBM_BW,
            "t_collective_s": sum(v["bytes"] for v in mv_coll.values()) / rl.ICI_BW,
        },
    }


def lower_registration_cell(name: str, multi_pod: bool, verbose: bool = True, rcfg=None) -> dict:
    from repro.core import gauss_newton as gn
    from repro.core import objective as obj
    from repro.core.grid import make_grid
    from repro.dist.context import DistContext

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rcfg = rcfg or REGISTRATION_GRIDS[name]
    grid = make_grid(rcfg.grid)
    axes = (("pod", "data"), "model") if multi_pod else ("data", "model")
    ctx = DistContext(grid, mesh, axes=axes, halo=rcfg.halo)
    cfg = gn.GNConfig(beta=rcfg.beta, n_t=rcfg.n_t, incompressible=rcfg.incompressible)

    def reg_step(v, g0, rho_R, rho_T):
        prob = obj.Problem(
            grid=grid,
            rho_R=rho_R,
            rho_T=rho_T,
            beta=rcfg.beta,
            n_t=rcfg.n_t,
            incompressible=rcfg.incompressible,
        )
        return gn.newton_iteration(v, g0, prob, ctx.ops, cfg, interp=ctx.interp)

    vshape = jax.ShapeDtypeStruct(
        (3,) + grid.shape, jnp.float32, sharding=ctx.vector_sharding()
    )
    sshape = jax.ShapeDtypeStruct(
        grid.shape, jnp.float32, sharding=ctx.scalar_sharding()
    )
    g0 = jax.ShapeDtypeStruct((), jnp.float32, sharding=NamedSharding(mesh, P()))

    t0 = time.time()
    lowered = jax.jit(reg_step, donate_argnums=(0,)).lower(vshape, g0, sshape, sshape)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = rl.memory_analysis_dict(compiled)
    rec = {
        "arch": name,
        "shape": "x".join(map(str, rcfg.grid)),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": "gn_newton_iteration",
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": mem,
    }
    # component probes (4 extra compiles) only on single-pod, and only for
    # grids <= 256^3-class: bytes/collectives scale linearly per shard, so
    # 512^3/1024^3 rows are extrapolated in EXPERIMENTS from the 256^3 probe.
    if not multi_pod and grid.num_points <= 256**3 * 1.2:
        rec["components"] = _reg_component_costs(grid, ctx, rcfg, mesh, chips)
    if verbose:
        print(f"--- {name} ({rec['shape']}) on {rec['mesh']} ---")
        print("memory_analysis:", mem)
        for comp, c in rec.get("components", {}).items():
            print(
                f"  {comp}: compute={c['t_compute_s']:.5f}s memory={c['t_memory_s']:.5f}s "
                f"collective={c['t_collective_s']:.5f}s"
            )
    return rec


def lower_multilevel_cell(name: str, multi_pod: bool, verbose: bool = True, rcfg=None) -> dict:
    """Lower+compile every level of a coarse-to-fine ladder on the mesh.

    Per level: the GN ``newton_iteration`` program on the level's derived
    ``DistContext`` (coarse matvecs are 8-64x cheaper — the grid-continuation
    lever) plus the spectral prolongation program that carries the warm start
    up the ladder (pencil-FFT truncation/zero-pad; its all-to-all bytes are
    the ladder's only extra communication).
    """
    from repro.core import gauss_newton as gn
    from repro.core import objective as obj
    from repro.core.grid import make_grid
    from repro.dist.context import DistContext
    from repro.multilevel import transfer

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rcfg = rcfg or REGISTRATION_GRIDS[name]
    if not rcfg.levels:
        raise ValueError(f"{name} has no multilevel ladder configured")
    axes = (("pod", "data"), "model") if multi_pod else ("data", "model")
    fine_grid = make_grid(rcfg.grid)
    fine_ctx = DistContext(fine_grid, mesh, axes=axes, halo=rcfg.halo)
    cfg = gn.GNConfig(beta=rcfg.beta, n_t=rcfg.n_t, incompressible=rcfg.incompressible)

    rec = {
        "arch": name,
        "shape": "x".join(map(str, rcfg.grid)),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": "multilevel_ladder",
        "status": "ok",
        "levels": [],
    }
    prev_ctx = None
    for shape in rcfg.levels:
        shape = tuple(shape)
        grid = fine_grid if shape == fine_grid.shape else make_grid(shape)
        ctx = fine_ctx if shape == fine_grid.shape else fine_ctx.coarsen(shape)

        def reg_step(v, g0, rho_R, rho_T, _grid=grid, _ctx=ctx):
            prob = obj.Problem(
                grid=_grid, rho_R=rho_R, rho_T=rho_T, beta=rcfg.beta,
                n_t=rcfg.n_t, incompressible=rcfg.incompressible,
            )
            return gn.newton_iteration(v, g0, prob, _ctx.ops, cfg, interp=_ctx.interp)

        vshape = jax.ShapeDtypeStruct((3,) + grid.shape, jnp.float32, sharding=ctx.vector_sharding())
        sshape = jax.ShapeDtypeStruct(grid.shape, jnp.float32, sharding=ctx.scalar_sharding())
        g0 = jax.ShapeDtypeStruct((), jnp.float32, sharding=NamedSharding(mesh, P()))
        t0 = time.time()
        compiled = jax.jit(reg_step, donate_argnums=(0,)).lower(vshape, g0, sshape, sshape).compile()
        t_newton = time.time() - t0
        level_rec = {
            "shape": list(shape),
            "t_compile_s": round(t_newton, 2),
            "memory": rl.memory_analysis_dict(compiled),
            "fine_equiv_matvec_weight": grid.num_points / fine_grid.num_points,
        }

        if prev_ctx is not None:  # the warm-start prolongation program
            pv = jax.ShapeDtypeStruct(
                (3,) + prev_ctx.grid.shape, jnp.float32, sharding=prev_ctx.vector_sharding()
            )
            cp = jax.jit(
                lambda v, _a=prev_ctx.ops, _b=ctx.ops: transfer.prolong(v, _a, _b)
            ).lower(pv).compile()
            _, coll = rl.analyze_compiled(cp, chips=chips)
            level_rec["prolong_collectives"] = {
                k: v for k, v in coll.items() if isinstance(v, dict) and v["count"]
            }
        rec["levels"].append(level_rec)
        prev_ctx = ctx
        if verbose:
            print(f"--- {name} level {shape} on {rec['mesh']} ---")
            print("memory_analysis:", level_rec["memory"])
    return rec


# --------------------------------------------------------------------------- #
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--registration", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--profile", default="baseline", choices=list(PROFILES))
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []

    def flush():
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out + ".tmp", "w") as f:
                json.dump(records, f, indent=1)
            os.replace(args.out + ".tmp", args.out)

    def run(fn, *a):
        try:
            records.append(fn(*a))
        except Exception as e:  # a failing cell is a bug — record it loudly
            traceback.print_exc()
            records.append({"args": [str(x) for x in a], "status": "FAILED", "error": str(e)})
        flush()  # incremental: partial sweeps survive interruption

    for mp in meshes:
        if args.registration:
            regs = [
                "claire-256", "claire-512", "claire-1024", "claire-256-inc",
                "claire-brain", "claire-256-ml", "claire-512-ml",
            ]
            for name in regs:
                if REGISTRATION_GRIDS[name].levels:
                    run(lower_multilevel_cell, name, mp)
                else:
                    run(lower_registration_cell, name, mp)
        if args.all:
            for arch in list_archs():
                for shape in SHAPES:
                    run(lower_lm_cell, arch, shape, mp, True, args.profile)
        elif args.arch:
            shapes = [args.shape] if args.shape else list(SHAPES)
            for shape in shapes:
                run(lower_lm_cell, args.arch, shape, mp, True, args.profile)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    n_fail = sum(1 for r in records if r.get("status") == "FAILED")
    print(f"cells: {len(records)}  ok: {sum(1 for r in records if r.get('status')=='ok')} "
          f"skipped: {sum(1 for r in records if r.get('status')=='skipped')}  FAILED: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Telemetry sinks: JSON-lines file sink and the console renderer.

``JsonlSink`` is the durable feed (one schema-versioned JSON object per
line, append-only, flushed per event so a killed run keeps its trace);
``ConsoleSink`` is the single renderer behind every ``verbose=`` knob in
the repo — the solver/ladder/server layers emit events and this module
turns them into exactly the progress lines those layers used to ``print``,
so default output is unchanged while the same event stream also lands in
the JSONL trace.
"""
from __future__ import annotations

import json
import os
import sys
from typing import IO


class JsonlSink:
    """Append schema-versioned records to ``path``, one JSON object per line.

    Usable as a context manager (``with telemetry.jsonl_sink(p): ...``)
    which installs/removes itself from the global sink registry, or
    directly via ``telemetry.add_sink``.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f: IO[str] = open(self.path, "a")
        self.n_written = 0

    def write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self.n_written += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        from repro.telemetry import runtime

        runtime.add_sink(self)
        return self

    def __exit__(self, *exc):
        from repro.telemetry import runtime

        runtime.remove_sink(self)
        self.close()
        return False


# --------------------------------------------------------------------------- #
# console rendering — the one place progress-line formats live
# --------------------------------------------------------------------------- #
def _fmt_seq(x):
    return tuple(x) if isinstance(x, (list, tuple)) else x


def render(rec: dict) -> str | None:
    """Legacy progress line for ``rec``, or None if the kind has no line."""
    kind = rec.get("kind")
    if kind == "newton_iter":
        if rec.get("subjects"):
            live = sum(1 for a in (rec.get("active") or []) if a)
            rel = rec["rel_gnorm"]
            return (
                f"[beta={rec['beta']:.0e}] it={rec['iter']:2d} "
                f"live={live}/{rec['subjects']} "
                f"max|g|/|g0|={max(rel):.3e} "
                f"cg={rec['cg_iters']}"
            )
        return (
            f"[beta={rec['beta']:.0e}] it={rec['iter']:2d} J={rec['j_val']:.4e} "
            f"misfit={rec['misfit']:.4e} |g|/|g0|={rec['rel_gnorm']:.3e} "
            f"cg={rec['cg_iters']} step={rec['step_len']:.3f}"
        )
    if kind == "level_start":
        return (
            f"=== level {rec['level']}/{rec['n_levels'] - 1}: "
            f"{_fmt_seq(rec['shape'])} betas={_fmt_seq(rec['betas'])} "
            f"warm={rec['warm_start']} ==="
        )
    if kind == "job":
        return (
            f"  retired job={rec['job_id']} newton={rec['newton_iters']} "
            f"matvecs={rec['hessian_matvecs']} |g|/|g0|={rec['rel_gnorm']:.2e}"
            f"{'' if rec['converged'] else ' (not converged)'}"
        )
    if kind == "counter":
        if rec["name"] == "halo_budget_exceeded":
            a = rec.get("attrs", {})
            return (
                f"halo-interp overflow: required halo {a.get('required')} > "
                f"budget {a.get('budget')} ({a.get('mode')})"
            )
        return f"[counter] {rec['name']}={rec['value']} total={rec['total']}"
    if kind == "span":
        return f"[span] {rec['path'] or rec['name']}: {rec['wall_s']:.4f}s"
    if kind == "serve_step":
        return (
            f"[serve] it={rec['iteration']} occupancy={rec['occupancy']}/"
            f"{rec['slots']} queue={rec['queue_len']} refills={rec['refills']}"
        )
    if kind == "level":
        return (
            f"[level {rec['level']}] newton={rec['newton_iters']} "
            f"matvecs={rec['hessian_matvecs']} "
            f"fine_equiv={rec['fine_equiv_matvecs']:.1f} "
            f"wall={rec['wall_s']:.2f}s"
        )
    if kind == "bench":
        return f"[bench] {rec['name']},{rec['us_per_call']:.1f},{rec['derived']}"
    return None


# event kinds rendered per verbosity level; level 2 adds the firehose
_LEVEL1 = ("newton_iter", "level_start", "job", "counter")
_LEVEL2 = _LEVEL1 + ("span", "serve_step", "level", "bench", "solve", "collectives")


class ConsoleSink:
    """Render events as the legacy progress lines behind a verbosity knob.

    ``verbosity=1`` shows what ``verbose=True`` used to print (per-iteration
    progress, level headers, job retirements, overflow warnings);
    ``verbosity=2`` additionally prints spans, serve occupancy, level
    summaries, and bench rows.
    """

    def __init__(self, verbosity: int = 1, stream: IO[str] | None = None):
        self.verbosity = verbosity
        self.stream = stream if stream is not None else sys.stdout

    def write(self, rec: dict) -> None:
        kinds = _LEVEL2 if self.verbosity >= 2 else _LEVEL1
        if rec.get("kind") not in kinds:
            return
        line = render(rec)
        if line is not None:
            print(line, file=self.stream)


class ListSink:
    """In-memory sink (tests / programmatic consumers)."""

    def __init__(self):
        self.records: list[dict] = []

    def write(self, rec: dict) -> None:
        self.records.append(rec)

    def __enter__(self):
        from repro.telemetry import runtime

        runtime.add_sink(self)
        return self

    def __exit__(self, *exc):
        from repro.telemetry import runtime

        runtime.remove_sink(self)
        return False

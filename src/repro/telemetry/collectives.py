"""Collective counting on lowered/compiled programs, as a first-class API.

Before this module every counted-collective pin re-derived its numbers
inline from HLO text (``tests/test_coalesce.py``, ``benchmarks/fft_suite``,
``benchmarks/interp_suite`` each had a private counter).
``count_collectives`` is the shared path: give it a ``jax.stages.Lowered``
(compiled on demand), a ``Compiled``, or optimized-HLO text, and get the
per-kind ``{"count", "bytes"}`` table that the byte parser of
``repro.analysis.roofline`` extracts — all-to-all, collective-permute,
all-gather, all-reduce, reduce-scatter, plus ``total_bytes``/``total_count``.

``emit_collectives`` attaches that table to the telemetry stream as a
labelled ``collectives`` event — how a serve/benchmark run records the
communication structure of the program it kept hot, next to the wall-clock
and matvec meters ``trace_report`` renders.
"""
from __future__ import annotations

from typing import Any


def hlo_text(obj: Any) -> str:
    """Optimized-HLO text from a Lowered / Compiled / str."""
    if isinstance(obj, str):
        return obj
    import jax

    if isinstance(obj, jax.stages.Lowered):
        # .as_text() on a Lowered is pre-SPMD StableHLO — collectives are
        # only final (and byte-annotated) after compilation
        return obj.compile().as_text()
    if hasattr(obj, "as_text"):
        return obj.as_text()
    raise TypeError(
        f"count_collectives wants a jax Lowered/Compiled or HLO text, got {type(obj)}"
    )


def count_collectives(obj: Any) -> dict:
    """Per-kind collective counts and output bytes of a compiled program."""
    from repro.analysis.roofline import parse_collective_bytes

    out = parse_collective_bytes(hlo_text(obj))
    out["total_count"] = sum(
        v["count"] for v in out.values() if isinstance(v, dict)
    )
    return out


def emit_collectives(label: str, obj: Any, echo: bool = False) -> dict:
    """Count collectives on ``obj`` and emit them as a telemetry event."""
    from repro.telemetry import events as ev
    from repro.telemetry import runtime

    coll = count_collectives(obj)
    runtime.emit(ev.CollectivesEvent(label=label, collectives=coll), echo=echo)
    return coll

"""``repro.telemetry`` — the measurement substrate (ISSUE 7 tentpole).

Structured tracing, counters, and per-job metrics for every layer:

* ``span(name)`` — nestable wall-clock timer (``block_until_ready`` at
  exit via ``sp.sync(x)``); near-zero overhead and zero trace-graph impact
  when no sink is installed; optional ``jax.profiler.TraceAnnotation``
  bridge (``configure(profiler=True)``).
* typed events (``events.py``) with a versioned JSON-lines schema —
  Newton iterations, ladder levels, serve jobs, counters, collectives,
  bench rows — validated by ``validate_record`` (the CI contract).
* sinks: ``jsonl_sink(path)`` (the durable trace ``trace_report`` reads),
  ``console_sink(verbosity)`` (the single renderer behind every
  ``verbose=`` knob), ``ListSink`` (tests).
* ``count_collectives(lowered)`` — the HLO collective counting the tests
  and benchmark suites used to re-derive privately, as a reusable API.

Typical run capture::

    from repro import telemetry
    with telemetry.jsonl_sink("results/run.jsonl"):
        out = multilevel.solve(rho_R, rho_T, grid, cfg)
    # then: python -m repro.analysis.trace_report results/run.jsonl
"""
from repro.telemetry.collectives import count_collectives, emit_collectives, hlo_text
from repro.telemetry.events import (
    SCHEMA_VERSION,
    BenchEvent,
    CollectivesEvent,
    CounterEvent,
    Event,
    FaultEvent,
    JobEvent,
    LevelEvent,
    LevelStartEvent,
    NewtonIterEvent,
    RecoveryEvent,
    ServeStepEvent,
    SolveEvent,
    SpanEvent,
    validate_record,
)
from repro.telemetry.runtime import (
    add_sink,
    annotate,
    configure,
    configure_from_env,
    console_sink,
    counter,
    counters,
    emit,
    enabled,
    jsonl_sink,
    remove_sink,
    reset_counters,
    sinks,
    span,
)
from repro.telemetry.sinks import ConsoleSink, JsonlSink, ListSink, render

__all__ = [
    "SCHEMA_VERSION",
    "Event",
    "SpanEvent",
    "NewtonIterEvent",
    "LevelEvent",
    "LevelStartEvent",
    "JobEvent",
    "ServeStepEvent",
    "CounterEvent",
    "CollectivesEvent",
    "BenchEvent",
    "SolveEvent",
    "FaultEvent",
    "RecoveryEvent",
    "validate_record",
    "span",
    "annotate",
    "emit",
    "counter",
    "counters",
    "reset_counters",
    "enabled",
    "sinks",
    "add_sink",
    "remove_sink",
    "configure",
    "configure_from_env",
    "jsonl_sink",
    "console_sink",
    "render",
    "JsonlSink",
    "ConsoleSink",
    "ListSink",
    "count_collectives",
    "emit_collectives",
    "hlo_text",
]

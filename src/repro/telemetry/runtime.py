"""Telemetry runtime: sink registry, span/timer API, counters.

Design constraints (the ISSUE 7 tentpole):

* **near-zero overhead, zero trace-graph impact when disabled** — with no
  sink installed, ``span.__enter__``/``__exit__`` are two attribute checks
  and ``emit`` is one; nothing here ever inserts an op into a traced
  program (spans live in the Python driver loops, ``annotate`` is pure
  HLO-metadata ``jax.named_scope``), so enabling/disabling telemetry can
  not change compiled executables or counted collectives (pinned by
  ``tests/test_telemetry.py``);
* **honest wall-clock** — a span calls ``jax.block_until_ready`` on
  whatever the caller registered via ``sp.sync(x)`` before reading the
  clock, so async dispatch does not attribute one phase's device time to
  the next;
* **profiler bridge** — ``configure(profiler=True)`` additionally opens a
  ``jax.profiler.TraceAnnotation`` per span so the same phase names show
  up in TensorBoard/Perfetto traces.
"""
from __future__ import annotations

import os
import time
from typing import Any

from repro.telemetry import events as ev
from repro.telemetry import sinks as _sinks

_SINKS: list[Any] = []
_COUNTERS: dict[str, float] = {}
_SPAN_STACK: list[str] = []
_PROFILER_BRIDGE = False

ENV_TRACE = "REPRO_TRACE"  # path of a JSONL trace to auto-install
ENV_VERBOSITY = "REPRO_TELEMETRY_VERBOSITY"  # >0: auto console sink


def add_sink(sink: Any) -> Any:
    """Register ``sink`` (any object with ``write(record: dict)``)."""
    _SINKS.append(sink)
    return sink


def remove_sink(sink: Any) -> None:
    if sink in _SINKS:
        _SINKS.remove(sink)


def sinks() -> tuple:
    return tuple(_SINKS)


def enabled() -> bool:
    """True when at least one sink is installed (spans measure, events land)."""
    return bool(_SINKS)


def configure(profiler: bool | None = None) -> None:
    global _PROFILER_BRIDGE
    if profiler is not None:
        _PROFILER_BRIDGE = bool(profiler)


def configure_from_env() -> None:
    """Install sinks from the environment (CLI entry points call this):
    ``REPRO_TRACE=path.jsonl`` adds a JSONL sink, and
    ``REPRO_TELEMETRY_VERBOSITY=1|2`` adds a console sink."""
    path = os.environ.get(ENV_TRACE)
    if path:
        add_sink(_sinks.JsonlSink(path))
    verb = int(os.environ.get(ENV_VERBOSITY, "0") or 0)
    if verb > 0:
        add_sink(_sinks.ConsoleSink(verbosity=verb))


def emit(event: ev.Event, echo: bool = False) -> dict | None:
    """Send ``event`` to every sink; with ``echo=True`` also render its
    legacy console line (unless a ConsoleSink is installed — no doubles).

    Returns the emitted record, or None when telemetry was a no-op."""
    if not _SINKS and not echo:
        return None
    rec = event.to_record()
    for s in _SINKS:
        s.write(rec)
    if echo and not any(isinstance(s, _sinks.ConsoleSink) for s in _SINKS):
        line = _sinks.render(rec)
        if line is not None:
            print(line)
    return rec


def counter(name: str, value: float = 1.0, echo: bool = False, **attrs) -> float:
    """Accumulate a named counter and emit a CounterEvent when enabled.

    The process-local total survives with telemetry disabled, so hot paths
    (e.g. the halo-overflow poison branch via ``jax.debug.callback``) can
    always count and a later ``telemetry.counters()`` read still sees them.
    """
    total = _COUNTERS.get(name, 0.0) + float(value)
    _COUNTERS[name] = total
    emit(ev.CounterEvent(name=name, value=float(value), total=total, attrs=attrs),
         echo=echo)
    return total


def counters() -> dict[str, float]:
    return dict(_COUNTERS)


def reset_counters() -> None:
    _COUNTERS.clear()


class span:
    """Nestable wall-clock span: ``with telemetry.span("pcg") as sp: ...``.

    Disabled (no sinks): ``__enter__`` returns immediately — no clock read,
    no block, no event.  Enabled: the exit path ``block_until_ready``s
    whatever was registered with ``sp.sync(x)`` (pass the jit outputs of the
    timed region) before reading the clock, emits a SpanEvent carrying the
    slash-joined nesting path, and — when the profiler bridge is on — the
    region also appears as a ``jax.profiler.TraceAnnotation``.
    """

    __slots__ = ("name", "attrs", "wall_s", "_t0", "_sync", "_ta")

    def __init__(self, name: str, **attrs):
        self.name = name
        self.attrs = attrs
        self.wall_s: float | None = None
        self._t0: float | None = None
        self._sync = None
        self._ta = None

    def sync(self, x):
        """Register ``x`` to be ``block_until_ready``'d at span exit."""
        self._sync = x
        return x

    def __enter__(self):
        if not _SINKS:
            return self
        if _PROFILER_BRIDGE:
            import jax

            self._ta = jax.profiler.TraceAnnotation(self.name)
            self._ta.__enter__()
        _SPAN_STACK.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._t0 is None:
            return False
        try:
            if exc_type is None and self._sync is not None:
                import jax

                jax.block_until_ready(self._sync)
            self.wall_s = time.perf_counter() - self._t0
            path = "/".join(_SPAN_STACK)
            depth = len(_SPAN_STACK) - 1
        finally:
            if _SPAN_STACK and _SPAN_STACK[-1] == self.name:
                _SPAN_STACK.pop()
            if self._ta is not None:
                self._ta.__exit__(exc_type, exc, tb)
                self._ta = None
        if exc_type is None:
            emit(ev.SpanEvent(name=self.name, wall_s=self.wall_s, path=path,
                              depth=depth, attrs=self.attrs))
        return False


def annotate(name: str):
    """Name a region INSIDE traced code: pure HLO-metadata ``named_scope``.

    Safe on the hot path — affects op metadata only (profiles and HLO dumps
    show the phase), never the graph structure, executables, or collectives.
    """
    import jax

    return jax.named_scope(name)


def jsonl_sink(path) -> _sinks.JsonlSink:
    """A JSON-lines sink for ``path`` (context manager installs/removes it)."""
    return _sinks.JsonlSink(path)


def console_sink(verbosity: int = 1, stream=None) -> _sinks.ConsoleSink:
    return _sinks.ConsoleSink(verbosity=verbosity, stream=stream)

"""Typed telemetry events and the versioned JSON-lines record schema.

Every record written by a sink is one JSON object per line:

    {"v": 1, "ts": <unix seconds>, "kind": "<event kind>", ...payload}

``v`` is ``SCHEMA_VERSION`` — bumped whenever a required field is added,
removed, or retyped, so downstream consumers (``repro.analysis.trace_report``,
the serving dashboard) can reject traces they do not understand instead of
mis-parsing them.  ``validate_record`` is the schema contract: it is what
``scripts/ci.sh`` runs over every emitted event, and what the
schema-stability test in ``tests/test_telemetry.py`` pins.

The event classes replace the dict soup the solver layers used to pass
around: each carries exactly the meters that layer owns (``gn.solve`` — the
Newton/PCG/Armijo counters; ``multilevel.solve`` — per-level matvec billing;
``launch.reg_serve`` — per-job queue-wait/slot/billing).  Cohort-shaped
emitters put per-subject lists in the same fields a single solve puts
scalars in; ``subjects`` disambiguates.
"""
from __future__ import annotations

import dataclasses
import numbers
import time
from typing import Any, ClassVar

SCHEMA_VERSION = 1


def _clean(x):
    """JSON-ready copy: numpy/jax scalars -> python, arrays -> lists."""
    if isinstance(x, dict):
        return {str(k): _clean(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_clean(v) for v in x]
    if isinstance(x, bool) or x is None or isinstance(x, (str, int, float)):
        return x
    if isinstance(x, numbers.Integral):
        return int(x)
    if isinstance(x, numbers.Real):
        return float(x)
    if hasattr(x, "tolist"):  # numpy / jax array or scalar
        return _clean(x.tolist())
    if hasattr(x, "item"):
        return _clean(x.item())
    return str(x)


@dataclasses.dataclass
class Event:
    """Base event: subclasses set ``kind`` and declare payload fields."""

    kind: ClassVar[str] = ""

    def to_record(self) -> dict:
        rec = {"v": SCHEMA_VERSION, "ts": time.time(), "kind": self.kind}
        for f in dataclasses.fields(self):
            rec[f.name] = _clean(getattr(self, f.name))
        return rec


@dataclasses.dataclass
class SpanEvent(Event):
    """Closed ``telemetry.span``: wall-clock after ``block_until_ready``."""

    kind: ClassVar[str] = "span"
    name: str
    wall_s: float
    path: str = ""  # slash-joined nesting, e.g. "multilevel.solve/gn.solve"
    depth: int = 0
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class NewtonIterEvent(Event):
    """One Newton iteration of ``gn.solve`` (scalars) or ``gn.solve_cohort``
    (per-subject lists in the same fields; ``subjects`` > 0)."""

    kind: ClassVar[str] = "newton_iter"
    source: str  # "gn.solve" | "gn.solve_cohort" | "reg_serve"
    beta: float
    iter: int
    j_val: Any
    misfit: Any
    reg: Any
    gnorm: Any
    rel_gnorm: Any
    cg_iters: Any  # the paper's Table V matvec meter
    step_len: Any
    armijo_trials: int = 0
    wall_s: float | None = None
    level: int | None = None  # set by the multilevel driver's callback
    subjects: int = 0  # 0: single solve; >0: cohort width S
    active: Any = None  # cohort live mask


@dataclasses.dataclass
class LevelEvent(Event):
    """One completed ladder level of ``multilevel.solve``."""

    kind: ClassVar[str] = "level"
    level: int
    shape: list
    betas: list
    warm_start: bool
    newton_iters: int
    hessian_matvecs: int
    fine_equiv_matvecs: float
    precond_fine_equiv_matvecs: float
    wall_s: float
    rel_gnorm: float | None = None


@dataclasses.dataclass
class LevelStartEvent(Event):
    kind: ClassVar[str] = "level_start"
    level: int
    n_levels: int
    shape: list
    betas: list
    warm_start: bool


@dataclasses.dataclass
class JobEvent(Event):
    """One retired registration job of ``launch.reg_serve`` — the per-tenant
    billing record (matvecs = what this job's masked PCG consumed)."""

    kind: ClassVar[str] = "job"
    job_id: str
    newton_iters: int
    hessian_matvecs: int
    fine_equiv_matvecs: float
    rel_gnorm: float
    converged: bool
    slot: int = -1
    queue_wait_steps: int = 0  # cohort iterations spent queued before a slot
    admitted_step: int = 0  # server.iterations when the job entered its slot
    retired_step: int = 0
    # tile index when the job is one block of a repro.blocks partition —
    # per-block billing rides the same record (None for plain jobs)
    block: list | None = None
    # ISSUE 10: the explicit retirement reason ("converged" | "stagnated" |
    # "max_newton" | "nonfinite" | "diverged" | "pcg_breakdown") — what the
    # boolean ``converged`` used to conflate — and which serve attempt this
    # record bills (1 = the original admission, >1 = a degraded retry)
    status: str = ""
    attempts: int = 1


@dataclasses.dataclass
class ServeStepEvent(Event):
    """One cohort iteration of a ``CohortServer``: the occupancy meter."""

    kind: ClassVar[str] = "serve_step"
    iteration: int
    slots: int
    occupancy: int  # live subjects this step
    queue_len: int
    refills: int  # cumulative slot refills (fills after the initial ones)


@dataclasses.dataclass
class CounterEvent(Event):
    kind: ClassVar[str] = "counter"
    name: str
    value: float
    total: float  # process-lifetime accumulation of this counter
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CollectivesEvent(Event):
    """``telemetry.count_collectives`` output attached to a labelled program:
    per-kind {count, bytes} for all-to-all / collective-permute / ..."""

    kind: ClassVar[str] = "collectives"
    label: str
    collectives: dict


@dataclasses.dataclass
class BenchEvent(Event):
    """One ``benchmarks.common.emit`` row (CSV line kept on stdout)."""

    kind: ClassVar[str] = "bench"
    name: str
    us_per_call: float
    derived: str = ""


@dataclasses.dataclass
class FaultEvent(Event):
    """One injected (or detected) fault: the chaos harness's audit record
    (``repro.resilience.faults``) and the serve layer's guard trips."""

    kind: ClassVar[str] = "fault"
    fault: str  # "nan_injection" | "kill" | "halo_overflow" | "guard_trip"
    target: str = ""  # job id / field / loop the fault hit
    iteration: int | None = None
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RecoveryEvent(Event):
    """One recovery action taken by the resilience machinery."""

    kind: ClassVar[str] = "recovery"
    # "retry_degraded" | "resume_from_checkpoint" | "ckpt_fallback"
    action: str
    job_id: str | None = None
    attempts: int | None = None  # attempt number the action admits/bills
    step: int | None = None  # checkpoint step / serve iteration involved
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SolveEvent(Event):
    """End-of-solve summary: the meters ``gn.solve``/``solve_cohort`` return."""

    kind: ClassVar[str] = "solve"
    source: str
    newton_iters: Any
    hessian_matvecs: Any
    fine_equiv_matvecs: Any = None
    precond_fine_equiv_matvecs: Any = None
    compiled_executables: int | None = None
    wall_s: float | None = None


EVENT_KINDS = {
    cls.kind: cls
    for cls in (
        SpanEvent, NewtonIterEvent, LevelEvent, LevelStartEvent, JobEvent,
        ServeStepEvent, CounterEvent, CollectivesEvent, BenchEvent, SolveEvent,
        FaultEvent, RecoveryEvent,
    )
}

# fields that MUST be present (and non-None where it matters) per kind —
# the schema contract validate_record enforces
_REQUIRED = {
    kind: tuple(
        f.name
        for f in dataclasses.fields(cls)
        if f.default is dataclasses.MISSING
        and f.default_factory is dataclasses.MISSING  # type: ignore[misc]
    )
    for kind, cls in EVENT_KINDS.items()
}


def validate_record(rec: Any) -> list[str]:
    """Return a list of schema violations (empty list: valid)."""
    errs: list[str] = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    v = rec.get("v")
    if v != SCHEMA_VERSION:
        errs.append(f"schema version {v!r} != {SCHEMA_VERSION}")
    if not isinstance(rec.get("ts"), (int, float)):
        errs.append(f"ts {rec.get('ts')!r} is not a timestamp")
    kind = rec.get("kind")
    if kind not in _REQUIRED:
        errs.append(f"unknown kind {kind!r}")
        return errs
    for name in _REQUIRED[kind]:
        if name not in rec:
            errs.append(f"{kind}: missing required field {name!r}")
    return errs

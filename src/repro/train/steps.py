"""Model-agnostic train/serve steps over the zoo.

``build_model(cfg)`` dispatches on family and returns a ``Model`` facade
with init/forward/loss/train_step/serve_step plus input & cache specs —
this is what the launcher, the dry-run, the smoke tests, and the examples
all consume.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import SHAPES, token_inputs
from repro.models import encdec, lm
from repro.models.common import ArchConfig, ShardRules
from repro.optim import adamw


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Mean CE in f32; labels < 0 are masked out.

    The gold logit is extracted with a broadcast-iota compare + masked sum
    rather than take_along_axis: with a vocab-sharded logits tensor the
    gather would make GSPMD all-gather the full (B,S,V) logits, while the
    masked sum reduces locally per vocab shard and all-reduces only the
    tiny (B,S) partials (§Perf)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    hit = vocab_iota == labels[..., None].clip(0)
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable  # key, rules -> (params, specs)
    forward: Callable  # params, batch -> logits
    loss: Callable  # params, batch -> scalar
    cache_init: Callable  # batch, max_len, rules -> (caches, specs)
    decode: Callable  # params, token, pos, caches -> (logits, caches)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("encdec", "audio") and cfg.enc_layers:

        def fwd(params, batch):
            return encdec.forward(cfg, params, batch["tokens"], batch["frames"])

        def loss(params, batch):
            return cross_entropy(fwd(params, batch), batch["labels"], cfg.vocab)

        def cache_fn(batch, max_len, rules, enc_len=None):
            return encdec.cache_init(cfg, batch, max_len, enc_len or max_len, rules)

        return Model(
            cfg=cfg,
            init=partial(encdec.init_params, cfg),
            forward=fwd,
            loss=loss,
            cache_init=cache_fn,
            decode=partial(encdec.decode_step, cfg),
        )

    def fwd(params, batch):
        return lm.forward(cfg, params, batch["tokens"], embeds=batch.get("embeds"))

    def loss(params, batch):
        return cross_entropy(fwd(params, batch), batch["labels"], cfg.vocab)

    return Model(
        cfg=cfg,
        init=partial(lm.init_params, cfg),
        forward=fwd,
        loss=loss,
        cache_init=partial(lm.cache_init, cfg),
        decode=partial(lm.decode_step, cfg),
    )


# --------------------------------------------------------------------------- #
# steps
# --------------------------------------------------------------------------- #
def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw.apply_updates(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model):
    def serve_step(params, token, pos, caches):
        logits, caches = model.decode(params, token, pos, caches)
        next_token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_token, caches

    return serve_step


def make_prefill_step(model: Model):
    def prefill(params, batch):
        return model.forward(params, batch)

    return prefill

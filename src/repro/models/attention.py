"""Attention block: GQA/MQA, RoPE/M-RoPE, qk-norm, sliding windows, caches.

Covers every assigned attention flavor:
  * gemma-7b      — 16 heads / 16 kv, head_dim 256, GeGLU
  * gemma3-1b     — 4 heads / 1 kv, 5:1 local(window):global pattern
  * minitron-4b   — 24/8 GQA, squared-ReLU MLP
  * qwen3-*       — GQA + per-head RMS qk-norm
  * qwen2-vl-72b  — 64/8 GQA + 3-section M-RoPE
  * moonshot/qwen3-moe — GQA + MoE MLPs
  * zamba2        — shared transformer block over a Mamba2 backbone
  * seamless      — enc-dec (self + cross attention)

Decode caches: full-length for global layers, **ring buffers bounded by
the window** for sliding-window layers (this is what makes gemma3-1b's
long_500k cell cheap: 25/30 of its layers cache only 1024 positions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, ShardRules, apply_rope, rms_norm

NEG_INF = -2.0e38


def attn_init(cfg: ArchConfig, key, rules: ShardRules, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d**-0.5
    params = {
        "wq": (jax.random.normal(k1, (d, h, hd)) * s).astype(cfg.dtype),
        "wk": (jax.random.normal(k2, (d, kv, hd)) * s).astype(cfg.dtype),
        "wv": (jax.random.normal(k3, (d, kv, hd)) * s).astype(cfg.dtype),
        "wo": (jax.random.normal(k4, (h, hd, d)) * (h * hd) ** -0.5).astype(cfg.dtype),
    }
    specs = {
        "wq": rules.spec(("fsdp", "heads", "head_dim"), (d, h, hd)),
        "wk": rules.spec(("fsdp", "kv_heads", "head_dim"), (d, kv, hd)),
        "wv": rules.spec(("fsdp", "kv_heads", "head_dim"), (d, kv, hd)),
        "wo": rules.spec(("heads", "head_dim", "fsdp"), (h, hd, d)),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((hd,), jnp.float32)
        params["k_norm"] = jnp.zeros((hd,), jnp.float32)
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def _qkv(cfg: ArchConfig, p: dict, x: jnp.ndarray, positions, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _block_local_attention(cfg: ArchConfig, p, q, k, v, window: int):
    """Sliding-window attention in O(S * 2w) instead of dense O(S^2).

    Beyond-paper §Perf optimization (hillclimb on gemma3-1b): the sequence
    is cut into window-sized blocks; block i attends to blocks {i-1, i}
    with the exact causal-window mask, which covers every (q, kv) pair with
    q - w < kv <= q.  Identical output to the dense path (tested).
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    w = window
    nb = s // w  # caller guarantees divisibility
    qb = q.reshape(b, nb, w, h, hd)
    pad = lambda t: jnp.concatenate([jnp.zeros_like(t[:, :1]), t], axis=1)
    kb = k.reshape(b, nb, w, kvh, hd)
    vb = v.reshape(b, nb, w, kvh, hd)
    k2 = jnp.concatenate([pad(kb)[:, :-1], kb], axis=2)  # (b, nb, 2w, kvh, hd)
    v2 = jnp.concatenate([pad(vb)[:, :-1], vb], axis=2)

    qpos = jnp.arange(w)[:, None] + w  # query index within the 2w window
    kpos = jnp.arange(2 * w)[None, :]
    m = (kpos <= qpos) & (kpos > qpos - w)
    first = jnp.arange(2 * w)[None, :] >= w  # block 0 has no left neighbor
    mask = jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)  # (w, 2w)
    mask0 = jnp.where(m & first, 0.0, NEG_INF).astype(jnp.float32)
    blk = jnp.arange(nb)
    mask_nb = jnp.where((blk > 0)[:, None, None], mask[None], mask0[None])  # (nb,w,2w)

    g = h // kvh
    qg = qb.reshape(b, nb, w, kvh, g, hd)
    scores = jnp.einsum("bnskgh,bntkh->bnkgst", qg, k2).astype(jnp.float32) * (hd**-0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = scores + mask_nb[None, :, None, None, :, :]
    wts = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkgst,bntkh->bnskgh", wts, v2).reshape(b, s, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention(
    cfg: ArchConfig,
    p: dict,
    x: jnp.ndarray,  # (B,S,D)
    positions: jnp.ndarray,
    window: int | None = None,
    kv_override: tuple | None = None,  # cross attention: (k, v, enc_mask)
) -> jnp.ndarray:
    b, s, _ = x.shape
    if kv_override is None:
        q, k, v = _qkv(cfg, p, x, positions)
        if window is not None and s % window == 0 and s >= 2 * window:
            return _block_local_attention(cfg, p, q, k, v, window)
        t = s
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        m = kpos <= qpos
        if window is not None:
            m &= kpos > qpos - window
        mask = jnp.where(m, 0.0, NEG_INF)[None].astype(jnp.float32)
        mask = jnp.broadcast_to(mask, (b, s, t))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k, v, mask = kv_override  # encoder memory: no causal mask

    bq, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(bq, sq, kvh, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * (hd**-0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = scores + mask[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(bq, sq, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# --------------------------------------------------------------------------- #
# decode-time cache
# --------------------------------------------------------------------------- #
def cache_init(cfg: ArchConfig, batch: int, max_len: int, window: int | None, rules: ShardRules):
    """KV cache for one attention layer; ring-buffer when windowed."""
    length = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv, cfg.head_dim
    shape = (batch, length, kv, hd)
    spec = rules.spec(("batch", "cache_seq", "kv_heads", "head_dim"), shape)
    cache = {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.full((length,), -1, jnp.int32),  # absolute position per slot
    }
    specs = {"k": spec, "v": spec, "pos": P(None)}
    return cache, specs


def attention_decode(
    cfg: ArchConfig,
    p: dict,
    x: jnp.ndarray,  # (B,1,D)
    pos: jnp.ndarray,  # scalar int32 — current position
    cache: dict,
    window: int | None = None,
) -> tuple[jnp.ndarray, dict]:
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _qkv(cfg, p, x, positions)

    length = cache["k"].shape[1]
    slot = jnp.mod(pos, length)  # ring-buffer write (full cache: slot == pos)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    slot_pos = cache["pos"].at[slot].set(pos)
    cache = {"k": k, "v": v, "pos": slot_pos}

    valid = slot_pos >= 0
    if window is not None:
        valid &= slot_pos > pos - window
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, :].astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (b, 1, length))

    kvh = k.shape[2]
    h, hd = cfg.n_heads, cfg.head_dim
    qg = q.reshape(b, 1, kvh, h // kvh, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * (hd**-0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = scores + mask[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(b, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

"""Shared LM machinery: configs, sharding-rule engine, layers.

The 10 assigned architectures are expressed as one ``ArchConfig`` each
(src/repro/configs/).  Parameters are plain nested dicts; every init
function returns ``(params, specs)`` where ``specs`` mirrors the param tree
with ``PartitionSpec`` leaves, produced through ``ShardRules`` — which
checks mesh-divisibility per dimension and falls back to replication when
a dim doesn't divide (e.g. gemma3-1b's 4 heads on a 16-way model axis),
recording every fallback for the dry-run report.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------- #
# configuration
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | ssm | moe | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention flavor
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE
    sliding_window: int | None = None  # window size for "local" layers
    layer_pattern: tuple[str, ...] = ("attn",)  # repeated; see blocks
    attn_logit_softcap: float | None = None
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    mlp: str = "swiglu"  # swiglu | geglu | relu2
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"  # scatter | dense (exact; smoke tests)
    moe_token_shard: int = 1  # dispatch groups per row (optimized: model size)

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # enc-dec (seamless)
    enc_layers: int = 0

    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 256
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs: no replayed TP collectives)
    scan_layers: bool = True  # False: unroll groups (depth-extrapolation probes)
    # which logical axes FSDP-shards parameters ("fsdp" rule axis)
    notes: str = ""

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_period == 0, (self.name, self.layer_pattern)
        return self.n_layers // self.pattern_period

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        d, v = self.d_model, self.vocab_padded
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_pattern = 0
        for kind in self.layer_pattern:
            if kind in ("attn", "local", "global", "attn_moe", "shared"):
                per_pattern += d * (self.n_heads + 2 * self.n_kv) * self.head_dim
                per_pattern += self.n_heads * self.head_dim * d
                if kind == "attn_moe":
                    per_pattern += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
                else:
                    mults = 3 if self.mlp in ("swiglu", "geglu") else 2
                    per_pattern += mults * d * self.d_ff
            elif kind == "mamba":
                din, st, hd = self.d_inner, self.ssm_state, self.ssm_heads
                per_pattern += d * (2 * din + 2 * st + hd) + din * d  # in/out proj
                per_pattern += (din + 2 * st) * self.ssm_conv + 3 * hd + din
        total += self.n_groups * per_pattern
        if self.enc_layers:  # encoder stack + cross-attention in decoder
            enc = self.enc_layers * (
                d * (self.n_heads + 2 * self.n_kv) * self.head_dim
                + self.n_heads * self.head_dim * d
                + 3 * d * self.d_ff
            )
            cross = self.n_layers * (
                d * (self.n_heads + 2 * self.n_kv) * self.head_dim + self.n_heads * self.head_dim * d
            )
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts top_k of n_experts."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        expert_all = self.n_groups * self.n_experts * 3 * d * self.d_ff
        expert_active = self.n_groups * self.top_k * 3 * d * self.d_ff
        return self.param_count() - expert_all + expert_active


# --------------------------------------------------------------------------- #
# sharding-rule engine
# --------------------------------------------------------------------------- #
class ShardRules:
    """Logical-axis -> mesh-axis mapping with divisibility fallback.

    rules: dict logical-name -> mesh axis (str | tuple | None).
    ``spec(("vocab","embed"), shape)`` returns a PartitionSpec where each
    dim keeps its mesh axis only if the dim size divides the axis size;
    otherwise the dim is replicated and the event is logged.
    """

    DEFAULT = {
        "batch": ("pod", "data"),
        "seq": None,
        "embed": None,
        "fsdp": "data",  # ZeRO/FSDP parameter dim
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "moe_embed": "data",  # expert-weight d_model dim (baseline: FSDP-like)
        "moe_ff": None,  # expert-weight d_ff dim (optimized profile: "data")
        "layers": None,
        "ssm_inner": "model",
        "cache_seq": None,
        "replicated": None,
    }

    def __init__(self, mesh, overrides: dict | None = None):
        self.mesh = mesh
        self.rules = dict(self.DEFAULT)
        if overrides:
            self.rules.update(overrides)
        self.fallbacks: list[tuple[str, int, Any]] = []

    def axis_size(self, axis) -> int:
        if axis is None:
            return 1
        names = axis if isinstance(axis, tuple) else (axis,)
        out = 1
        for n in names:
            out *= int(self.mesh.shape.get(n, 1))
        return out

    def _resolve(self, logical, dim_size: int):
        axis = self.rules.get(logical)
        if axis is None:
            return None
        # drop mesh axes absent from this mesh (e.g. "pod" on single-pod)
        names = axis if isinstance(axis, tuple) else (axis,)
        names = tuple(n for n in names if n in self.mesh.shape)
        if not names:
            return None
        size = 1
        for n in names:
            size *= int(self.mesh.shape[n])
        if dim_size % size != 0:
            self.fallbacks.append((logical, dim_size, names))
            return None
        return names if len(names) > 1 else names[0]

    def spec(self, logicals: tuple, shape: tuple) -> P:
        assert len(logicals) == len(shape), (logicals, shape)
        used: set = set()
        entries = []
        for lg, sz in zip(logicals, shape):
            r = self._resolve(lg, sz)
            # a mesh axis may appear at most once in a PartitionSpec
            flat = r if isinstance(r, tuple) else ((r,) if r else ())
            if any(a in used for a in flat):
                r = None
            else:
                used.update(flat)
            entries.append(r)
        return P(*entries)


# --------------------------------------------------------------------------- #
# layers
# --------------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # (B, S, H, hd)
    positions: jnp.ndarray,  # (B, S) or (3, B, S) for M-RoPE
    theta: float,
    mrope_sections: tuple[int, ...] | None = None,
) -> jnp.ndarray:
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))  # (hd/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        # Qwen2-VL M-RoPE: the hd/2 frequency slots are split into
        # (temporal, height, width) sections, each driven by its own
        # position id.  Text tokens use (t, t, t) -> reduces to 1-D RoPE.
        assert positions.ndim == 3 and sum(mrope_sections) == hd // 2
        parts = []
        start = 0
        for sec, pos in zip(mrope_sections, positions):
            parts.append(pos[..., None].astype(jnp.float32) * freqs[start : start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    elif cfg.mlp == "relu2":  # nemotron/minitron squared-ReLU
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:
        raise ValueError(cfg.mlp)
    return h @ p["w_down"]


def mlp_init(cfg: ArchConfig, key, rules: ShardRules, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d**-0.5
    scale_out = f**-0.5
    params, specs = {}, {}
    if cfg.mlp in ("swiglu", "geglu"):
        params["w_gate"] = (jax.random.normal(k1, (d, f)) * scale_in).astype(cfg.dtype)
        specs["w_gate"] = rules.spec(("fsdp", "mlp"), (d, f))
    params["w_up"] = (jax.random.normal(k2, (d, f)) * scale_in).astype(cfg.dtype)
    specs["w_up"] = rules.spec(("fsdp", "mlp"), (d, f))
    params["w_down"] = (jax.random.normal(k3, (f, d)) * scale_out).astype(cfg.dtype)
    specs["w_down"] = rules.spec(("mlp", "fsdp"), (f, d))
    return params, specs

"""Mixture-of-Experts layer: top-k routing, capacity-bounded dispatch.

TPU adaptation note (DESIGN.md §5): MoE dispatch is the LM-side analogue of
the paper's central obstacle — an irregular, data-dependent all-to-all.  We
resolve it the same way the paper's interpolation was adapted: replace the
dynamic alltoallv with a *statically bounded* exchange.  Tokens are grouped
by data shard, ranked within their expert by an O(M log M) sort (not a
T x E one-hot cumsum — memory), dropped beyond the per-group capacity
``C = ceil(k * S_g / E * cf)``, and scattered into a dense ``(G, E, C, D)``
buffer.  Expert matmuls are then regular einsums with experts sharded over
the ``model`` axis (EP); GSPMD lowers the G<->E resharding to a static
collective.  FLOPs stay proportional to *active* experts (top-k), which is
what the roofline's ``6 N_active D`` model assumes.

Two paths:
  * ``dense``   — every expert on every token, mask-combined. Exact; used by
                  smoke tests and as the oracle for the dispatch path.
  * ``scatter`` — the production path described above (default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, ShardRules


def moe_init(cfg: ArchConfig, key, rules: ShardRules):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    params = {
        "router": (jax.random.normal(ks[0], (d, e)) * d**-0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * d**-0.5).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * d**-0.5).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f**-0.5).astype(cfg.dtype),
    }
    specs = {
        "router": rules.spec(("fsdp", "replicated"), (d, e)),
        "w_gate": rules.spec(("experts", "moe_embed", "moe_ff"), (e, d, f)),
        "w_up": rules.spec(("experts", "moe_embed", "moe_ff"), (e, d, f)),
        "w_down": rules.spec(("experts", "moe_ff", "moe_embed"), (e, f, d)),
    }
    return params, specs


def _routing(cfg: ArchConfig, p: dict, x: jnp.ndarray):
    """x (..., D) -> (topk_idx (..., k), topk_w (..., k)) normalized."""
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return idx, w


def moe_apply_dense(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Exact path: all experts on all tokens (oracle / small configs)."""
    idx, w = _routing(cfg, p, x)  # (B,S,k)
    gate = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    up = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    y_all = jnp.einsum("bsef,efd->bsed", h, p["w_down"])  # (B,S,E,D)
    mask = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)  # (B,S,k,E)
    comb = jnp.einsum("bske,bsk->bse", mask, w).astype(x.dtype)
    return jnp.einsum("bsed,bse->bsd", y_all, comb)


def _rank_in_expert(ids: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """ids (M,) int32 -> rank of each entry among same-expert entries.

    Sort-based (O(M log M), O(M+E) memory): stable-sort by expert id; the
    position within the sorted run is ``i - start[expert]``.
    """
    m = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    counts = jnp.bincount(ids, length=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(m, dtype=jnp.int32) - starts[sorted_ids].astype(jnp.int32)
    return jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)


def moe_apply_scatter(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Capacity-bounded dispatch path. x (B,S,D) -> (B,S,D).

    Dispatch groups: ``cfg.moe_token_shard`` groups per batch row during
    training (1 => row-per-group; >1 additionally shards tokens over the
    model axis for dispatch — §Perf optimization: a2a payload per chip
    drops by the model-axis size); the whole batch is one group during
    decode (S=1) so capacity tracks *active* experts.

    Structured as dispatch -> (sharding hint) -> expert FFN -> (hint) ->
    combine so the group<->expert resharding lowers to an all-to-all
    instead of GSPMD's default data-axis all-reduce (see hints.py).
    """
    from repro.models import hints

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    idx, w = _routing(cfg, p, x)  # (B,S,k)

    sdiv = cfg.moe_token_shard if (s > 1 and s % max(cfg.moe_token_shard, 1) == 0) else 1
    g = b * sdiv if s > 1 else 1
    tpg = b * s // g  # tokens per dispatch group
    cap = int(max(1, round(k * tpg / e * cfg.capacity_factor)))
    xg_all = x.reshape(g, tpg, d)
    idx_all = idx.reshape(g, tpg, k)
    w_all = w.reshape(g, tpg, k)
    grp_axes = ("pod", "data", "model") if sdiv > 1 else ("pod", "data")
    xg_all = hints.constrain(xg_all, grp_axes, None, None)

    m = tpg * k
    toks = jnp.repeat(jnp.arange(tpg, dtype=jnp.int32), k)

    def dispatch(xg, idxg):  # (T,D), (T,k) -> buf, keep, slot
        ids = idxg.reshape(m)
        ranks = _rank_in_expert(ids, e)
        keep = ranks < cap
        slot = jnp.where(keep, ids * cap + ranks, e * cap)  # overflow slot dropped
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(xg[toks])
        return buf, keep, slot

    bufs, keeps, slots = jax.vmap(dispatch)(xg_all, idx_all)
    bufs = bufs[:, :-1].reshape(g, e, cap, d)
    # group->expert reshard: keep groups sharded; GSPMD routes to the
    # expert-sharded weights with an all-to-all rather than an all-reduce
    bufs = hints.constrain(bufs, grp_axes, None, None, None)

    gate = jnp.einsum("gecd,edf->gecf", bufs, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", bufs, p["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"]).reshape(g, e * cap, d)
    out = hints.constrain(out, grp_axes, None, None)

    def combine(outg, keep, slot, wgr):  # back to token order, weighted
        gathered = jnp.where(keep[:, None], outg[jnp.minimum(slot, e * cap - 1)], 0.0)
        contrib = gathered * wgr.reshape(m)[:, None].astype(x.dtype)
        return jnp.zeros((tpg, d), x.dtype).at[toks].add(contrib)

    y = jax.vmap(combine)(out, keeps, slots, w_all)
    return y.reshape(b, s, d)


def moe_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.moe_dispatch == "dense":
        return moe_apply_dense(cfg, p, x)
    return moe_apply_scatter(cfg, p, x)

"""Encoder-decoder LM (seamless-m4t-large-v2 backbone).

Per the assignment spec, the audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model) for the encoder;
the decoder is a standard causal transformer with cross-attention into the
encoder memory.  Both stacks reuse the attention/MLP blocks of lm.py and
are scanned over layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models.common import ArchConfig, ShardRules, mlp_apply, mlp_init, rms_norm
from repro.models.lm import _embed, _logits


def _enc_layer_init(cfg: ArchConfig, key, rules: ShardRules):
    k1, k2 = jax.random.split(key)
    pa, sa = attn.attn_init(cfg, k1, rules)
    pm, sm = mlp_init(cfg, k2, rules)
    return (
        {"ln_attn": jnp.zeros((cfg.d_model,), jnp.float32), "attn": pa,
         "ln_mlp": jnp.zeros((cfg.d_model,), jnp.float32), "mlp": pm},
        {"ln_attn": P(None), "attn": sa, "ln_mlp": P(None), "mlp": sm},
    )


def _dec_layer_init(cfg: ArchConfig, key, rules: ShardRules):
    k1, k2, k3 = jax.random.split(key, 3)
    pself, sself = attn.attn_init(cfg, k1, rules)
    pcross, scross = attn.attn_init(cfg, k2, rules)
    pm, sm = mlp_init(cfg, k3, rules)
    return (
        {"ln_self": jnp.zeros((cfg.d_model,), jnp.float32), "self": pself,
         "ln_cross": jnp.zeros((cfg.d_model,), jnp.float32), "cross": pcross,
         "ln_mlp": jnp.zeros((cfg.d_model,), jnp.float32), "mlp": pm},
        {"ln_self": P(None), "self": sself, "ln_cross": P(None), "cross": scross,
         "ln_mlp": P(None), "mlp": sm},
    )


def init_params(cfg: ArchConfig, key, rules: ShardRules):
    kE, kEnc, kDec = jax.random.split(key, 3)
    vp, d = cfg.vocab_padded, cfg.d_model
    params = {
        "embed": (jax.random.normal(kE, (vp, d)) * d**-0.5).astype(cfg.dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "enc_norm": jnp.zeros((d,), jnp.float32),
    }
    specs = {
        "embed": rules.spec(("vocab", "fsdp"), (vp, d)),
        "final_norm": P(None),
        "enc_norm": P(None),
    }
    ekeys = jax.random.split(kEnc, cfg.enc_layers)
    params["encoder"] = jax.vmap(lambda k: _enc_layer_init(cfg, k, rules)[0])(ekeys)
    _, es = _enc_layer_init(cfg, kEnc, rules)
    specs["encoder"] = jax.tree.map(lambda s: P(None, *s), es, is_leaf=lambda s: isinstance(s, P))
    dkeys = jax.random.split(kDec, cfg.n_layers)
    params["decoder"] = jax.vmap(lambda k: _dec_layer_init(cfg, k, rules)[0])(dkeys)
    _, ds = _dec_layer_init(cfg, kDec, rules)
    specs["decoder"] = jax.tree.map(lambda s: P(None, *s), ds, is_leaf=lambda s: isinstance(s, P))
    return params, specs


def encode(cfg: ArchConfig, params: dict, frames: jnp.ndarray) -> jnp.ndarray:
    """frames (B, S_enc, D) stub embeddings -> encoder memory (B, S_enc, D)."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = frames.astype(cfg.dtype)
    full = jnp.zeros((b, s, s), jnp.float32)  # bidirectional

    def layer(carry, p):
        # bidirectional self-attention: pass k/v via kv_override (no causal mask)
        h = rms_norm(carry, p["ln_attn"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
        x2 = carry + attn.attention(cfg, p["attn"], h, positions, kv_override=(k, v, full))
        h2 = rms_norm(x2, p["ln_mlp"], cfg.norm_eps)
        return x2 + mlp_apply(cfg, p["mlp"], h2), None

    body = jax.checkpoint(layer) if cfg.remat else layer
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:  # unrolled (cost-analysis probes)
        for i in range(cfg.enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, frames: jnp.ndarray):
    """Teacher-forced training pass -> logits (B, S_dec, Vp)."""
    memory = encode(cfg, params, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _embed(cfg, params, tokens)
    enc_mask = jnp.zeros((b, s, memory.shape[1]), jnp.float32)

    def layer(carry, p):
        h = rms_norm(carry, p["ln_self"], cfg.norm_eps)
        x2 = carry + attn.attention(cfg, p["self"], h, positions)
        h2 = rms_norm(x2, p["ln_cross"], cfg.norm_eps)
        ck = jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wv"])
        x3 = x2 + attn.attention(cfg, p["cross"], h2, positions, kv_override=(ck, cv, enc_mask))
        h3 = rms_norm(x3, p["ln_mlp"], cfg.norm_eps)
        return x3 + mlp_apply(cfg, p["mlp"], h3), None

    body = jax.checkpoint(layer) if cfg.remat else layer
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["decoder"])
    else:  # unrolled (cost-analysis probes)
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["decoder"]))
    return _logits(cfg, params, x)


def cache_init(cfg: ArchConfig, batch: int, max_len: int, enc_len: int, rules: ShardRules):
    """Self-attn KV cache + precomputed cross k/v per decoder layer."""
    c, s = attn.cache_init(cfg, batch, max_len, None, rules)
    stack = lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape)
    self_cache = jax.tree.map(stack, c)
    self_specs = jax.tree.map(lambda sp: P(None, *sp), s, is_leaf=lambda sp: isinstance(sp, P))
    kv, hd = cfg.n_kv, cfg.head_dim
    shape = (cfg.n_layers, batch, enc_len, kv, hd)
    spec = P(None, *rules.spec(("batch", "cache_seq", "kv_heads", "head_dim"), shape[1:]))
    cross = {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}
    return (
        {"self": self_cache, "cross": cross},
        {"self": self_specs, "cross": {"k": spec, "v": spec}},
    )


def decode_step(cfg: ArchConfig, params: dict, token: jnp.ndarray, pos, caches):
    """One decoder token against precomputed cross k/v. -> (logits, caches)."""
    x = _embed(cfg, params, token)
    b = token.shape[0]
    enc_len = caches["cross"]["k"].shape[2]
    enc_mask = jnp.zeros((b, 1, enc_len), jnp.float32)

    def layer(carry, scanned):
        h = carry
        p, sc, ck, cv = scanned
        hn = rms_norm(h, p["ln_self"], cfg.norm_eps)
        out, sc = attn.attention_decode(cfg, p["self"], hn, pos, sc)
        h = h + out
        hn = rms_norm(h, p["ln_cross"], cfg.norm_eps)
        h = h + attn.attention(cfg, p["cross"], hn, None, kv_override=(ck, cv, enc_mask))
        hn = rms_norm(h, p["ln_mlp"], cfg.norm_eps)
        h = h + mlp_apply(cfg, p["mlp"], hn)
        return h, sc

    xs = (params["decoder"], caches["self"], caches["cross"]["k"], caches["cross"]["v"])
    if cfg.scan_layers:
        x, new_self = jax.lax.scan(layer, x, xs)
    else:  # unrolled (cost-analysis probes)
        outs = []
        for i in range(cfg.n_layers):
            x, sc = layer(x, jax.tree.map(lambda a: a[i], xs))
            outs.append(sc)
        new_self = jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
    return _logits(cfg, params, x), {"self": new_self, "cross": caches["cross"]}

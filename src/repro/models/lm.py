"""Decoder-only LM: pattern-grouped blocks, scan-over-layers, KV/SSM caches.

One generic model covers 8 of the 10 assigned architectures via
``cfg.layer_pattern`` (the remaining 2 — seamless enc-dec — live in
encdec.py and reuse these blocks):

    gemma-7b / minitron-4b / qwen3-1.7b / qwen2-vl-72b : ("attn",)
    gemma3-1b  : ("local",)*5 + ("global",)      (5:1 sliding:full)
    moonshot / qwen3-moe : ("attn_moe",)
    mamba2-130m: ("mamba",)
    zamba2-2.7b: ("shared",) + ("mamba",)*5      (shared-weight attn block)

Layers are stacked **per pattern group** and applied with ``lax.scan`` so
the HLO is O(1) in depth (compile-time essential for the 94-layer MoE and
80-layer VLM dry-runs); ``jax.checkpoint`` remats each group.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import hints, mamba2, moe
from repro.models.common import ArchConfig, ShardRules, mlp_apply, mlp_init, rms_norm

ATTN_KINDS = ("attn", "local", "global", "attn_moe", "shared")


def _window(cfg: ArchConfig, kind: str):
    return cfg.sliding_window if kind == "local" else None


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def block_init(cfg: ArchConfig, kind: str, key, rules: ShardRules):
    k1, k2 = jax.random.split(key)
    if kind == "mamba":
        p, s = mamba2.mamba_init(cfg, k1, rules)
        return (
            {"norm": jnp.zeros((cfg.d_model,), jnp.float32), "mamba": p},
            {"norm": P(None), "mamba": s},
        )
    pa, sa = attn.attn_init(cfg, k1, rules)
    if kind == "attn_moe":
        pm, sm = moe.moe_init(cfg, k2, rules)
    else:
        pm, sm = mlp_init(cfg, k2, rules)
    params = {
        "ln_attn": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": pa,
        "ln_mlp": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": pm,
    }
    specs = {"ln_attn": P(None), "attn": sa, "ln_mlp": P(None), "mlp": sm}
    return params, specs


def group_init(cfg: ArchConfig, key, rules: ShardRules):
    params, specs = {}, {}
    keys = jax.random.split(key, len(cfg.layer_pattern))
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == "shared":
            continue  # shared block params live outside the scan stack
        p, s = block_init(cfg, kind, keys[i], rules)
        params[f"slot{i}"] = p
        specs[f"slot{i}"] = s
    return params, specs


def init_params(cfg: ArchConfig, key, rules: ShardRules):
    kE, kG, kS, kH = jax.random.split(key, 4)
    vp, d = cfg.vocab_padded, cfg.d_model
    params = {
        "embed": (jax.random.normal(kE, (vp, d)) * d**-0.5).astype(cfg.dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    specs = {
        "embed": rules.spec(("vocab", "fsdp"), (vp, d)),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kH, (d, vp)) * d**-0.5).astype(cfg.dtype)
        specs["lm_head"] = rules.spec(("fsdp", "vocab"), (d, vp))

    # stacked pattern groups (one init vmapped over groups)
    gkeys = jax.random.split(kG, cfg.n_groups)
    stacked = jax.vmap(lambda k: group_init(cfg, k, rules)[0])(gkeys)
    _, gspecs = group_init(cfg, kG, rules)
    params["groups"] = stacked
    specs["groups"] = jax.tree.map(
        lambda s: P(None, *s), gspecs, is_leaf=lambda s: isinstance(s, P)
    )

    if "shared" in cfg.layer_pattern:
        p, s = block_init(cfg, "shared", kS, rules)
        params["shared"] = p
        specs["shared"] = s
    return params, specs


# --------------------------------------------------------------------------- #
# forward (training / prefill)
# --------------------------------------------------------------------------- #
def block_apply(cfg: ArchConfig, kind: str, p: dict, x: jnp.ndarray, positions):
    if kind == "mamba":
        return x + mamba2.mamba_apply(cfg, p["mamba"], rms_norm(x, p["norm"], cfg.norm_eps))
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    x = x + attn.attention(cfg, p["attn"], h, positions, window=_window(cfg, kind))
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if kind == "attn_moe":
        x = x + moe.moe_apply(cfg, p["mlp"], h)
    else:
        x = x + mlp_apply(cfg, p["mlp"], h)
    return x


def _embed(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, embeds=None):
    x = params["embed"][tokens] if embeds is None else embeds.astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    return x


def _logits(cfg: ArchConfig, params: dict, x: jnp.ndarray):
    # logits stay in model dtype (f32 materialization at 256k vocab would
    # double the dominant activation); the CE loss upcasts per-block.
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.vocab_padded != cfg.vocab:  # mask padding rows
        pad = jnp.full((cfg.vocab_padded - cfg.vocab,), -1e30, logits.dtype)
        logits = logits.at[..., cfg.vocab :].set(pad)
    return logits


def forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, embeds=None) -> jnp.ndarray:
    """tokens (B,S) int32 -> logits (B,S,Vp).  ``embeds`` overrides the
    embedding lookup for modality-stub inputs (VLM patches / audio frames).
    """
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections is not None:  # text-only: (t, t, t)
        positions = jnp.broadcast_to(positions, (3, b, s))
    x = _embed(cfg, params, tokens, embeds)
    # §Perf: residual-stream pinning (batch over (pod,data), replicated on
    # model).  NOT for MoE archs: their token-sharded dispatch wants tokens
    # on the model axis too, and the conflicting constraints caused a
    # per-layer reshard storm (qwen3-moe hillclimb iteration 2 — refuted).
    pin = cfg.n_experts == 0
    if pin:
        x = hints.constrain(x, ("pod", "data"), None, None)

    shared = params.get("shared")

    def group_fn(carry, gparams):
        h = hints.constrain(carry, ("pod", "data"), None, None) if pin else carry
        for i, kind in enumerate(cfg.layer_pattern):
            p = shared if kind == "shared" else gparams[f"slot{i}"]
            h = block_apply(cfg, kind, p, h, positions)
        return h, None

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.checkpoint_dots
            if cfg.remat_policy == "dots"
            else None
        )
        body = jax.checkpoint(group_fn, policy=policy)
    else:
        body = group_fn
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["groups"])
    else:  # unrolled (cost-analysis probes; see launch/dryrun.py)
        for i in range(cfg.n_groups):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["groups"]))
    return _logits(cfg, params, x)


# --------------------------------------------------------------------------- #
# serving: cache init + single-token decode
# --------------------------------------------------------------------------- #
def cache_init(cfg: ArchConfig, batch: int, max_len: int, rules: ShardRules):
    caches, specs = {}, {}
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == "mamba":
            c, s = mamba2.mamba_state_init(cfg, batch, rules)
        else:
            c, s = attn.cache_init(cfg, batch, max_len, _window(cfg, kind), rules)
        caches[f"slot{i}"] = c
        specs[f"slot{i}"] = s
    # stack over groups
    stack = lambda a: jnp.broadcast_to(a[None], (cfg.n_groups,) + a.shape)
    caches = jax.tree.map(stack, caches)
    specs = jax.tree.map(
        lambda s: P(None, *s), specs, is_leaf=lambda s: isinstance(s, P)
    )
    return caches, specs


def decode_step(cfg: ArchConfig, params: dict, token: jnp.ndarray, pos, caches):
    """token (B,1) + caches -> (logits (B,1,Vp), new caches).  pos: int32."""
    x = _embed(cfg, params, token)
    shared = params.get("shared")

    def group_fn(carry, scanned):
        h = carry
        gparams, gcache = scanned
        new_cache = {}
        for i, kind in enumerate(cfg.layer_pattern):
            key = f"slot{i}"
            p = shared if kind == "shared" else gparams[key]
            if kind == "mamba":
                hn = rms_norm(h, p["norm"], cfg.norm_eps)
                out, new_cache[key] = mamba2.mamba_decode(cfg, p["mamba"], hn, gcache[key])
                h = h + out
            else:
                hn = rms_norm(h, p["ln_attn"], cfg.norm_eps)
                out, new_cache[key] = attn.attention_decode(
                    cfg, p["attn"], hn, pos, gcache[key], window=_window(cfg, kind)
                )
                h = h + out
                hn = rms_norm(h, p["ln_mlp"], cfg.norm_eps)
                if kind == "attn_moe":
                    h = h + moe.moe_apply(cfg, p["mlp"], hn)
                else:
                    h = h + mlp_apply(cfg, p["mlp"], hn)
        return h, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(group_fn, x, (params["groups"], caches))
    else:  # unrolled (cost-analysis probes)
        outs = []
        for i in range(cfg.n_groups):
            x, nc = group_fn(
                x,
                (
                    jax.tree.map(lambda a: a[i], params["groups"]),
                    jax.tree.map(lambda a: a[i], caches),
                ),
            )
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return _logits(cfg, params, x), new_caches

"""Mamba2 block — SSD (state-space duality) algorithm, arXiv:2405.21060.

TPU-native chunked SSD: the sequence is split into chunks of Q tokens;
within a chunk the SSM is evaluated as a masked-decay attention-like
quadratic form (MXU matmuls), and a compact per-chunk state
(H, head_dim, d_state) is passed between chunks by a `lax.scan` — the same
sequential-in-time state propagation pattern as the paper's semi-Lagrangian
transport loop (all state device-resident, matmul-heavy inner body).

Decode is the O(1) recurrent form: state <- state * exp(dt A) + dt B x.
A naive full-recurrence reference (`ssd_reference`) backs the tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, ShardRules, rms_norm


def mamba_init(cfg: ArchConfig, key, rules: ShardRules):
    d = cfg.d_model
    din = cfg.d_inner
    st, nh, hd, kc = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    d_in_proj = 2 * din + 2 * st + nh  # z, x, B, C, dt   (n_groups = 1)
    d_conv_ch = din + 2 * st  # conv over x, B, C
    ks = jax.random.split(key, 4)
    params = {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) * d**-0.5).astype(cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (kc, d_conv_ch)) * kc**-0.5).astype(cfg.dtype),
        "conv_b": jnp.zeros((d_conv_ch,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((din,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (din, d)) * din**-0.5).astype(cfg.dtype),
    }
    specs = {
        "in_proj": rules.spec(("fsdp", "ssm_inner"), (d, d_in_proj)),
        "conv_w": P(None, None),
        "conv_b": P(None),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": P(None),
        "out_proj": rules.spec(("ssm_inner", "fsdp"), (din, d)),
    }
    return params, specs


def _split_proj(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    din, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * st]
    dt = zxbcdt[..., 2 * din + 2 * st :]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state=None):
    """Per-channel causal conv1d. x (B,S,C); w (K,C).  state (B,K-1,C) for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : k - 1])
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype), new_state


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """segsum(x)[..., t, s] = sum_{s < r <= t} x[..., r]  (lower-triangular)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int):
    """SSD scan.  x (B,S,H,P); dt (B,S,H) >0; a (H,)<0; b,c (B,S,N).

    Returns y (B,S,H,P).  n_groups=1: B/C shared across heads.
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    da = dtc * a  # (B,nc,Q,H) — per-step log-decay
    da_t = jnp.swapaxes(da, -1, -2)  # (B,nc,H,Q)
    da_cum = jnp.cumsum(da_t, axis=-1)  # decay from chunk start
    da_total = da_cum[..., -1]  # (B,nc,H)

    # ---- intra-chunk (quadratic, MXU): y_t += sum_{s<=t} C_t.B_s L_ts dt_s x_s
    l = jnp.exp(_segsum(da_t))  # (B,nc,H,Q,Q)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)  # (B,nc,Q,Q)
    w = cb[:, :, None] * l  # (B,nc,H,Q,Q)
    y = jnp.einsum("bchqk,bckh,bckhp->bcqhp", w.astype(x.dtype), dtc.astype(x.dtype), xc)

    # ---- per-chunk terminal states: S_c = sum_s exp(da_total - da_cum_s) dt_s B_s x_s^T
    decay_to_end = jnp.exp(da_total[..., None] - da_cum)  # (B,nc,H,Q)
    sx = jnp.einsum(
        "bchk,bckh,bckn,bckhp->bchnp",
        decay_to_end.astype(jnp.float32),
        dtc.astype(jnp.float32),
        bc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence (lax.scan over chunks)
    def step(carry, inp):
        tot, sxc = inp  # (B,H) chunk total log-decay, (B,H,N,P) chunk contribution
        new = carry * jnp.exp(tot)[..., None, None] + sxc
        return new, carry  # emit the state *entering* this chunk

    init = jnp.zeros((bs, h, n, p), jnp.float32)
    _, states_in = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(da_total, 1, 0).astype(jnp.float32), jnp.moveaxis(sx, 1, 0)),
    )
    states_in = jnp.moveaxis(states_in, 0, 1)  # (B,nc,H,N,P): state entering chunk c

    # ---- inter-chunk output: y_t += C_t . (exp(da_cum_t) * S_in)
    decay_from_start = jnp.exp(da_cum)  # (B,nc,H,Q)
    y_inter = jnp.einsum(
        "bcqn,bchnp,bchq->bcqhp",
        cc.astype(jnp.float32),
        states_in,
        decay_from_start.astype(jnp.float32),
    )
    y = y + y_inter.astype(x.dtype)
    return y.reshape(bs, s, h, p)


def ssd_reference(x, dt, a, b, c):
    """Naive O(S) recurrence — oracle for tests and the decode step."""
    bs, s, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a)  # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt
        )
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    init = jnp.zeros((bs, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,H,P)


def mamba_apply(cfg: ArchConfig, prm: dict, x: jnp.ndarray, chunk: int = 64) -> jnp.ndarray:
    """Full-sequence forward. x (B,S,D) -> (B,S,D)."""
    din, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ prm["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, _ = _causal_conv(xbc, prm["conv_w"], prm["conv_b"])
    xs = xbc[..., :din]
    b = xbc[..., din : din + st]
    c = xbc[..., din + st :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + prm["dt_bias"])
    a = -jnp.exp(prm["A_log"])
    bs, s, _ = xs.shape
    xh = xs.reshape(bs, s, nh, hd)
    y = ssd_chunked(xh, dt, a, b, c, chunk=min(chunk, s))
    y = y + prm["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bs, s, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), prm["norm"], cfg.norm_eps)
    return y @ prm["out_proj"]


def mamba_state_init(cfg: ArchConfig, batch: int, rules: ShardRules):
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ch = cfg.d_inner + 2 * st
    state = {
        "ssm": jnp.zeros((batch, nh, st, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, ch), cfg.dtype),
    }
    specs = {
        "ssm": rules.spec(("batch", "ssm_inner", "replicated", "replicated"), state["ssm"].shape),
        "conv": rules.spec(("batch", "replicated", "replicated"), state["conv"].shape),
    }
    return state, specs


def mamba_decode(cfg: ArchConfig, prm: dict, x: jnp.ndarray, state: dict):
    """One-token decode. x (B,1,D) -> ((B,1,D), new_state).  O(1) in context."""
    din, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ prm["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, prm["conv_w"], prm["conv_b"], state["conv"])
    xs = xbc[..., :din]
    b = xbc[:, 0, din : din + st]
    c = xbc[:, 0, din + st :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + prm["dt_bias"])  # (B,H)
    a = -jnp.exp(prm["A_log"])
    xh = xs[:, 0].reshape(-1, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt * a)  # (B,H)
    ssm = state["ssm"] * decay[..., None, None] + jnp.einsum("bh,bn,bhp->bhnp", dt, b.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), ssm)
    y = y + prm["D"][None, :, None] * xh
    y = y.reshape(-1, 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), prm["norm"], cfg.norm_eps)
    return y @ prm["out_proj"], {"ssm": ssm, "conv": conv_state}

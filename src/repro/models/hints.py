"""Ambient-mesh activation-sharding hints.

GSPMD occasionally picks a pathological strategy for ops whose natural
sharding is ambiguous (our dry-run found it all-REDUCING MoE dispatch
buffers over the data axis instead of all-to-all-ing them to the expert
shards — 11 TB/chip/step on qwen3-moe).  ``constrain`` drops a
``with_sharding_constraint`` when a mesh has been installed (the dry-run /
launcher does this); in single-device tests it is a no-op.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def constrain(x, *spec_entries):
    """with_sharding_constraint(x, P(*entries)) under the ambient mesh.

    Entries referring to axes absent from the mesh are dropped; no mesh
    installed -> identity.
    """
    if _MESH is None:
        return x
    cleaned = []
    for e in spec_entries:
        if e is None:
            cleaned.append(None)
            continue
        names = e if isinstance(e, tuple) else (e,)
        names = tuple(n for n in names if n in _MESH.shape)
        # drop axes that don't divide this dim
        cleaned.append(names if len(names) > 1 else (names[0] if names else None))
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*cleaned)))
    except Exception:
        return x  # non-divisible etc.: hint is best-effort

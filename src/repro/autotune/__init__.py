"""``repro.autotune`` — knob search + persistent tuning cache (ISSUE 8).

Three layers:

* ``cache`` — the JSON tuning cache (``results/autotune_cache.json`` by
  default, gitignored; ``REPRO_AUTOTUNE_CACHE`` overrides) with schema and
  knob-revision pins, a value allowlist, and counted fallbacks for every
  invalid-file class.
* ``measure`` — candidate scoring: median wall time on real devices,
  deterministic collective count/byte cost model on CPU hosts.
* ``search`` — coordinate-descent sweep of (chunk, field_dtype,
  plan_dtype, interp_method) over the compiled Hessian matvec, plus
  preconditioner races and mesh-layout records.

Consumers consult through two entry points here: ``consult_gn`` (called by
``gn.solve``/``make_cohort_step``/``register`` when ``GNConfig.autotune !=
"off"``) and ``consult_ctx`` (called by ``DistContext.__init__``).  Both
only fill knobs still at their default sentinels — an explicit value
always wins — and a missing/invalid cache is a silent no-op, so tuning can
never change behavior the user pinned by hand.
"""
from __future__ import annotations

from repro.autotune.cache import (
    KNOBS_REV,
    SCHEMA_VERSION,
    TunedConfig,
    TuningCache,
    cell_key,
    default_cache_path,
    resolve_tuned,
    tuned_replace,
)

__all__ = [
    "SCHEMA_VERSION",
    "KNOBS_REV",
    "TunedConfig",
    "TuningCache",
    "cell_key",
    "default_cache_path",
    "resolve_tuned",
    "tuned_replace",
    "consult_gn",
    "consult_ctx",
    "sweep_cell",
    "sweep_mesh_layouts",
]

# default sentinels of the GNConfig perf knobs the resolver may fill
_GN_DEFAULTS = {"interp_method": "ref", "plan_dtype": None, "field_dtype": None}


def _ndev_of(ops) -> int:
    mesh = getattr(getattr(ops, "fft", None), "mesh", None)
    return int(mesh.devices.size) if mesh is not None else 1


def consult_gn(cfg, grid, ops):
    """Fill still-at-default perf knobs of a ``GNConfig`` from the cache.

    ``autotune="sweep"`` additionally runs ``search.sweep_cell`` on a cache
    miss when ``ops`` is backed by a device mesh (a local solve has no
    collectives to tune — the sweep is skipped and defaults stand)."""
    tuned = resolve_tuned(grid.shape, _ndev_of(ops), beta=cfg.beta)
    if tuned is None and cfg.autotune == "sweep":
        mesh = getattr(getattr(ops, "fft", None), "mesh", None)
        if mesh is not None:
            from repro.autotune.search import sweep_cell

            fft = ops.fft
            sweep_cell(grid, mesh, beta=cfg.beta, axes=fft.axes)
            tuned = resolve_tuned(grid.shape, _ndev_of(ops), beta=cfg.beta)
    if tuned is None:
        return cfg
    return tuned_replace(cfg, tuned, _GN_DEFAULTS)


def consult_ctx(ctx) -> dict:
    """Tuned knobs for a ``DistContext`` under construction.

    Returns only the knobs the context should adopt: those still at their
    constructor sentinels (``chunk=None``, ``interp_method="auto"``,
    ``plan_dtype=None``, ``field_dtype=None``).  Beta is not known at
    context-build time, so the lookup uses the exact-cell beta-agnostic
    entry (``beta-any``)."""
    tuned = resolve_tuned(ctx.grid.shape, int(ctx.mesh.devices.size), beta=None)
    if tuned is None:
        return {}
    out: dict = {}
    if ctx.chunk is None and tuned.chunk is not None:
        out["chunk"] = tuned.chunk
    if ctx.interp_method == "auto" and tuned.interp_method is not None:
        out["interp_method"] = tuned.interp_method
    if ctx.plan_dtype is None and tuned.plan_dtype is not None:
        out["plan_dtype"] = tuned.plan_dtype
    if ctx.field_dtype is None and tuned.field_dtype is not None:
        out["field_dtype"] = tuned.field_dtype
    return out


def sweep_cell(*args, **kwargs):
    """Lazy re-export of ``repro.autotune.search.sweep_cell``."""
    from repro.autotune import search

    return search.sweep_cell(*args, **kwargs)


def sweep_mesh_layouts(*args, **kwargs):
    """Lazy re-export of ``repro.autotune.search.sweep_mesh_layouts``."""
    from repro.autotune import search

    return search.sweep_mesh_layouts(*args, **kwargs)

"""Persistent tuning cache: JSON winners keyed by (grid, devices, beta) cell.

The cache is the contract between the sweep driver (``repro.autotune.search``
/ ``benchmarks/autotune_suite.py``) and the consumers that consult it by
default (``DistContext``, ``GNConfig``-driven solvers, ``register``):

* one file (``results/autotune_cache.json`` unless ``REPRO_AUTOTUNE_CACHE``
  points elsewhere — the repo gitignores the default path so committed
  winners can never silently change solver behavior on another machine),
* top-level ``schema`` pin plus a per-entry ``knobs_rev`` pin: bump
  ``KNOBS_REV`` whenever a knob's meaning changes and every stale entry
  degrades to "no entry" instead of mis-tuning a new build,
* a hard allowlist on knob names AND values: an entry that names an unknown
  knob, an out-of-range chunk, or a dtype outside {float32, bfloat16} is
  rejected wholesale (``telemetry.counter("autotune.cache_invalid")`` with a
  ``reason`` attribute counts every rejection class, pinned by
  ``tests/test_autotune.py``),
* counted-mode entries (winners chosen by deterministic collective
  counts/bytes on CPU hosts) never apply the dtype knobs on resolve: halved
  payload bytes make bf16 win every counted comparison by construction, so
  only a wall-clock-measured entry may flip numerics-adjacent knobs.

This module deliberately imports nothing but the stdlib and
``repro.telemetry`` — ``core/gauss_newton.py`` and ``dist/context.py``
consult it lazily without creating an import cycle.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from repro import telemetry

SCHEMA_VERSION = 1
# bump when a knob's semantics change: stale entries then fall back to
# defaults (counted as reason="knobs_rev") instead of mis-applying
KNOBS_REV = 1

ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE = os.path.join("results", "autotune_cache.json")

COUNTER_INVALID = "autotune.cache_invalid"
COUNTER_HIT = "autotune.cache_hit"
COUNTER_MISS = "autotune.cache_miss"

_VALID_INTERP = ("ref", "pallas", "auto")
_VALID_DTYPES = ("float32", "bfloat16")
_VALID_PRECOND = ("spectral", "two_level", "vcycle")
_VALID_MODES = ("counted", "wall")
# knobs a cache entry may carry; anything else rejects the entry
KNOB_NAMES = ("chunk", "interp_method", "plan_dtype", "field_dtype", "precond")


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One cell's winning knob set.  ``None`` = knob not tuned (keep the
    consumer's default).  ``mode`` records how the winner was measured:
    ``"wall"`` (real devices, median wall time) or ``"counted"``
    (deterministic collective count/byte cost model — the CI-hermetic
    fallback).  ``precond`` is advisory: the preconditioner is a callable
    argument of ``gn.solve``, so the resolver reports the winner (and the
    bench records it) but never injects it."""

    chunk: int | str | None = None
    interp_method: str | None = None
    plan_dtype: str | None = None
    field_dtype: str | None = None
    precond: str | None = None
    mode: str = "counted"
    cost: float | None = None
    knobs_rev: int = KNOBS_REV

    def knobs(self) -> dict:
        return {k: getattr(self, k) for k in KNOB_NAMES if getattr(self, k) is not None}


def cell_key(shape, ndev: int, beta: float | None = None) -> str:
    """``"N1xN2xN3/Ddev/beta-<g>"`` — same cell naming as the dry-run
    planner records; ``beta=None`` gives the beta-agnostic key."""
    dims = "x".join(str(int(n)) for n in shape)
    b = "any" if beta is None else format(float(beta), "g")
    return f"{dims}/{int(ndev)}dev/beta-{b}"


def _check_knobs(entry: dict) -> str | None:
    """Allowlist guard.  Returns a rejection reason or None when valid."""
    for name in entry.get("knobs", {}):
        if name not in KNOB_NAMES:
            return f"unknown_knob:{name}"
    knobs = entry.get("knobs", {})
    chunk = knobs.get("chunk")
    if chunk is not None and chunk != "auto":
        if not isinstance(chunk, int) or isinstance(chunk, bool) or chunk < 1:
            return "invalid_chunk"
    im = knobs.get("interp_method")
    if im is not None and im not in _VALID_INTERP:
        return "invalid_interp_method"
    for dk in ("plan_dtype", "field_dtype"):
        dt = knobs.get(dk)
        if dt is not None and dt not in _VALID_DTYPES:
            return f"invalid_{dk}"
    pc = knobs.get("precond")
    if pc is not None and pc not in _VALID_PRECOND:
        return "invalid_precond"
    if entry.get("mode", "counted") not in _VALID_MODES:
        return "invalid_mode"
    return None


def default_cache_path() -> str:
    return os.environ.get(ENV_CACHE) or DEFAULT_CACHE


class TuningCache:
    """Load/store tuned winners.  Every failure mode degrades to "no entry"
    with a counted telemetry event — a corrupt or hostile cache file can
    slow a run down (defaults) but never crash or mis-tune it."""

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()

    # -- IO ----------------------------------------------------------------
    def load(self) -> dict:
        if not os.path.exists(self.path):
            return {}
        try:
            with open(self.path) as fh:
                raw = json.load(fh)
        except (OSError, ValueError):
            telemetry.counter(COUNTER_INVALID, reason="corrupt", path=self.path)
            return {}
        if not isinstance(raw, dict) or not isinstance(raw.get("cells"), dict):
            telemetry.counter(COUNTER_INVALID, reason="malformed", path=self.path)
            return {}
        if raw.get("schema") != SCHEMA_VERSION:
            telemetry.counter(
                COUNTER_INVALID, reason="schema", found=raw.get("schema"), path=self.path
            )
            return {}
        return raw["cells"]

    def _write(self, cells: dict) -> None:
        # crash-safe: pid-unique temp + fsync + atomic rename, so two
        # concurrent sweeps never tear each other's cache (the fixed-name
        # ``.tmp`` pattern let one writer promote another's partial bytes)
        from repro.resilience.atomic import atomic_write_json

        payload = {"schema": SCHEMA_VERSION, "cells": cells}
        atomic_write_json(
            self.path, payload, indent=2, sort_keys=True, trailing_newline=True
        )

    # -- entries -----------------------------------------------------------
    def get(self, cell: str) -> TunedConfig | None:
        entry = self.load().get(cell)
        if entry is None:
            return None
        if not isinstance(entry, dict):
            telemetry.counter(COUNTER_INVALID, reason="malformed_entry", cell=cell)
            return None
        if entry.get("knobs_rev") != KNOBS_REV:
            telemetry.counter(
                COUNTER_INVALID, reason="knobs_rev", cell=cell, found=entry.get("knobs_rev")
            )
            return None
        reason = _check_knobs(entry)
        if reason is not None:
            telemetry.counter(COUNTER_INVALID, reason=reason, cell=cell)
            return None
        knobs = entry.get("knobs", {})
        return TunedConfig(
            chunk=knobs.get("chunk"),
            interp_method=knobs.get("interp_method"),
            plan_dtype=knobs.get("plan_dtype"),
            field_dtype=knobs.get("field_dtype"),
            precond=knobs.get("precond"),
            mode=entry.get("mode", "counted"),
            cost=entry.get("cost"),
            knobs_rev=KNOBS_REV,
        )

    def put(self, cell: str, tuned: TunedConfig) -> None:
        entry = {
            "knobs": tuned.knobs(),
            "mode": tuned.mode,
            "cost": tuned.cost,
            "knobs_rev": tuned.knobs_rev,
        }
        reason = _check_knobs(entry)
        if reason is not None:
            raise ValueError(f"refusing to store invalid tuning entry for {cell}: {reason}")
        cells = self.load()
        cells[cell] = entry
        self._write(cells)

    # -- validation (ci.sh) -------------------------------------------------
    def validate(self) -> list[str]:
        """Schema problems as human-readable strings; [] == valid (a missing
        file is valid — the cache is optional by design)."""
        if not os.path.exists(self.path):
            return []
        try:
            with open(self.path) as fh:
                raw = json.load(fh)
        except (OSError, ValueError) as e:
            return [f"unreadable JSON: {e}"]
        problems = []
        if not isinstance(raw, dict):
            return ["top level is not an object"]
        if raw.get("schema") != SCHEMA_VERSION:
            problems.append(f"schema {raw.get('schema')!r} != {SCHEMA_VERSION}")
        cells = raw.get("cells")
        if not isinstance(cells, dict):
            return problems + ["'cells' is not an object"]
        for cell, entry in cells.items():
            if not isinstance(entry, dict):
                problems.append(f"{cell}: entry is not an object")
                continue
            if entry.get("knobs_rev") != KNOBS_REV:
                problems.append(f"{cell}: knobs_rev {entry.get('knobs_rev')!r} != {KNOBS_REV}")
            reason = _check_knobs(entry)
            if reason is not None:
                problems.append(f"{cell}: {reason}")
        return problems


def resolve_tuned(
    shape,
    ndev: int,
    beta: float | None = None,
    path: str | None = None,
) -> TunedConfig | None:
    """Look up the winning knob set for a cell: exact-beta entry first, the
    beta-agnostic entry as fallback.  Counted-mode entries come back with
    the dtype knobs stripped (see module docstring)."""
    cache = TuningCache(path)
    tuned = cache.get(cell_key(shape, ndev, beta))
    if tuned is None and beta is not None:
        tuned = cache.get(cell_key(shape, ndev, None))
    if tuned is None:
        telemetry.counter(COUNTER_MISS, cell=cell_key(shape, ndev, beta))
        return None
    if tuned.mode == "counted" and (tuned.plan_dtype or tuned.field_dtype):
        tuned = dataclasses.replace(tuned, plan_dtype=None, field_dtype=None)
    telemetry.counter(COUNTER_HIT, cell=cell_key(shape, ndev, beta))
    return tuned


def tuned_replace(cfg: Any, tuned: TunedConfig, defaults: dict) -> Any:
    """Dataclass-replace every field of ``cfg`` named in ``defaults`` that is
    (a) still at its default sentinel and (b) tuned (non-None in ``tuned``).
    Explicitly-set knobs always win over the cache."""
    updates = {}
    for field, default in defaults.items():
        if getattr(cfg, field) == default:
            val = getattr(tuned, field, None)
            if val is not None:
                updates[field] = val
    return dataclasses.replace(cfg, **updates) if updates else cfg

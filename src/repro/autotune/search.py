"""Coordinate-descent knob sweep over the distributed Hessian matvec.

``sweep_cell(grid, mesh, beta=...)`` is the driver behind
``python -m benchmarks.run --suite autotune`` and ``GNConfig(autotune=
"sweep")``: for one ``(grid, mesh, beta)`` cell it

1. builds a deterministic synthetic registration problem (smooth cosine
   blobs — no RNG, so counted sweeps are bit-reproducible),
2. scores candidate knob sets on the compiled ``gn_hessian_matvec``
   program — the inner-loop kernel that dominates a solve (paper Table V
   bills everything in its units) — via ``repro.autotune.measure``
   (median wall seconds on real devices, deterministic collective
   count/byte cost on CPU hosts),
3. walks the knobs in a fixed order (chunk, field_dtype, plan_dtype,
   interp_method), keeping each knob's winner before sweeping the next —
   coordinate descent, |candidates| programs per knob instead of the
   cross product,
4. optionally races preconditioner variants (spectral vs two-level) on a
   short *solve* — the matvec program can't see a preconditioner, so this
   knob is scored by the deterministic ``hessian_matvecs +
   precond_fine_equiv_matvecs`` meter (or solve wall time),
5. writes the winner to the ``TuningCache`` so the next
   ``DistContext``/``gn.solve`` of the same cell resolves it without
   re-sweeping (pinned by ``tests/test_autotune.py``).

Wall-mode winners must beat the incumbent by ``HYSTERESIS`` (5%) —
machine noise should not flip a knob off its default; counted mode is
deterministic and takes any strict improvement.

Heavy imports (jax, repro.core, repro.dist) happen inside functions: this
module is imported by ``repro.autotune`` which core modules consult lazily.
"""
from __future__ import annotations

import dataclasses

from repro import telemetry
from repro.autotune import measure
from repro.autotune.cache import TunedConfig, TuningCache, cell_key

HYSTERESIS = 0.05  # wall mode: >5% improvement required to leave a default
KNOB_ORDER = ("chunk", "field_dtype", "plan_dtype", "interp_method")


def default_candidates(mode: str, backend: str | None = None) -> dict:
    """Per-knob candidate lists.  ``None`` always means "consumer default".

    Counted mode skips ``interp_method``: kernel choice never changes the
    collective structure, so the cost model cannot rank it (ties keep the
    default).  ``pallas`` only enters on TPU where it can actually win.
    """
    cands = {
        "chunk": [None, 1, 2, 4, "auto"],
        "field_dtype": [None, "bfloat16"],
        "plan_dtype": [None, "bfloat16"],
    }
    if mode == "wall":
        cands["interp_method"] = [None, "pallas"] if backend == "tpu" else [None]
    else:
        cands["interp_method"] = [None]
    return cands


def _synthetic_pair(grid):
    """Deterministic smooth reference/template pair (no RNG)."""
    import jax.numpy as jnp
    import numpy as np

    axes = [np.linspace(0.0, 2 * np.pi, n, endpoint=False) for n in grid.shape]
    X, Y, Z = np.meshgrid(*axes, indexing="ij")
    rho_R = np.exp(np.cos(X) + 0.5 * np.cos(Y) - 0.3 * np.cos(Z)) / np.e
    rho_T = np.exp(np.cos(X - 0.4) + 0.5 * np.cos(Y + 0.3) - 0.3 * np.cos(Z - 0.2)) / np.e
    return (
        jnp.asarray(rho_R, grid.dtype),
        jnp.asarray(rho_T, grid.dtype),
    )


def _test_velocity(grid):
    import jax.numpy as jnp
    import numpy as np

    axes = [np.linspace(0.0, 2 * np.pi, n, endpoint=False) for n in grid.shape]
    X, Y, Z = np.meshgrid(*axes, indexing="ij")
    v = np.stack(
        [0.05 * np.sin(X) * np.cos(Y), 0.05 * np.sin(Y) * np.cos(Z), 0.04 * np.sin(Z)]
    )
    return jnp.asarray(v, grid.dtype)


def _build_ctx(grid, mesh, knobs: dict, *, axes=("data", "model"), halo: int = 4):
    from repro.dist.context import DistContext

    return DistContext(
        grid,
        mesh,
        axes=axes,
        halo=halo,
        chunk=knobs.get("chunk"),
        interp_method=knobs.get("interp_method") or "auto",
        plan_dtype=knobs.get("plan_dtype"),
        field_dtype=knobs.get("field_dtype"),
        autotune="off",  # the sweep must not consult the cache it is filling
    )


def _matvec_score(grid, mesh, beta, knobs, *, axes, halo, mode, repeats) -> float:
    """Cost of the compiled Hessian matvec under one candidate knob set."""
    import jax

    from repro.core import objective as obj

    ctx = _build_ctx(grid, mesh, knobs, axes=axes, halo=halo)
    rho_R, rho_T = _synthetic_pair(grid)
    prob = obj.Problem(
        grid=grid,
        rho_R=ctx.shard_scalar(rho_R),
        rho_T=ctx.shard_scalar(rho_T),
        beta=float(beta),
        n_t=2,
        incompressible=False,
    )
    v = ctx.shard_vector(_test_velocity(grid))
    state = obj.newton_state(v, prob, ctx.ops, ctx.interp)
    f = jax.jit(lambda p: obj.gn_hessian_matvec(p, state, prob, ctx.ops, ctx.interp))
    p = ctx.shard_vector(_test_velocity(grid))
    if mode == "counted":
        return measure.counted_cost(f.lower(p))
    return measure.wall_cost(f, p, repeats=repeats)


def _precond_score(grid, mesh, beta, knobs, variant, *, axes, halo, mode, repeats) -> float:
    """Race a preconditioner variant on a short solve.

    The matvec program cannot see the preconditioner, so this knob uses the
    solver's own deterministic billing meter: raw Hessian matvecs plus the
    fine-grid-equivalent cost of every preconditioner application
    (``gn.solve``'s Table-V accounting).  Wall mode times the solve.
    """
    import time

    from repro.core import gauss_newton as gn

    ctx = _build_ctx(grid, mesh, knobs, axes=axes, halo=halo)
    rho_R, rho_T = _synthetic_pair(grid)
    cfg = gn.GNConfig(beta=float(beta), n_t=2, max_newton=2, max_cg=8, autotune="off")
    precond = None
    if variant == "two_level":
        from repro.core import objective as obj
        from repro.multilevel import precond as mlp

        coarse_shape = tuple(n // 2 for n in grid.shape)
        coarse_ctx = ctx.coarsen(coarse_shape)
        prob = obj.Problem(
            grid=grid,
            rho_R=ctx.shard_scalar(rho_R),
            rho_T=ctx.shard_scalar(rho_T),
            beta=float(beta),
            n_t=2,
            incompressible=False,
        )
        precond = mlp.make_two_level_precond(
            prob, ctx.ops, coarse_ctx.ops, interp_coarse=coarse_ctx.interp, galerkin=True
        )
    t0 = time.perf_counter()
    out = gn.solve(
        ctx.shard_scalar(rho_R),
        ctx.shard_scalar(rho_T),
        grid,
        cfg,
        ops=ctx.ops,
        interp=ctx.interp,
        precond=precond,
    )
    wall = time.perf_counter() - t0
    if mode == "counted":
        return float(out["hessian_matvecs"]) + float(out["precond_fine_equiv_matvecs"])
    return wall


def sweep_cell(
    grid,
    mesh,
    *,
    beta: float = 1e-2,
    axes=("data", "model"),
    halo: int = 4,
    cache: TuningCache | None = None,
    mode: str | None = None,
    candidates: dict | None = None,
    include_precond: bool = True,
    repeats: int = 3,
    write: bool = True,
) -> dict:
    """Sweep one ``(grid, mesh, beta)`` cell; returns the full record
    (candidates, per-candidate costs, winner) and persists the winner."""
    import jax

    mode = mode or measure.measure_mode()
    cands = candidates if candidates is not None else default_candidates(
        mode, jax.default_backend()
    )
    cache = cache or TuningCache()
    ndev = int(mesh.devices.size)
    cell = cell_key(grid.shape, ndev, beta)

    best: dict = {}
    trials = []
    with telemetry.span("autotune.sweep_cell", cell=cell, mode=mode):
        base_cost = _matvec_score(
            grid, mesh, beta, best, axes=axes, halo=halo, mode=mode, repeats=repeats
        )
        trials.append({"knobs": dict(best), "cost": base_cost})
        for knob in KNOB_ORDER:
            incumbent = best.get(knob)
            incumbent_cost = base_cost
            for cand in cands.get(knob, [None]):
                if cand == incumbent:
                    continue
                trial = dict(best)
                trial[knob] = cand
                try:
                    cost = _matvec_score(
                        grid, mesh, beta, trial,
                        axes=axes, halo=halo, mode=mode, repeats=repeats,
                    )
                except Exception as e:  # infeasible candidate (divisibility, ...)
                    telemetry.counter(
                        "autotune.candidate_failed", knob=knob, value=1.0, error=str(e)[:120]
                    )
                    continue
                trials.append({"knobs": dict(trial), "cost": cost})
                margin = HYSTERESIS if mode == "wall" and incumbent is None else 0.0
                if cost < incumbent_cost * (1.0 - margin):
                    incumbent, incumbent_cost = cand, cost
            if incumbent is not None:
                best[knob] = incumbent
            base_cost = incumbent_cost

        precond_winner = None
        precond_trials = []
        if include_precond:
            for variant in ("spectral", "two_level"):
                try:
                    cost = _precond_score(
                        grid, mesh, beta, best, variant,
                        axes=axes, halo=halo, mode=mode, repeats=repeats,
                    )
                except Exception as e:
                    telemetry.counter(
                        "autotune.candidate_failed", knob="precond", error=str(e)[:120]
                    )
                    continue
                precond_trials.append({"variant": variant, "cost": cost})
            if precond_trials:
                winner = min(precond_trials, key=lambda t: t["cost"])
                margin = HYSTERESIS if mode == "wall" else 0.0
                spectral = next(
                    (t for t in precond_trials if t["variant"] == "spectral"), None
                )
                if (
                    winner["variant"] != "spectral"
                    and spectral is not None
                    and winner["cost"] >= spectral["cost"] * (1.0 - margin)
                ):
                    winner = spectral
                precond_winner = winner["variant"]

    tuned = TunedConfig(
        chunk=best.get("chunk"),
        interp_method=best.get("interp_method"),
        plan_dtype=best.get("plan_dtype"),
        field_dtype=best.get("field_dtype"),
        precond=None if precond_winner in (None, "spectral") else precond_winner,
        mode=mode,
        cost=float(base_cost),
    )
    if write:
        cache.put(cell, tuned)
    return {
        "cell": cell,
        "mode": mode,
        "grid": list(grid.shape),
        "devices": ndev,
        "beta": float(beta),
        "trials": trials,
        "precond_trials": precond_trials if include_precond else [],
        "winner": tuned.knobs(),
        "cost": float(base_cost),
        "cache_path": cache.path,
    }


def sweep_mesh_layouts(grid, devices=None, *, beta: float = 1e-2, halo: int = 4,
                       mode: str | None = None, repeats: int = 3) -> dict:
    """Race mesh layouts (1xD / 2xD/2 / Dx1) over the same device set.

    The mesh is an input of ``DistContext`` (callers own placement), so the
    winner is *recorded* for the bench tables rather than cached as a knob
    — ``BENCH_autotune.json`` carries it next to the cell winners.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    devices = list(devices if devices is not None else jax.devices())
    D = len(devices)
    mode = mode or measure.measure_mode()
    layouts = [(1, D), (D, 1)]
    if D % 2 == 0:
        layouts.insert(1, (2, D // 2))
    rows = []
    for p1, p2 in layouts:
        if grid.shape[0] % max(p1, 1) or grid.shape[1] % max(p2, 1):
            continue
        mesh = Mesh(np.asarray(devices).reshape(p1, p2), ("data", "model"))
        try:
            cost = _matvec_score(
                grid, mesh, beta, {}, axes=("data", "model"), halo=halo,
                mode=mode, repeats=repeats,
            )
        except Exception as e:
            telemetry.counter("autotune.candidate_failed", knob="mesh", error=str(e)[:120])
            continue
        rows.append({"layout": [p1, p2], "cost": float(cost)})
    winner = min(rows, key=lambda r: r["cost"])["layout"] if rows else None
    return {"mode": mode, "layouts": rows, "winner": winner}

"""Candidate measurement: wall clock where real devices exist, deterministic
counted cost everywhere else.

Two regimes, picked by ``measure_mode()``:

* ``"wall"`` — on GPU/TPU backends a candidate is scored by the median of
  ``repeats`` timed executions of its compiled program (one untimed warmup,
  ``block_until_ready`` inside the clock), wrapped in a telemetry span so a
  JSONL trace records every trial.
* ``"counted"`` — on CPU hosts (CI, the forced-host-device benchmark
  subprocesses) wall time of emulated collectives is noise, so the score is
  a deterministic cost model over the compiled program's collectives:

      cost = sum_kinds count * LATENCY_WEIGHT + total_bytes / BYTES_SCALE

  i.e. one unit per collective launch (latency/dispatch) plus one unit per
  ``AUTO_CHUNK_TARGET_BYTES`` of payload (bandwidth).  Identical inputs give
  identical costs on every machine — counted sweeps are reproducible and
  their winners are pinned by tests, which is exactly why the resolver
  refuses to apply dtype knobs from counted entries (halved payloads win
  the byte term by construction, not by measurement).
"""
from __future__ import annotations

import statistics
import time

from repro import telemetry

# cost-model constants (counted mode).  LATENCY_WEIGHT is per collective
# launch; BYTES_SCALE normalizes payload bytes to the pipelined-FFT chunk
# target so one "full chunk" of traffic costs about one launch.
LATENCY_WEIGHT = 1.0
BYTES_SCALE = float(8 << 20)  # == repro.dist.pencil_fft.AUTO_CHUNK_TARGET_BYTES


def measure_mode() -> str:
    """``"wall"`` on real accelerators, ``"counted"`` on CPU hosts."""
    import jax

    return "wall" if jax.default_backend() in ("gpu", "tpu") else "counted"


def counted_cost(obj) -> float:
    """Deterministic cost of a compiled/lowered program (see module doc)."""
    coll = telemetry.count_collectives(obj)
    launches = coll.get("total_count", 0)
    total_bytes = coll.get("total_bytes", 0)
    return launches * LATENCY_WEIGHT + total_bytes / BYTES_SCALE


def wall_cost(fn, *args, repeats: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` with warmup + device sync."""
    import jax

    jax.block_until_ready(fn(*args))  # warmup / compile
    times = []
    for i in range(repeats):
        with telemetry.span("autotune.trial", repeat=i):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times.append(time.perf_counter() - t0)
    return statistics.median(times)


def measure_candidate(compiled, args, mode: str | None = None, repeats: int = 3) -> float:
    """Score one candidate program: counted cost or median wall time."""
    mode = mode or measure_mode()
    if mode == "counted":
        return counted_cost(compiled)
    return wall_cost(compiled, *args, repeats=repeats)

"""CLI: validate or inspect the tuning cache, or sweep a cell in-process.

    python -m repro.autotune --validate            # ci.sh schema gate
    python -m repro.autotune --show                # print resolved entries
    python -m repro.autotune --sweep 16,16,32      # sweep on this host's devices

``--validate`` exits non-zero on any schema problem (a MISSING cache file
is valid — the cache is optional by design), which is what ``ci.sh`` runs.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.autotune.cache import TuningCache, default_cache_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.autotune")
    ap.add_argument("--cache", default=None, help=f"cache path (default {default_cache_path()})")
    ap.add_argument("--validate", action="store_true", help="schema-check the cache; exit 1 on problems")
    ap.add_argument("--show", action="store_true", help="dump the cache cells as JSON")
    ap.add_argument("--sweep", default=None, metavar="N1,N2,N3",
                    help="sweep one grid cell on this process's devices (1xD mesh)")
    ap.add_argument("--beta", type=float, default=1e-2)
    args = ap.parse_args(argv)

    cache = TuningCache(args.cache)
    if args.validate:
        problems = cache.validate()
        for p in problems:
            print(f"autotune cache INVALID: {p}", file=sys.stderr)
        if not problems:
            print(f"autotune cache OK: {cache.path}")
        return 1 if problems else 0
    if args.show:
        print(json.dumps(cache.load(), indent=2, sort_keys=True))
        return 0
    if args.sweep:
        import jax
        import numpy as np
        from jax.sharding import Mesh

        from repro.autotune.search import sweep_cell
        from repro.core.grid import make_grid

        shape = tuple(int(x) for x in args.sweep.split(","))
        devs = np.asarray(jax.devices())
        mesh = Mesh(devs.reshape(1, devs.size), ("data", "model"))
        rec = sweep_cell(make_grid(shape), mesh, beta=args.beta, cache=cache)
        print(json.dumps(rec, indent=2))
        return 0
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())

"""Pallas TPU kernel: semi-Lagrangian tricubic interpolation.

The paper measures tricubic interpolation as ~60% of total runtime
(§III-C2: 64 values gathered per point, ~600 flops, compute-to-traffic
ratio O(1) — memory bound on x86) and lists "blocking, prefetching,
vectorization" as future work.  This kernel is the TPU-native realization
of exactly that blocking:

  * The output grid is tiled (T1, T2, T3); for each tile we DMA the
    matching input region *plus a halo* from HBM into a VMEM scratch
    buffer (explicit HBM->VMEM staging = the paper's "prefetching").
    The semi-Lagrangian structure bounds every departure point to
    ``|disp| <= H`` voxels from its home voxel (enforced by the planner,
    see core/planner.py), so one halo of width H+2 covers the whole
    4-point stencil of every query in the tile.
  * TPUs have no hardware gather, so the 4x4x4 stencil gather is recast
    as dense **one-hot contractions**: per dimension we build a (P, W)
    interpolation matrix A_d (4 cubic Lagrange weights scattered at the
    stencil rows) and contract A_1 on the MXU, A_2/A_3 on the VPU.
    This turns a scatter/gather-bound loop into systolic matmul work
    (the "vectorization" item, in MXU form).

Layout: VMEM working set per tile is
``W1*W2*W3*4B  (scratch) + P*W2*W3*4B (largest intermediate)`` with
``W_d = T_d + 2H + 3`` and ``P = T2*T3`` points per x1-slice sub-block;
defaults (tile 8x8x32, H=4) keep it under ~2 MB, MXU dims are padded by
Mosaic.  Validated in interpret mode against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import InterpPlan, lagrange_weights


def _onehot_matrix(i0, wts, p, w):
    """(P, W) one-hot interpolation matrix from stencil bases + weights.

    ``i0`` (P,) f32 — base (offset -1 row) index of each query in the local
    window; ``wts`` (4, P) — the cubic Lagrange weights to scatter.
    """
    rel = jax.lax.broadcasted_iota(jnp.float32, (p, w), 1) - i0[:, None]
    a = (
        wts[0][:, None] * (rel == -1.0)
        + wts[1][:, None] * (rel == 0.0)
        + wts[2][:, None] * (rel == 1.0)
        + wts[3][:, None] * (rel == 2.0)
    )
    return a.astype(jnp.float32)


def _kernel(fpad_hbm, disp_ref, out_ref, scratch, sem, *, tile, halo):
    t1, t2, t3 = tile
    w1 = t1 + 2 * halo + 3
    w2 = t2 + 2 * halo + 3
    w3 = t3 + 2 * halo + 3
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    # --- HBM -> VMEM: input tile + halo (padded array origin = -(halo+1)) ---
    cp = pltpu.make_async_copy(
        fpad_hbm.at[pl.ds(i * t1, w1), pl.ds(j * t2, w2), pl.ds(k * t3, w3)],
        scratch,
        sem,
    )
    cp.start()
    cp.wait()

    fld = scratch[...].astype(jnp.float32)
    flat23 = fld.reshape(w1, w2 * w3)

    def one_slice(s1, _):
        # queries of the x1-slice s1: local coords inside the scratch tile
        d1 = disp_ref[0, s1, :, :].astype(jnp.float32).reshape(-1)  # (P,)
        d2 = disp_ref[1, s1, :, :].astype(jnp.float32).reshape(-1)
        d3 = disp_ref[2, s1, :, :].astype(jnp.float32).reshape(-1)
        p = d1.shape[0]

        base2 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 0).reshape(-1)
        base3 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 1).reshape(-1)
        off = jnp.float32(halo + 1)
        q1 = s1.astype(jnp.float32) + off + d1
        q2 = base2 + off + d2
        q3 = base3 + off + d3

        def interp_matrix(q, w):
            i0 = jnp.floor(q)
            wts = lagrange_weights(q - i0)  # (4, P)
            return _onehot_matrix(i0, wts, p, w)  # (P, W)

        a1 = interp_matrix(q1, w1)
        a2 = interp_matrix(q2, w2)
        a3 = interp_matrix(q3, w3)

        # MXU: contract dim-1  (P, W1) @ (W1, W2*W3) -> (P, W2*W3)
        s = jnp.dot(a1, flat23, preferred_element_type=jnp.float32)
        s = s.reshape(p, w2, w3)
        # VPU: contract dim-2 and dim-3
        s = jnp.sum(a2[:, :, None] * s, axis=1)  # (P, W3)
        res = jnp.sum(a3 * s, axis=1)  # (P,)
        out_ref[pl.ds(s1, 1), :, :] = res.reshape(1, t2, t3).astype(out_ref.dtype)
        return _

    jax.lax.fori_loop(0, t1, one_slice, 0)


@functools.partial(jax.jit, static_argnames=("tile", "halo", "interpret"))
def tricubic_displace_pallas_padded(
    fpad: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Kernel entry for an ALREADY ghost-extended field.

    ``fpad`` is the (N1+2H+3, N2+2H+3, N3+2H+3) block with ``halo+1`` planes
    below and ``halo+2`` above each axis — exactly the layout produced both
    by ``jnp.pad(mode="wrap")`` (single device) and by the multi-hop
    ``ppermute`` ghost exchange in ``repro.dist.halo`` (per-shard block), so
    the distributed path dispatches here without an extra copy.
    """
    pad = 2 * halo + 3
    n1, n2, n3 = (s - pad for s in fpad.shape)
    t1, t2, t3 = tile
    assert n1 % t1 == 0 and n2 % t2 == 0 and n3 % t3 == 0, ((n1, n2, n3), tile)
    w = (t1 + 2 * halo + 3, t2 + 2 * halo + 3, t3 + 2 * halo + 3)
    grid = (n1 // t1, n2 // t2, n3 // t3)
    kern = functools.partial(_kernel, tile=tile, halo=halo)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # stays in HBM; DMA'd manually
            pl.BlockSpec((3, t1, t2, t3), lambda i, j, k: (0, i, j, k)),
        ],
        out_specs=pl.BlockSpec((t1, t2, t3), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((n1, n2, n3), fpad.dtype),
        scratch_shapes=[pltpu.VMEM(w, fpad.dtype), pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(fpad, disp)


@functools.partial(jax.jit, static_argnames=("tile", "halo", "interpret"))
def tricubic_displace_pallas(
    field: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Evaluate ``field`` at ``x + disp`` (grid units), |disp| <= halo.

    field: (N1, N2, N3) f32/bf16; disp: (3, N1, N2, N3).
    Wrap-around periodicity is materialized once by pre-padding the field
    by (halo+1, halo+2) planes per dimension (mode="wrap"); afterwards all
    kernel addressing is local and static.
    """
    n1, n2, n3 = field.shape
    t1, t2, t3 = tile
    assert n1 % t1 == 0 and n2 % t2 == 0 and n3 % t3 == 0, (field.shape, tile)
    lo, hi = halo + 1, halo + 2
    fpad = jnp.pad(field, ((lo, hi), (lo, hi), (lo, hi)), mode="wrap")
    return tricubic_displace_pallas_padded(
        fpad, disp, tile=tile, halo=halo, interpret=interpret
    )


# --------------------------------------------------------------------------- #
# batched multi-field kernels: one DMA + one set of A-matrices serves C
# channels.  The dim-1 contraction becomes (P, W1) @ (W1, C*W2*W3) on the
# MXU — C x the arithmetic per A-matrix build, real intensity gains on this
# memory-bound kernel — and the planned variants skip the per-point floor +
# Lagrange-polynomial work entirely (precomputed InterpPlan operators).
# --------------------------------------------------------------------------- #
def _contract_channels(a1, a2, a3, fld, out_ref, s1, *, tile, channels):
    """Shared epilogue: contract the 3 A-matrices against a (C,W1,W2,W3)
    scratch block and store the slice result (C, 1, T2, T3)."""
    t1, t2, t3 = tile
    c = channels
    w1, w2, w3 = fld.shape[1:]
    p = t2 * t3
    # MXU: (P, W1) x (C, W1, W2*W3) -> (P, C, W2*W3), contracting W1
    s = jax.lax.dot_general(
        a1,
        fld.reshape(c, w1, w2 * w3),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s.reshape(p, c, w2, w3)
    s = jnp.sum(a2[:, None, :, None] * s, axis=2)  # (P, C, W3)
    res = jnp.sum(a3[:, None, :] * s, axis=2)  # (P, C)
    out_ref[:, pl.ds(s1, 1), :, :] = res.T.reshape(c, 1, t2, t3).astype(out_ref.dtype)


def _kernel_many(fpad_hbm, disp_ref, out_ref, scratch, sem, *, tile, halo, channels):
    t1, t2, t3 = tile
    w1 = t1 + 2 * halo + 3
    w2 = t2 + 2 * halo + 3
    w3 = t3 + 2 * halo + 3
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    cp = pltpu.make_async_copy(
        fpad_hbm.at[:, pl.ds(i * t1, w1), pl.ds(j * t2, w2), pl.ds(k * t3, w3)],
        scratch,
        sem,
    )
    cp.start()
    cp.wait()
    fld = scratch[...].astype(jnp.float32)  # (C, W1, W2, W3)

    def one_slice(s1, _):
        d1 = disp_ref[0, s1, :, :].astype(jnp.float32).reshape(-1)  # (P,)
        d2 = disp_ref[1, s1, :, :].astype(jnp.float32).reshape(-1)
        d3 = disp_ref[2, s1, :, :].astype(jnp.float32).reshape(-1)
        p = d1.shape[0]
        base2 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 0).reshape(-1)
        base3 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 1).reshape(-1)
        off = jnp.float32(halo + 1)
        q1 = s1.astype(jnp.float32) + off + d1
        q2 = base2 + off + d2
        q3 = base3 + off + d3

        def interp_matrix(q, w):
            i0 = jnp.floor(q)
            return _onehot_matrix(i0, lagrange_weights(q - i0), p, w)

        _contract_channels(
            interp_matrix(q1, w1), interp_matrix(q2, w2), interp_matrix(q3, w3),
            fld, out_ref, s1, tile=tile, channels=channels,
        )
        return _

    jax.lax.fori_loop(0, t1, one_slice, 0)


def _kernel_planned(fpad_hbm, ib_ref, w_ref, out_ref, scratch, sem, *, tile, halo, channels):
    """Planned variant: stencil bases + weights arrive precomputed (InterpPlan
    blocks), so the per-point floor and weight polynomials are skipped — only
    the one-hot scatter (tile-local by construction) remains per call."""
    t1, t2, t3 = tile
    w1 = t1 + 2 * halo + 3
    w2 = t2 + 2 * halo + 3
    w3 = t3 + 2 * halo + 3
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    cp = pltpu.make_async_copy(
        fpad_hbm.at[:, pl.ds(i * t1, w1), pl.ds(j * t2, w2), pl.ds(k * t3, w3)],
        scratch,
        sem,
    )
    cp.start()
    cp.wait()
    fld = scratch[...].astype(jnp.float32)

    def one_slice(s1, _):
        ib1 = ib_ref[0, s1, :, :].astype(jnp.float32).reshape(-1)  # (P,)
        ib2 = ib_ref[1, s1, :, :].astype(jnp.float32).reshape(-1)
        ib3 = ib_ref[2, s1, :, :].astype(jnp.float32).reshape(-1)
        p = ib1.shape[0]
        base2 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 0).reshape(-1)
        base3 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 1).reshape(-1)
        off = jnp.float32(halo + 1)
        # floor(x + d) = x + ib at integral home coordinates, so the local
        # stencil base is directly home + ghost offset + ib
        i0_1 = s1.astype(jnp.float32) + off + ib1
        i0_2 = base2 + off + ib2
        i0_3 = base3 + off + ib3
        def wts(d):  # one (4, T2, T3) weight plane, sliced per x1-slice
            return w_ref[d, :, s1, :, :].astype(jnp.float32).reshape(4, p)

        a1 = _onehot_matrix(i0_1, wts(0), p, w1)
        a2 = _onehot_matrix(i0_2, wts(1), p, w2)
        a3 = _onehot_matrix(i0_3, wts(2), p, w3)
        _contract_channels(a1, a2, a3, fld, out_ref, s1, tile=tile, channels=channels)
        return _

    jax.lax.fori_loop(0, t1, one_slice, 0)


def _many_call(kern, fpad, operands, extra_in_specs, *, tile, halo, interpret):
    """Shared pallas_call plumbing of the batched entries."""
    pad = 2 * halo + 3
    c = fpad.shape[0]
    n1, n2, n3 = (s - pad for s in fpad.shape[1:])
    t1, t2, t3 = tile
    assert n1 % t1 == 0 and n2 % t2 == 0 and n3 % t3 == 0, ((n1, n2, n3), tile)
    w = (c, t1 + 2 * halo + 3, t2 + 2 * halo + 3, t3 + 2 * halo + 3)
    grid = (n1 // t1, n2 // t2, n3 // t3)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + extra_in_specs,
        out_specs=pl.BlockSpec((c, t1, t2, t3), lambda i, j, k: (0, i, j, k)),
        out_shape=jax.ShapeDtypeStruct((c, n1, n2, n3), fpad.dtype),
        scratch_shapes=[pltpu.VMEM(w, fpad.dtype), pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(fpad, *operands)


@functools.partial(jax.jit, static_argnames=("tile", "halo", "interpret"))
def tricubic_displace_pallas_padded_many(
    fpad: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched kernel entry for an ALREADY ghost-extended stack.

    ``fpad`` (C, N1+2H+3, N2+2H+3, N3+2H+3) — the layout produced by one
    stacked ``jnp.pad(mode="wrap")`` or by the single batched ghost exchange
    of ``repro.dist.halo``; ``disp`` (3, N1, N2, N3) shared by all channels.
    """
    t1, t2, t3 = tile
    kern = functools.partial(_kernel_many, tile=tile, halo=halo, channels=fpad.shape[0])
    spec = [pl.BlockSpec((3, t1, t2, t3), lambda i, j, k: (0, i, j, k))]
    return _many_call(kern, fpad, (disp,), spec, tile=tile, halo=halo, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "halo", "interpret"))
def tricubic_apply_pallas_padded(
    fpad: jnp.ndarray,
    ib: jnp.ndarray,
    w: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Planned batched kernel entry: ``fpad`` (C, ghost-extended), plus the
    ``InterpPlan`` operator arrays ``ib`` (3, N..) / ``w`` (3, 4, N..)."""
    t1, t2, t3 = tile
    kern = functools.partial(_kernel_planned, tile=tile, halo=halo, channels=fpad.shape[0])
    specs = [
        pl.BlockSpec((3, t1, t2, t3), lambda i, j, k: (0, i, j, k)),
        pl.BlockSpec((3, 4, t1, t2, t3), lambda i, j, k: (0, 0, i, j, k)),
    ]
    return _many_call(kern, fpad, (ib, w), specs, tile=tile, halo=halo, interpret=interpret)


def _wrap_pad_many(fields: jnp.ndarray, halo: int) -> jnp.ndarray:
    lo, hi = halo + 1, halo + 2
    return jnp.pad(fields, ((0, 0), (lo, hi), (lo, hi), (lo, hi)), mode="wrap")


def tricubic_displace_pallas_many(
    fields: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched ``fields`` (C, N1,N2,N3) at x + disp, |disp| <= halo."""
    return tricubic_displace_pallas_padded_many(
        _wrap_pad_many(fields, halo), disp, tile=tile, halo=halo, interpret=interpret
    )


def tricubic_apply_pallas(
    fields: jnp.ndarray,
    plan: InterpPlan,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Planned batched apply (periodic wrap materialized by pre-padding)."""
    return tricubic_apply_pallas_padded(
        _wrap_pad_many(fields, halo), plan.ib, plan.w,
        tile=tile, halo=halo, interpret=interpret,
    )

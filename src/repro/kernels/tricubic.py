"""Pallas TPU kernel: semi-Lagrangian tricubic interpolation.

The paper measures tricubic interpolation as ~60% of total runtime
(§III-C2: 64 values gathered per point, ~600 flops, compute-to-traffic
ratio O(1) — memory bound on x86) and lists "blocking, prefetching,
vectorization" as future work.  This kernel is the TPU-native realization
of exactly that blocking:

  * The output grid is tiled (T1, T2, T3); for each tile we DMA the
    matching input region *plus a halo* from HBM into a VMEM scratch
    buffer (explicit HBM->VMEM staging = the paper's "prefetching").
    The semi-Lagrangian structure bounds every departure point to
    ``|disp| <= H`` voxels from its home voxel (enforced by the planner,
    see core/planner.py), so one halo of width H+2 covers the whole
    4-point stencil of every query in the tile.
  * TPUs have no hardware gather, so the 4x4x4 stencil gather is recast
    as dense **one-hot contractions**: per dimension we build a (P, W)
    interpolation matrix A_d (4 cubic Lagrange weights scattered at the
    stencil rows) and contract A_1 on the MXU, A_2/A_3 on the VPU.
    This turns a scatter/gather-bound loop into systolic matmul work
    (the "vectorization" item, in MXU form).

Layout: VMEM working set per tile is
``W1*W2*W3*4B  (scratch) + P*W2*W3*4B (largest intermediate)`` with
``W_d = T_d + 2H + 3`` and ``P = T2*T3`` points per x1-slice sub-block;
defaults (tile 8x8x32, H=4) keep it under ~2 MB, MXU dims are padded by
Mosaic.  Validated in interpret mode against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import lagrange_weights


def _kernel(fpad_hbm, disp_ref, out_ref, scratch, sem, *, tile, halo):
    t1, t2, t3 = tile
    w1 = t1 + 2 * halo + 3
    w2 = t2 + 2 * halo + 3
    w3 = t3 + 2 * halo + 3
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    # --- HBM -> VMEM: input tile + halo (padded array origin = -(halo+1)) ---
    cp = pltpu.make_async_copy(
        fpad_hbm.at[pl.ds(i * t1, w1), pl.ds(j * t2, w2), pl.ds(k * t3, w3)],
        scratch,
        sem,
    )
    cp.start()
    cp.wait()

    fld = scratch[...].astype(jnp.float32)
    flat23 = fld.reshape(w1, w2 * w3)

    def one_slice(s1, _):
        # queries of the x1-slice s1: local coords inside the scratch tile
        d1 = disp_ref[0, s1, :, :].astype(jnp.float32).reshape(-1)  # (P,)
        d2 = disp_ref[1, s1, :, :].astype(jnp.float32).reshape(-1)
        d3 = disp_ref[2, s1, :, :].astype(jnp.float32).reshape(-1)
        p = d1.shape[0]

        base2 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 0).reshape(-1)
        base3 = jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 1).reshape(-1)
        off = jnp.float32(halo + 1)
        q1 = s1.astype(jnp.float32) + off + d1
        q2 = base2 + off + d2
        q3 = base3 + off + d3

        def interp_matrix(q, w):
            i0 = jnp.floor(q)
            t = q - i0
            wts = lagrange_weights(t)  # (4, P)
            rel = jax.lax.broadcasted_iota(jnp.float32, (p, w), 1) - i0[:, None]
            a = (
                wts[0][:, None] * (rel == -1.0)
                + wts[1][:, None] * (rel == 0.0)
                + wts[2][:, None] * (rel == 1.0)
                + wts[3][:, None] * (rel == 2.0)
            )
            return a.astype(jnp.float32)  # (P, W)

        a1 = interp_matrix(q1, w1)
        a2 = interp_matrix(q2, w2)
        a3 = interp_matrix(q3, w3)

        # MXU: contract dim-1  (P, W1) @ (W1, W2*W3) -> (P, W2*W3)
        s = jnp.dot(a1, flat23, preferred_element_type=jnp.float32)
        s = s.reshape(p, w2, w3)
        # VPU: contract dim-2 and dim-3
        s = jnp.sum(a2[:, :, None] * s, axis=1)  # (P, W3)
        res = jnp.sum(a3 * s, axis=1)  # (P,)
        out_ref[pl.ds(s1, 1), :, :] = res.reshape(1, t2, t3).astype(out_ref.dtype)
        return _

    jax.lax.fori_loop(0, t1, one_slice, 0)


@functools.partial(jax.jit, static_argnames=("tile", "halo", "interpret"))
def tricubic_displace_pallas_padded(
    fpad: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Kernel entry for an ALREADY ghost-extended field.

    ``fpad`` is the (N1+2H+3, N2+2H+3, N3+2H+3) block with ``halo+1`` planes
    below and ``halo+2`` above each axis — exactly the layout produced both
    by ``jnp.pad(mode="wrap")`` (single device) and by the multi-hop
    ``ppermute`` ghost exchange in ``repro.dist.halo`` (per-shard block), so
    the distributed path dispatches here without an extra copy.
    """
    pad = 2 * halo + 3
    n1, n2, n3 = (s - pad for s in fpad.shape)
    t1, t2, t3 = tile
    assert n1 % t1 == 0 and n2 % t2 == 0 and n3 % t3 == 0, ((n1, n2, n3), tile)
    w = (t1 + 2 * halo + 3, t2 + 2 * halo + 3, t3 + 2 * halo + 3)
    grid = (n1 // t1, n2 // t2, n3 // t3)
    kern = functools.partial(_kernel, tile=tile, halo=halo)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # stays in HBM; DMA'd manually
            pl.BlockSpec((3, t1, t2, t3), lambda i, j, k: (0, i, j, k)),
        ],
        out_specs=pl.BlockSpec((t1, t2, t3), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((n1, n2, n3), fpad.dtype),
        scratch_shapes=[pltpu.VMEM(w, fpad.dtype), pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(fpad, disp)


@functools.partial(jax.jit, static_argnames=("tile", "halo", "interpret"))
def tricubic_displace_pallas(
    field: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    tile: tuple[int, int, int] = (8, 8, 32),
    halo: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Evaluate ``field`` at ``x + disp`` (grid units), |disp| <= halo.

    field: (N1, N2, N3) f32/bf16; disp: (3, N1, N2, N3).
    Wrap-around periodicity is materialized once by pre-padding the field
    by (halo+1, halo+2) planes per dimension (mode="wrap"); afterwards all
    kernel addressing is local and static.
    """
    n1, n2, n3 = field.shape
    t1, t2, t3 = tile
    assert n1 % t1 == 0 and n2 % t2 == 0 and n3 % t3 == 0, (field.shape, tile)
    lo, hi = halo + 1, halo + 2
    fpad = jnp.pad(field, ((lo, hi), (lo, hi), (lo, hi)), mode="wrap")
    return tricubic_displace_pallas_padded(
        fpad, disp, tile=tile, halo=halo, interpret=interpret
    )

"""Pallas TPU kernel: fused spectral diagonal scaling (complex-as-planes).

The paper applies every elliptic operator as a diagonal scaling between the
forward and inverse FFT (§III-B1).  On TPU the spectrum lives as two real
planes (re, im) — a *real* diagonal symbol (biharmonic beta*k^4 here)
applies to both planes identically, and one VPU kernel can emit several
symbols in a single HBM pass:

    out_c = beta_c * |k|^4 * spec      (c = 1..n_out)

the k-space half of the fused ``reg_plus_project`` optimization
(EXPERIMENTS §Perf R1) as an explicit kernel: one spectrum read + n_out
writes instead of n_out full round trips.  Tiled over the (k2, k3) plane;
wavenumbers are rebuilt in-kernel from broadcasted iotas (fftfreq
convention), so no k-grid arrays stream from HBM at all.  Validated in
interpret mode against the numpy-built k-grids (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, n1, n2, n3, tile, betas):
    i, j = pl.program_id(0), pl.program_id(1)
    t2, t3 = tile
    idx2 = i * t2 + jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 0)
    idx3 = j * t3 + jax.lax.broadcasted_iota(jnp.float32, (t2, t3), 1)
    # fftfreq convention: 0..ceil(N/2)-1, then negative frequencies
    k2 = jnp.where(idx2 < (n2 + 1) // 2, idx2, idx2 - n2)
    k3 = jnp.where(idx3 < (n3 + 1) // 2, idx3, idx3 - n3)
    re = re_ref[...]  # (n1, t2, t3)
    im = im_ref[...]
    for c, beta in enumerate(betas):
        for k1i in range(n1):  # unrolled: k1 is a compile-time constant
            k1 = float(k1i) if k1i < (n1 + 1) // 2 else float(k1i - n1)
            ksq = k1 * k1 + k2 * k2 + k3 * k3
            sym = (beta * ksq * ksq).astype(jnp.float32)
            out_re_ref[c, k1i] = re[k1i] * sym
            out_im_ref[c, k1i] = im[k1i] * sym


@functools.partial(jax.jit, static_argnames=("betas", "tile", "interpret"))
def biharmonic_scale_pallas(
    spec_re: jnp.ndarray,  # (N1, N2, N3) f32 — real plane of the spectrum
    spec_im: jnp.ndarray,
    betas: tuple[float, ...] = (1.0,),
    tile: tuple[int, int] = (8, 128),
    interpret: bool = False,
):
    """Apply ``beta_c * |k|^4`` for every beta in one pass.

    Returns (out_re, out_im), each (len(betas), N1, N2, N3).
    """
    n1, n2, n3 = spec_re.shape
    t2, t3 = tile
    assert n2 % t2 == 0 and n3 % t3 == 0, (spec_re.shape, tile)
    kern = functools.partial(_kernel, n1=n1, n2=n2, n3=n3, tile=tile, betas=betas)
    grid = (n2 // t2, n3 // t3)
    c = len(betas)
    out_shape = [
        jax.ShapeDtypeStruct((c, n1, n2, n3), jnp.float32),
        jax.ShapeDtypeStruct((c, n1, n2, n3), jnp.float32),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, t2, t3), lambda i, j: (0, i, j)),
            pl.BlockSpec((n1, t2, t3), lambda i, j: (0, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((c, n1, t2, t3), lambda i, j: (0, 0, i, j)),
            pl.BlockSpec((c, n1, t2, t3), lambda i, j: (0, 0, i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(spec_re, spec_im)

"""Pure-jnp oracles for the interpolation kernels.

Tricubic **Lagrange** interpolation on a periodic grid: the paper's
64-coefficient (4^3) interpolant (§III-C2), 4th-order accurate, exact for
cubic polynomials, exact at grid points.  Coordinates are in *grid-index
units* (voxel i sits at coordinate i); periodic wrap is index arithmetic.

Two entry styles:

* ``tricubic_displace``/``tricubic_points`` — one field, weights rebuilt
  per call (the historical contract; kept as the bit-stable oracle).
* plan-once / apply-many — ``make_interp_plan(disp)`` precomputes the
  per-point stencil base offsets and separable Lagrange weights (the
  ~600-flop §III-C2 weight construction) once per displacement field;
  ``interp_apply`` then evaluates any number of fields, batched over a
  leading channel axis, against the cached operators.  The plan arrays are
  *layout-agnostic* (``ib`` is the offset from each point's home voxel, not
  an absolute index), so the same ``InterpPlan`` drives this oracle, the
  Pallas kernel (``kernels/tricubic.py``), and the per-shard mesh path
  (``dist/halo.py``) — and because its construction is purely elementwise
  in ``disp``, it is sharding-preserving (no collectives) on a mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def lagrange_weights(t: jnp.ndarray) -> jnp.ndarray:
    """Cubic Lagrange weights for stencil offsets (-1, 0, 1, 2) at frac t.

    Returns shape (4, *t.shape); rows sum to 1 for any t.
    """
    t = t.astype(jnp.promote_types(t.dtype, jnp.float32))
    w_m1 = -t * (t - 1.0) * (t - 2.0) / 6.0
    w_0 = (t + 1.0) * (t - 1.0) * (t - 2.0) / 2.0
    w_1 = -(t + 1.0) * t * (t - 2.0) / 2.0
    w_2 = (t + 1.0) * t * (t - 1.0) / 6.0
    return jnp.stack([w_m1, w_0, w_1, w_2])


def tricubic_points(field: jnp.ndarray, coords: jnp.ndarray) -> jnp.ndarray:
    """Interpolate ``field`` (N1,N2,N3) at ``coords`` (3, *Q) grid units.

    Fully vectorized gather of the 4x4x4 stencil; memory O(64 * #points).
    """
    acc = jnp.promote_types(jnp.result_type(field, coords), jnp.float32)
    qshape = coords.shape[1:]
    q = coords.reshape(3, -1)
    i0 = jnp.floor(q).astype(jnp.int32)
    t = (q - i0).astype(acc)

    n1, n2, n3 = field.shape
    offs = jnp.arange(-1, 3, dtype=jnp.int32)
    idx1 = jnp.mod(i0[0][None, :] + offs[:, None], n1)  # (4, M)
    idx2 = jnp.mod(i0[1][None, :] + offs[:, None], n2)
    idx3 = jnp.mod(i0[2][None, :] + offs[:, None], n3)

    flat = (
        idx1[:, None, None, :] * (n2 * n3)
        + idx2[None, :, None, :] * n3
        + idx3[None, None, :, :]
    )  # (4,4,4,M)
    vals = jnp.take(field.reshape(-1), flat, axis=0).astype(acc)

    w1 = lagrange_weights(t[0])  # (4, M)
    w2 = lagrange_weights(t[1])
    w3 = lagrange_weights(t[2])
    w = w1[:, None, None, :] * w2[None, :, None, :] * w3[None, None, :, :]
    out = jnp.sum(vals * w, axis=(0, 1, 2))
    return out.reshape(qshape).astype(field.dtype)


def tricubic_points_chunked(field: jnp.ndarray, coords: jnp.ndarray, chunk: int = 1 << 16) -> jnp.ndarray:
    """Memory-bounded variant: maps ``tricubic_points`` over point chunks."""
    qshape = coords.shape[1:]
    q = coords.reshape(3, -1)
    m = q.shape[1]
    pad = (-m) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad)))
    qc = qp.reshape(3, -1, chunk).transpose(1, 0, 2)  # (n_chunks, 3, chunk)
    out = jax.lax.map(lambda c: tricubic_points(field, c), qc)
    return out.reshape(-1)[:m].reshape(qshape).astype(field.dtype)


def tricubic_displace(field: jnp.ndarray, disp: jnp.ndarray) -> jnp.ndarray:
    """Semi-Lagrangian form: evaluate ``field`` at ``x_i + disp_i``.

    ``disp`` has shape (3, N1, N2, N3) in grid units; output (N1, N2, N3).
    """
    n1, n2, n3 = field.shape
    ct = jnp.promote_types(disp.dtype, jnp.float32)
    base = jnp.stack(
        jnp.meshgrid(
            jnp.arange(n1, dtype=ct),
            jnp.arange(n2, dtype=ct),
            jnp.arange(n3, dtype=ct),
            indexing="ij",
        ),
        axis=0,
    )
    return tricubic_points(field, base + disp.astype(ct))


def tricubic_displace_vec(field: jnp.ndarray, disp: jnp.ndarray) -> jnp.ndarray:
    """Vector-field variant: field (C, N1,N2,N3) -> (C, N1,N2,N3)."""
    return jax.vmap(lambda f: tricubic_displace(f, disp))(field)


# ------------------------------------------------------------------------- #
# plan-once / apply-many: precomputed interpolation operators
# ------------------------------------------------------------------------- #
class InterpPlan(NamedTuple):
    """Cached per-point interpolation operators for one displacement field.

    Built once per ``SLPlan`` departure field and reused by every transport
    solve and PCG Hessian matvec of a Newton iteration (the paper's
    "interpolation planner", §III-C2).

    ``ib``        (3, N1, N2, N3) int32 — ``floor(disp)``: stencil base
                  offset from each point's *home* voxel (layout-agnostic;
                  the home index is integral, so ``floor(x + d) = x + ib``).
                  A *cohort* plan (per-subject displacements
                  ``disp (S, 3, N..)``) carries ``ib (S, 3, N..)``.
    ``w``         (3, 4, N1, N2, N3) — separable cubic Lagrange weights at
                  the fractional part ``disp - ib``.  Default dtype is the
                  f32-promoted dtype of ``disp`` (f64 displacements keep
                  f64 weights); ``make_interp_plan(disp, dtype=bfloat16)``
                  packs the stored weights to bf16 — the plan is the
                  dominant per-iteration cache (12 weight planes per
                  departure field), so packing halves its HBM footprint
                  while every apply path still *contracts* in >= f32 (the
                  oracle upcasts to the accumulate dtype, the Pallas kernel
                  builds its one-hot A-matrices in f32 on the MXU).
    ``halo_need`` () f32 — ``ceil(max |disp|)``: the ghost-layer bound of
                  ``core.planner.required_halo``, cached so the distributed
                  budget check (``dist.halo.make_checked_interp``) costs
                  nothing per apply.
    """

    ib: jnp.ndarray
    w: jnp.ndarray
    halo_need: jnp.ndarray


def make_interp_plan(disp: jnp.ndarray, dtype=None) -> InterpPlan:
    """Precompute the tricubic operators for ``disp`` (3, N1, N2, N3).

    A cohort of per-subject displacements ``(S, 3, N1, N2, N3)`` yields a
    cohort plan (``ib (S,3,N..)``, ``w (S,3,4,N..)``); ``halo_need`` is the
    max over the cohort (one shared ghost-exchange budget per apply).

    By default weights keep the (f32-promoted) dtype of ``disp`` — an f64
    displacement yields f64 weights, so f64 solves lose nothing on the
    planned path.  ``dtype`` overrides the *storage* dtype of ``w`` (pass
    ``jnp.bfloat16`` to halve the plan's memory footprint); the weights are
    always *constructed* in the promoted dtype and only packed on store,
    and every apply upcasts back to the accumulate dtype before
    contracting.
    """
    d = disp.astype(jnp.promote_types(disp.dtype, jnp.float32))
    ibf = jnp.floor(d)
    # single (3,N..) -> (3,4,N..); cohort (S,3,N..) -> (S,3,4,N..)
    w = jnp.moveaxis(lagrange_weights(d - ibf), 0, -4)
    return InterpPlan(
        ib=ibf.astype(jnp.int32),
        w=w if dtype is None else w.astype(dtype),
        halo_need=jnp.ceil(jnp.max(jnp.abs(d))),
    )


def _gather_contract(flat_fields, flat_idx, w, m):
    """Shared apply arithmetic: 64-point gather + separable contraction.

    ``flat_fields`` (C, Ntot); ``flat_idx`` (4,4,4,M); ``w`` (3,4,M).
    Returns (C, M).  The stencil *indices and weights* are shared across
    channels (that is the batching win on this memory-bound gather — the
    ~600-flop/pt construction is paid once), but the gathers themselves run
    channel-at-a-time: a fused (C,4,4,4,M) gather thrashes cache/HBM at
    production sizes and measures slower than C sequential passes.
    Contracting one stencil axis at a time costs ~2*(64+16+4)
    flops/pt/channel instead of the 128 of a fused 64-term weighted sum.
    """
    idx = flat_idx.reshape(-1)
    outs = []
    for ci in range(flat_fields.shape[0]):
        vals = jnp.take(flat_fields[ci], idx).reshape(4, 4, 4, m)
        s = jnp.sum(vals * w[0][:, None, None, :], axis=0)  # (4,4,M)
        s = jnp.sum(s * w[1][:, None, :], axis=0)  # (4,M)
        outs.append(jnp.sum(s * w[2], axis=0))  # (M,)
    return jnp.stack(outs)


def _stencil_flat_indices(ib: jnp.ndarray, grid_shape, store_shape, lo: int | None):
    """Flattened (4,4,4,M) gather indices of every point's tricubic stencil.

    ``ib`` (3, M) stencil base offsets over a ``grid_shape`` block of points,
    gathered from a row-major ``store_shape`` array.  ``lo=None`` wraps
    periodically (store == grid); an integer ``lo`` addresses a ghost-padded
    block whose origin sits at padded index ``lo`` (no wrap).
    """
    n1, n2, n3 = grid_shape
    s1, s2, s3 = store_shape
    offs = jnp.arange(-1, 3, dtype=jnp.int32)
    home = [
        jax.lax.broadcasted_iota(jnp.int32, (n1, n2, n3), d).reshape(-1) for d in range(3)
    ]
    idx = [home[d][None, :] + ib[d][None, :] + offs[:, None] for d in range(3)]  # (4,M)
    if lo is None:
        idx = [jnp.mod(ix, n) for ix, n in zip(idx, (n1, n2, n3))]
    else:
        idx = [ix + jnp.int32(lo) for ix in idx]
    return (
        idx[0][:, None, None, :] * (s2 * s3)
        + idx[1][None, :, None, :] * s3
        + idx[2][None, None, :, :]
    )


def _interp_apply_impl(store: jnp.ndarray, plan: InterpPlan, lo: int | None) -> jnp.ndarray:
    """Shared planned-apply body of ``interp_apply``/``interp_apply_padded``."""
    n1, n2, n3 = plan.ib.shape[-3:]
    lead = store.shape[:-3]
    ff = store.reshape(-1, store.shape[-3] * store.shape[-2] * store.shape[-1])
    ib = plan.ib.reshape(3, -1)
    flat = _stencil_flat_indices(ib, (n1, n2, n3), store.shape[-3:], lo)
    acc = jnp.promote_types(jnp.result_type(store, plan.w), jnp.float32)
    # bf16-packed plans upcast here: the contraction always runs in >= f32
    w = plan.w.reshape(3, 4, -1).astype(acc)
    out = _gather_contract(ff.astype(acc), flat, w, ib.shape[1])
    return out.reshape(lead + (n1, n2, n3)).astype(store.dtype)


def interp_apply(fields: jnp.ndarray, plan: InterpPlan) -> jnp.ndarray:
    """Evaluate ``fields`` (..., N1,N2,N3) at the planned departure points.

    Leading dims are batched channels sharing one gather-index computation;
    periodic wrap by index arithmetic (valid for any displacement — also the
    exact global fallback of the distributed checked interp).

    With a *cohort* plan (``ib (S,3,N..)``) the fields carry the subject
    axis at position -4 — ``(..., S, N1,N2,N3)``, any leading dims batched
    channels — and each subject's slab is evaluated against its own
    operators (vmap over S; the per-subject arithmetic is bit-identical to
    the single-subject oracle).
    """
    if plan.ib.ndim == 5:  # cohort plan: per-subject operators
        def one(f, ib, w):
            return _interp_apply_impl(f, InterpPlan(ib, w, plan.halo_need), lo=None)

        return jax.vmap(one, in_axes=(-4, 0, 0), out_axes=-4)(fields, plan.ib, plan.w)
    return _interp_apply_impl(fields, plan, lo=None)


def interp_apply_padded(fpad: jnp.ndarray, plan: InterpPlan, lo: int) -> jnp.ndarray:
    """Planned apply on a ghost-extended block (no wrap): the per-shard body
    of the distributed halo interp.

    ``fpad`` (..., N1+lo+hi, N2+lo+hi, N3+lo+hi) with the block origin at
    padded index ``lo``; ``plan`` holds the *local* (block-shaped) operators.
    Cohort plans (``ib (S,3,n..)``) pair each subject's operators with the
    ``-4`` axis of ``fpad`` — the whole ``(C, S, ...)`` stack shares the one
    ghost exchange the caller already paid.
    """
    if plan.ib.ndim == 5:  # cohort plan
        def one(f, ib, w):
            return _interp_apply_impl(f, InterpPlan(ib, w, plan.halo_need), lo=lo)

        return jax.vmap(one, in_axes=(-4, 0, 0), out_axes=-4)(fpad, plan.ib, plan.w)
    return _interp_apply_impl(fpad, plan, lo=lo)


def tricubic_displace_many(fields: jnp.ndarray, disp: jnp.ndarray) -> jnp.ndarray:
    """Batched semi-Lagrangian form: ``fields`` (..., N1,N2,N3) at x + disp.

    One weight construction and one gather-index computation for the whole
    channel stack (vs C of each for C looped ``tricubic_displace`` calls).
    """
    return interp_apply(fields, make_interp_plan(disp))


# ------------------------------------------------------------------------- #
# oracle for the fused spectral diagonal-scale kernel
# ------------------------------------------------------------------------- #
def spectral_scale(spec_re: jnp.ndarray, spec_im: jnp.ndarray, scale: jnp.ndarray):
    """Elementwise real-scale of a complex spectrum stored as two real planes."""
    return spec_re * scale, spec_im * scale

"""Pure-jnp oracles for the interpolation kernels.

Tricubic **Lagrange** interpolation on a periodic grid: the paper's
64-coefficient (4^3) interpolant (§III-C2), 4th-order accurate, exact for
cubic polynomials, exact at grid points.  Coordinates are in *grid-index
units* (voxel i sits at coordinate i); periodic wrap is index arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lagrange_weights(t: jnp.ndarray) -> jnp.ndarray:
    """Cubic Lagrange weights for stencil offsets (-1, 0, 1, 2) at frac t.

    Returns shape (4, *t.shape); rows sum to 1 for any t.
    """
    t = t.astype(jnp.promote_types(t.dtype, jnp.float32))
    w_m1 = -t * (t - 1.0) * (t - 2.0) / 6.0
    w_0 = (t + 1.0) * (t - 1.0) * (t - 2.0) / 2.0
    w_1 = -(t + 1.0) * t * (t - 2.0) / 2.0
    w_2 = (t + 1.0) * t * (t - 1.0) / 6.0
    return jnp.stack([w_m1, w_0, w_1, w_2])


def tricubic_points(field: jnp.ndarray, coords: jnp.ndarray) -> jnp.ndarray:
    """Interpolate ``field`` (N1,N2,N3) at ``coords`` (3, *Q) grid units.

    Fully vectorized gather of the 4x4x4 stencil; memory O(64 * #points).
    """
    acc = jnp.promote_types(jnp.result_type(field, coords), jnp.float32)
    qshape = coords.shape[1:]
    q = coords.reshape(3, -1)
    i0 = jnp.floor(q).astype(jnp.int32)
    t = (q - i0).astype(acc)

    n1, n2, n3 = field.shape
    offs = jnp.arange(-1, 3, dtype=jnp.int32)
    idx1 = jnp.mod(i0[0][None, :] + offs[:, None], n1)  # (4, M)
    idx2 = jnp.mod(i0[1][None, :] + offs[:, None], n2)
    idx3 = jnp.mod(i0[2][None, :] + offs[:, None], n3)

    flat = (
        idx1[:, None, None, :] * (n2 * n3)
        + idx2[None, :, None, :] * n3
        + idx3[None, None, :, :]
    )  # (4,4,4,M)
    vals = jnp.take(field.reshape(-1), flat, axis=0).astype(acc)

    w1 = lagrange_weights(t[0])  # (4, M)
    w2 = lagrange_weights(t[1])
    w3 = lagrange_weights(t[2])
    w = w1[:, None, None, :] * w2[None, :, None, :] * w3[None, None, :, :]
    out = jnp.sum(vals * w, axis=(0, 1, 2))
    return out.reshape(qshape).astype(field.dtype)


def tricubic_points_chunked(field: jnp.ndarray, coords: jnp.ndarray, chunk: int = 1 << 16) -> jnp.ndarray:
    """Memory-bounded variant: maps ``tricubic_points`` over point chunks."""
    qshape = coords.shape[1:]
    q = coords.reshape(3, -1)
    m = q.shape[1]
    pad = (-m) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad)))
    qc = qp.reshape(3, -1, chunk).transpose(1, 0, 2)  # (n_chunks, 3, chunk)
    out = jax.lax.map(lambda c: tricubic_points(field, c), qc)
    return out.reshape(-1)[:m].reshape(qshape).astype(field.dtype)


def tricubic_displace(field: jnp.ndarray, disp: jnp.ndarray) -> jnp.ndarray:
    """Semi-Lagrangian form: evaluate ``field`` at ``x_i + disp_i``.

    ``disp`` has shape (3, N1, N2, N3) in grid units; output (N1, N2, N3).
    """
    n1, n2, n3 = field.shape
    ct = jnp.promote_types(disp.dtype, jnp.float32)
    base = jnp.stack(
        jnp.meshgrid(
            jnp.arange(n1, dtype=ct),
            jnp.arange(n2, dtype=ct),
            jnp.arange(n3, dtype=ct),
            indexing="ij",
        ),
        axis=0,
    )
    return tricubic_points(field, base + disp.astype(ct))


def tricubic_displace_vec(field: jnp.ndarray, disp: jnp.ndarray) -> jnp.ndarray:
    """Vector-field variant: field (C, N1,N2,N3) -> (C, N1,N2,N3)."""
    return jax.vmap(lambda f: tricubic_displace(f, disp))(field)


# ------------------------------------------------------------------------- #
# oracle for the fused spectral diagonal-scale kernel
# ------------------------------------------------------------------------- #
def spectral_scale(spec_re: jnp.ndarray, spec_im: jnp.ndarray, scale: jnp.ndarray):
    """Elementwise real-scale of a complex spectrum stored as two real planes."""
    return spec_re * scale, spec_im * scale

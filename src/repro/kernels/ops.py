"""Jitted dispatch layer over the interpolation kernels.

``method="auto"`` picks the Pallas kernel on TPU when the semi-Lagrangian
displacement bound fits the halo budget, and the pure-jnp oracle elsewhere
(CPU/GPU, or when the planner reports an unbounded displacement).  On this
CPU container the Pallas path runs in interpret mode (correctness only) —
the solver keeps the oracle path hot so wall-clock tests stay fast.

The first-class entry is ``make_interp``: an ``Interp`` executor implements
the solver-wide interpolation protocol —

    interp(field, disp)          field (..., N1,N2,N3), leading dims batched
    interp.make_plan(disp)       -> InterpPlan (precomputed operators)
    interp.apply_plan(fields, plan)

``core.planner.make_plan`` builds one ``InterpPlan`` per departure field
through ``make_plan`` and ``core.semilag`` binds ``apply_plan`` so every
transport solve and PCG Hessian matvec of a Newton iteration reuses the
cached weights.  ``repro.dist.halo`` implements the same protocol on the
pencil mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.tricubic import (
    tricubic_apply_pallas,
    tricubic_displace_pallas,
    tricubic_displace_pallas_many,
)


def _pick_tile(shape: tuple[int, int, int]) -> tuple[int, int, int] | None:
    def best(n, cands):
        for c in cands:
            if n % c == 0:
                return c
        return None

    t1 = best(shape[0], (8, 4, 2, 1))
    t2 = best(shape[1], (8, 4, 2, 1))
    t3 = best(shape[2], (64, 32, 16, 8))
    if t3 is None:
        return None
    return (t1, t2, t3)


def _resolve(method: str, shape3, tile):
    """Single dispatch policy: "auto" -> the Pallas kernel on TPU, the jnp
    oracle elsewhere; Pallas additionally needs a valid tile for the shape
    (falls back to "ref" otherwise).  Returns (method, tile)."""
    if method == "auto":
        method = "pallas" if jax.default_backend() == "tpu" else "ref"
    if method == "pallas":
        tile = tile or _pick_tile(tuple(shape3))
        if tile is None:
            method = "ref"
    return method, tile


def tricubic_displace(
    field: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    method: str = "auto",
    halo: int = 4,
    tile: tuple[int, int, int] | None = None,
) -> jnp.ndarray:
    """field (N1,N2,N3) sampled at x + disp; disp (3,N1,N2,N3), grid units."""
    method, tile = _resolve(method, field.shape, tile)
    if method == "ref":
        return ref.tricubic_displace(field, disp)
    interpret = jax.default_backend() != "tpu"
    return tricubic_displace_pallas(field, disp, tile=tile, halo=halo, interpret=interpret)


def tricubic_displace_vec(field: jnp.ndarray, disp: jnp.ndarray, **kw) -> jnp.ndarray:
    """Vector/stacked fields: (C, N1,N2,N3)."""
    return jax.vmap(lambda f: tricubic_displace(f, disp, **kw))(field)


def tricubic_displace_many(
    fields: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    method: str = "auto",
    halo: int = 4,
    tile: tuple[int, int, int] | None = None,
) -> jnp.ndarray:
    """Batched multi-field entry: ``fields`` (..., N1,N2,N3), leading dims
    are channels sharing one weight construction / one kernel launch.

    A cohort displacement ``disp (S, 3, N..)`` pairs subject ``s`` with the
    ``-4`` axis of ``fields`` (``(..., S, N..)``); the per-subject gathers
    run on the jnp oracle (the Pallas kernel is single-subject)."""
    shape3 = fields.shape[-3:]
    lead = fields.shape[:-3]
    if disp.ndim == 5:  # cohort: per-subject departure fields
        return ref.tricubic_displace_many(fields, disp)
    method, tile = _resolve(method, shape3, tile)
    if method == "ref":
        return ref.tricubic_displace_many(fields, disp)
    interpret = jax.default_backend() != "tpu"
    out = tricubic_displace_pallas_many(
        fields.reshape((-1,) + shape3), disp, tile=tile, halo=halo, interpret=interpret
    )
    return out.reshape(lead + shape3)


class Interp:
    """Plan-aware single-device interpolation executor (see module docstring).

    ``method``/``halo``/``tile`` follow ``tricubic_displace``; the Pallas
    budget ``halo`` also caps plan displacements on that path (checked by
    the caller via ``core.planner.required_halo``).  ``plan_dtype`` packs
    the cached ``InterpPlan`` weights (e.g. ``jnp.bfloat16`` halves the
    plan's memory; contraction stays f32 — see ``ref.make_interp_plan``).
    """

    def __init__(self, method: str = "auto", halo: int = 4, tile=None, plan_dtype=None):
        self.method = method
        self.halo = halo
        self.tile = tile
        self.plan_dtype = plan_dtype

    def _resolved(self, shape3):
        return _resolve(self.method, shape3, self.tile)

    def __call__(self, field: jnp.ndarray, disp: jnp.ndarray) -> jnp.ndarray:
        if field.ndim == 3:
            return tricubic_displace(
                field, disp, method=self.method, halo=self.halo, tile=self.tile
            )
        return tricubic_displace_many(
            field, disp, method=self.method, halo=self.halo, tile=self.tile
        )

    def make_plan(self, disp: jnp.ndarray) -> ref.InterpPlan:
        return ref.make_interp_plan(disp, dtype=self.plan_dtype)

    def apply_plan(self, fields: jnp.ndarray, plan: ref.InterpPlan) -> jnp.ndarray:
        shape3 = fields.shape[-3:]
        method, tile = self._resolved(shape3)
        if method == "ref" or plan.ib.ndim == 5:  # cohort plans: oracle path
            return ref.interp_apply(fields, plan)
        lead = fields.shape[:-3]
        interpret = jax.default_backend() != "tpu"
        out = tricubic_apply_pallas(
            fields.reshape((-1,) + shape3), plan,
            tile=tile, halo=self.halo, interpret=interpret,
        )
        return out.reshape(lead + shape3)


def make_interp(method: str = "auto", halo: int = 4, tile=None, plan_dtype=None) -> Interp:
    """Factory for the solver's ``interp=`` slots (kept for API symmetry
    with ``repro.dist.halo.make_halo_interp``)."""
    return Interp(method=method, halo=halo, tile=tile, plan_dtype=plan_dtype)


def tricubic_points(field: jnp.ndarray, coords: jnp.ndarray, chunk: int | None = None) -> jnp.ndarray:
    """Arbitrary (unbounded) query points — oracle path only."""
    if chunk:
        return ref.tricubic_points_chunked(field, coords, chunk)
    return ref.tricubic_points(field, coords)


@functools.partial(jax.jit, static_argnames=())
def max_displacement(disp: jnp.ndarray) -> jnp.ndarray:
    """Per-axis max |disp| in grid units — the planner's halo requirement."""
    return jnp.max(jnp.abs(disp))

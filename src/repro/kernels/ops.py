"""Jitted dispatch layer over the interpolation kernels.

``method="auto"`` picks the Pallas kernel on TPU when the semi-Lagrangian
displacement bound fits the halo budget, and the pure-jnp oracle elsewhere
(CPU/GPU, or when the planner reports an unbounded displacement).  On this
CPU container the Pallas path runs in interpret mode (correctness only) —
the solver keeps the oracle path hot so wall-clock tests stay fast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.tricubic import tricubic_displace_pallas


def _pick_tile(shape: tuple[int, int, int]) -> tuple[int, int, int] | None:
    def best(n, cands):
        for c in cands:
            if n % c == 0:
                return c
        return None

    t1 = best(shape[0], (8, 4, 2, 1))
    t2 = best(shape[1], (8, 4, 2, 1))
    t3 = best(shape[2], (64, 32, 16, 8))
    if t3 is None:
        return None
    return (t1, t2, t3)


def tricubic_displace(
    field: jnp.ndarray,
    disp: jnp.ndarray,
    *,
    method: str = "auto",
    halo: int = 4,
    tile: tuple[int, int, int] | None = None,
) -> jnp.ndarray:
    """field (N1,N2,N3) sampled at x + disp; disp (3,N1,N2,N3), grid units."""
    if method == "auto":
        method = "pallas" if jax.default_backend() == "tpu" else "ref"
    if method == "ref":
        return ref.tricubic_displace(field, disp)
    tile = tile or _pick_tile(field.shape)
    if tile is None:
        return ref.tricubic_displace(field, disp)
    interpret = jax.default_backend() != "tpu"
    return tricubic_displace_pallas(field, disp, tile=tile, halo=halo, interpret=interpret)


def tricubic_displace_vec(field: jnp.ndarray, disp: jnp.ndarray, **kw) -> jnp.ndarray:
    """Vector/stacked fields: (C, N1,N2,N3)."""
    return jax.vmap(lambda f: tricubic_displace(f, disp, **kw))(field)


def tricubic_points(field: jnp.ndarray, coords: jnp.ndarray, chunk: int | None = None) -> jnp.ndarray:
    """Arbitrary (unbounded) query points — oracle path only."""
    if chunk:
        return ref.tricubic_points_chunked(field, coords, chunk)
    return ref.tricubic_points(field, coords)


@functools.partial(jax.jit, static_argnames=())
def max_displacement(disp: jnp.ndarray) -> jnp.ndarray:
    """Per-axis max |disp| in grid units — the planner's halo requirement."""
    return jnp.max(jnp.abs(disp))

"""AdamW with ZeRO-style sharded states, clipping, schedule, compression.

* Optimizer moments are stored in f32 and sharded with the *same*
  PartitionSpecs as their parameters — with FSDP rules that means every
  state shard lives on the chips that own the parameter shard (ZeRO-2/3
  behavior falls out of GSPMD; no separate machinery needed).
* Optional gradient compression: grads are cast to bf16 *before* the
  cross-replica reduction boundary and restored to f32 inside the update
  (halves DP all-reduce bytes; see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    compress_grads: bool = False  # bf16 reduction


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs):
    """Moments inherit parameter specs; step is replicated."""
    from jax.sharding import PartitionSpec as P

    is_spec = lambda s: isinstance(s, P)
    return {
        "mu": jax.tree.map(lambda s: s, param_specs, is_leaf=is_spec),
        "nu": jax.tree.map(lambda s: s, param_specs, is_leaf=is_spec),
        "step": P(),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    if cfg.compress_grads:
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm, "lr": lr}

"""Halo-exchange tricubic interpolation (paper §III-C2, Alg. 1).

The semi-Lagrangian solver evaluates fields at departure points that the
planner bounds to ``|disp| <= halo`` voxels from their home voxel
(``repro.core.planner.required_halo``).  On a 2-D pencil mesh each device
therefore only needs its own block plus a ghost layer wide enough to cover
``halo`` plus the tricubic stencil's (-1..+2) reach — the paper's Alg. 1
scatter phase, realized here as neighbor-block ``lax.ppermute`` hops
inside ``shard_map`` instead of MPI_Alltoallv.

Ghost widths: a query ``q = i + d`` with ``|d| < halo`` touches stencil
rows ``floor(q)-1 .. floor(q)+2``, i.e. ``halo+1`` cells below the block
and ``halo+2`` above.  When the ghost layer is wider than the shard
itself (claire-brain's halo=8 on 16-wide production shards, or halo=9 on
4-wide test shards) the exchange takes ``ceil(width / shard_width)``
ppermute hops per direction — whole neighbor blocks are forwarded
ring-style and the overhang is trimmed.  The unsharded third axis wraps
locally.  After the exchange, interpolation is embarrassingly local and
reuses the ``kernels/ref.py`` oracle arithmetic verbatim, so the
distributed path is bit-comparable to the single-device one.

Batched multi-field contract: the interp built here accepts ``fields``
with leading channel dims (C, N1, N2, N3).  The whole C-stack rides ONE
ghost-exchange sequence per call — the per-direction ``ppermute`` count is
independent of C, a C x cut in collective-latency count versus C looped
scalar calls (pinned by ``tests/test_dist_interp.py``, measured by
``benchmarks`` suite ``interp``).  It also implements the plan protocol of
``core.semilag``: ``make_plan(disp)`` precomputes the per-point stencil
operators (elementwise in ``disp`` — sharding-preserving, no collectives)
and ``apply_plan(fields, plan)`` interpolates against the cached weights,
so every transport of a Newton iteration skips the per-call weight
construction.

Cohort contract: per-subject displacements ``(S, 3, N..)`` (or a cohort
``InterpPlan``) pair with fields carrying the subject axis at ``-4`` —
``(C, S, N1, N2, N3)``.  The whole (C, S) stack still rides ONE
ghost-exchange sequence per call, so the per-call collective count is
independent of the cohort size — the amortization ``gn.solve_cohort``
is built on (counted-collective pin in ``tests/test_cohort.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry
from repro.core.grid import Grid
from repro.kernels import ref
from repro.kernels.tricubic import (
    tricubic_apply_pallas_padded,
    tricubic_displace_pallas_padded_many,
)
from repro.launch.mesh import mesh_axes_size


def _wrap_pad(x: jnp.ndarray, lo: int, hi: int, axis: int) -> jnp.ndarray:
    """Periodic pad along an unsharded local axis (index arithmetic, so the
    pad may exceed the axis length)."""
    n = x.shape[axis]
    idx = jnp.arange(-lo, n + hi) % n
    return jnp.take(x, idx, axis=axis)


def _neighbor_blocks(x: jnp.ndarray, name, p: int, hops: int, from_left: bool):
    """Blocks of the ``hops`` nearest ring neighbors in one direction.

    ``from_left=True`` returns ``[block_{i-1}, block_{i-2}, ...]`` at device
    ``i`` (periodic); each hop forwards the previously received block.
    """
    step = -1 if from_left else 1
    perm = [((j + step) % p, j) for j in range(p)]
    out, cur = [], x
    for _ in range(hops):
        cur = lax.ppermute(cur, name, perm)
        out.append(cur)
    return out


def _exchange_axis(x: jnp.ndarray, name, p: int, lo: int, hi: int, axis: int):
    """Extend ``x`` by ``lo``/``hi`` ghost cells along a sharded local axis.

    Leading (channel) dims of ``x`` ride along: the ppermute count per
    direction depends only on the ghost width, never on the stack size.
    """
    n = x.shape[axis]
    if p == 1:
        return _wrap_pad(x, lo, hi, axis)
    kl, kh = -(-lo // n), -(-hi // n)
    # single hop (the common case): permute only the ghost strip; multi-hop
    # forwards whole blocks, since later hops need the full previous block
    send_l = x if kl > 1 else lax.slice_in_dim(x, n - lo, n, axis=axis)
    send_r = x if kh > 1 else lax.slice_in_dim(x, 0, hi, axis=axis)
    left = _neighbor_blocks(send_l, name, p, hops=kl, from_left=True)
    right = _neighbor_blocks(send_r, name, p, hops=kh, from_left=False)
    lcat = jnp.concatenate(list(reversed(left)), axis=axis)
    rcat = jnp.concatenate(right, axis=axis)
    return jnp.concatenate(
        [
            lax.slice_in_dim(lcat, lcat.shape[axis] - lo, lcat.shape[axis], axis=axis),
            x,
            lax.slice_in_dim(rcat, 0, hi, axis=axis),
        ],
        axis=axis,
    )


def _exchange_ghosts(f: jnp.ndarray, *, a1, a2, p1, p2, lo, hi) -> jnp.ndarray:
    """One full ghost exchange of a local block (..., n1l, n2l, n3)."""
    nd = f.ndim
    fp = _exchange_axis(f, a1, p1, lo, hi, axis=nd - 3)
    fp = _exchange_axis(fp, a2, p2, lo, hi, axis=nd - 2)
    return _wrap_pad(fp, lo, hi, axis=nd - 1)


def _interp_local_many(f, d, *, a1, a2, p1, p2, lo, hi, kernel="ref"):
    """Batched per-device body: ``f`` (C, n1l, n2l, n3) rides ONE exchange.

    Scalar fields go through here too (C=1, reshaped by the dispatcher) —
    one exchange/dispatch/fallback implementation for every arity.

    ``kernel="pallas"`` dispatches the per-shard interpolation to the
    VMEM-blocked Pallas kernel (``kernels/tricubic.py``): the ghost-extended
    block IS the kernel's padded-field layout (``halo+1`` planes below,
    ``halo+2`` above), so the exchange and the kernel compose with no copy.
    Falls back to the ``kernels/ref.py`` gather when the shard shape has no
    valid tile or the kernel would run interpreted off-TPU.
    """
    fp = _exchange_ghosts(f, a1=a1, a2=a2, p1=p1, p2=p2, lo=lo, hi=hi)
    shape3 = f.shape[1:]
    if kernel in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import _pick_tile

        tile = _pick_tile(shape3)
        if tile is not None:
            return tricubic_displace_pallas_padded_many(
                fp, d, tile=tile, halo=lo - 1, interpret=(kernel == "pallas_interpret")
            )
    return ref.interp_apply_padded(fp, ref.make_interp_plan(d), lo)


def _apply_local_many(f, ib, w, *, a1, a2, p1, p2, lo, hi, kernel="ref"):
    """Planned batched body: precomputed local operators, one exchange."""
    fp = _exchange_ghosts(f, a1=a1, a2=a2, p1=p1, p2=p2, lo=lo, hi=hi)
    shape3 = f.shape[1:]
    if kernel in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import _pick_tile

        tile = _pick_tile(shape3)
        if tile is not None:
            return tricubic_apply_pallas_padded(
                fp, ib, w, tile=tile, halo=lo - 1, interpret=(kernel == "pallas_interpret")
            )
    need = jnp.zeros((), jnp.float32)  # bound enforced by the checked wrapper
    return ref.interp_apply_padded(fp, ref.InterpPlan(ib=ib, w=w, halo_need=need), lo)


def _interp_local_cohort(f, d, *, a1, a2, p1, p2, lo, hi, kernel="ref"):
    """Cohort per-device body: ``f`` (C, S, n1l, n2l, n3) against per-subject
    displacements ``d`` (S, 3, n1l, n2l, n3).

    The ENTIRE (C, S) stack rides the one ghost-exchange sequence — the
    ppermute count is independent of both the channel count and the cohort
    size, which is the collective-amortization the cohort solver banks on.
    The per-shard interpolation is the ``kernels/ref.py`` cohort gather
    (``interp_apply_padded`` vmaps each subject against its own operators);
    the Pallas kernel keeps its single-subject scope.
    """
    fp = _exchange_ghosts(f, a1=a1, a2=a2, p1=p1, p2=p2, lo=lo, hi=hi)
    return ref.interp_apply_padded(fp, ref.make_interp_plan(d), lo)


def _apply_local_cohort(f, ib, w, *, a1, a2, p1, p2, lo, hi, kernel="ref"):
    """Planned cohort body: precomputed per-subject operators, one exchange."""
    fp = _exchange_ghosts(f, a1=a1, a2=a2, p1=p1, p2=p2, lo=lo, hi=hi)
    need = jnp.zeros((), jnp.float32)  # bound enforced by the checked wrapper
    return ref.interp_apply_padded(fp, ref.InterpPlan(ib=ib, w=w, halo_need=need), lo)


def _resolve_method(method: str) -> str:
    """"auto" -> the Pallas kernel on TPU, the jnp gather elsewhere.

    "pallas" forces the kernel (interpret mode off-TPU: correctness tests);
    "ref" forces the gather.
    """
    if method == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if method == "pallas" and jax.default_backend() != "tpu":
        return "pallas_interpret"
    return method


def make_halo_interp(grid: Grid, mesh, axes=("data", "model"), halo: int = 4,
                     method: str = "auto", plan_dtype=None):
    """Build the distributed ``interp`` callable (batched + plan protocol).

    Plugs into every ``interp=`` slot of ``repro.core.semilag`` /
    ``repro.core.planner``: ``fields`` is ``(..., N1, N2, N3)`` sharded
    ``P(a1, a2, None)`` over the trailing space axes (leading channel dims
    replicated as a stack), ``disp`` a ``(3, N1, N2, N3)`` grid-unit
    displacement sharded ``P(None, a1, a2, None)`` with ``|disp| < halo``.
    ``method`` picks the per-shard kernel (see ``_resolve_method``).

    The returned callable carries ``make_plan`` / ``apply_plan`` so the
    solver's plan-once/apply-many path works on the mesh: plan construction
    is elementwise (stays sharded, no collectives) and the planned apply
    runs the same single ghost-exchange sequence per call.  ``plan_dtype``
    packs the cached plan weights (``jnp.bfloat16`` halves the plan's HBM
    footprint per shard; the per-shard contraction still upcasts to f32 —
    see ``ref.make_interp_plan``).
    """
    a1, a2 = tuple(axes)
    p1, p2 = mesh_axes_size(mesh, a1), mesh_axes_size(mesh, a2)
    n1, n2, _ = grid.shape
    if n1 % p1 or n2 % p2:
        raise ValueError(f"grid {grid.shape} not divisible by pencil mesh ({p1},{p2})")
    kw = dict(a1=a1, a2=a2, p1=p1, p2=p2, lo=halo + 1, hi=halo + 2,
              kernel=_resolve_method(method))
    smkw = dict(mesh=mesh, check_rep=False)
    s_stack = P(None, a1, a2, None)
    s_w = P(None, None, a1, a2, None)
    sm4 = shard_map(partial(_interp_local_many, **kw), in_specs=(s_stack, s_stack),
                    out_specs=s_stack, **smkw)
    sm_apply = shard_map(partial(_apply_local_many, **kw), in_specs=(s_stack, s_stack, s_w),
                         out_specs=s_stack, **smkw)
    # cohort variants: a subjects axis rides between the channel stack and
    # space — (C, S, n1, n2, n3) fields against (S, 3, n..) displacements /
    # (S, 3, 4, n..) plan weights, all replicated over the leading dims
    s_coh = P(None, None, a1, a2, None)
    s_coh_w = P(None, None, None, a1, a2, None)
    sm5 = shard_map(partial(_interp_local_cohort, **kw), in_specs=(s_coh, s_coh),
                    out_specs=s_coh, **smkw)
    sm_apply5 = shard_map(partial(_apply_local_cohort, **kw), in_specs=(s_coh, s_coh, s_coh_w),
                          out_specs=s_coh, **smkw)

    def interp(field, disp):
        if disp.ndim == 5:  # cohort: per-subject displacements
            lead = field.shape[:-4]
            out = sm5(field.reshape((-1,) + field.shape[-4:]), disp)
            return out.reshape(lead + out.shape[-4:])
        lead = field.shape[:-3]
        out = sm4(field.reshape((-1,) + field.shape[-3:]), disp)
        return out.reshape(lead + out.shape[-3:])

    def apply_plan(fields, plan: ref.InterpPlan):
        if plan.ib.ndim == 5:  # cohort plan: per-subject operators
            lead = fields.shape[:-4]
            out = sm_apply5(fields.reshape((-1,) + fields.shape[-4:]), plan.ib, plan.w)
            return out.reshape(lead + out.shape[-4:])
        lead = fields.shape[:-3]
        out = sm_apply(fields.reshape((-1,) + fields.shape[-3:]), plan.ib, plan.w)
        return out.reshape(lead + out.shape[-3:])

    interp.make_plan = partial(ref.make_interp_plan, dtype=plan_dtype)
    interp.apply_plan = apply_plan
    return interp


# --------------------------------------------------------------------------- #
# dynamic halo budget (ROADMAP): the ghost exchange is only correct while
# every departure point stays within ``halo`` voxels of its home voxel
# (``repro.core.planner.required_halo``'s bound).  A line-search step that
# overshoots would silently read ring-wrapped garbage from the local block;
# the checked wrapper turns that into an explicit runtime branch.
# --------------------------------------------------------------------------- #
def make_checked_interp(halo_interp, mesh, axes, halo: int, on_overflow: str = "error"):
    """Wrap a halo interp with a per-call displacement-bound check.

    ``on_overflow``:
      * "error"  — cheap default: the output is NaN-poisoned and a debug
        message printed when ``ceil(max|disp|) > halo``; NaNs surface in the
        line search / convergence test instead of a silently wrong field.
      * "gather" — correct-but-slow fallback: a ``lax.cond`` switches to the
        global ``kernels/ref.py`` gather (XLA all-gathers the field), so the
        iteration stays exact at the cost of one global collective.

    On the planned path the bound comes for free off the cached
    ``InterpPlan.halo_need`` (one max-reduction per Newton iteration, paid
    at plan-build time, instead of one per interp call).
    """
    from repro.kernels.ops import max_displacement

    a1, a2 = tuple(axes)
    budget = jnp.float32(halo)

    def out_sharding(ndim):
        lead = (None,) * (ndim - 3)
        return NamedSharding(mesh, P(*lead, a1, a2, None))

    def _record_overflow(n):
        # host-side: count the violation and render the legacy warning line
        # (echo keeps the printed diagnostic; sinks additionally get a
        # ``halo_budget_exceeded`` counter event with the offending bound)
        telemetry.counter(
            "halo_budget_exceeded", echo=True,
            required=float(n), budget=halo, mode=on_overflow,
        )

    def warn_if(ok, need):
        lax.cond(
            ok,
            lambda n: None,
            lambda n: jax.debug.callback(_record_overflow, n),
            need,
        )

    def checked(field, disp):
        need = jnp.ceil(max_displacement(disp))
        ok = need <= budget
        warn_if(ok, need)
        if on_overflow == "gather":
            return lax.cond(
                ok,
                halo_interp,
                lambda f, d: lax.with_sharding_constraint(
                    ref.tricubic_displace_many(f, d), out_sharding(field.ndim)
                ),
                field,
                disp,
            )
        out = halo_interp(field, disp)
        return out + jnp.where(ok, 0.0, jnp.nan).astype(out.dtype)

    def checked_apply(fields, plan: ref.InterpPlan):
        ok = plan.halo_need <= budget
        warn_if(ok, plan.halo_need)
        if on_overflow == "gather":
            # ref.interp_apply wraps by global index arithmetic — exact for
            # any displacement, so it is the planned gather fallback
            return lax.cond(
                ok,
                halo_interp.apply_plan,
                lambda f, p: lax.with_sharding_constraint(
                    ref.interp_apply(f, p), out_sharding(fields.ndim)
                ),
                fields,
                plan,
            )
        out = halo_interp.apply_plan(fields, plan)
        return out + jnp.where(ok, 0.0, jnp.nan).astype(out.dtype)

    checked.make_plan = halo_interp.make_plan
    checked.apply_plan = checked_apply
    return checked

"""Distributed-memory solver layer (paper §III-C, Fig. 4, Alg. 1).

The paper's two communication-bound primitives, expressed as JAX SPMD
programs over a 2-D device mesh:

* ``repro.dist.pencil_fft.PencilFFT`` — the 2-D pencil-decomposed parallel
  FFT (``shard_map`` + ``lax.all_to_all`` transposes), drop-in for
  ``repro.core.spectral.LocalFFT``.
* ``repro.dist.halo`` — ghost-layer (halo) exchange + local tricubic
  interpolation for the semi-Lagrangian transport solves, the TPU analogue
  of Algorithm 1's scatter phase.
* ``repro.dist.context.DistContext`` — ties both to a concrete
  (grid, mesh, axes, halo) choice and hands the solver sharded inputs.
"""
from repro.dist.context import DistContext
from repro.dist.halo import make_halo_interp
from repro.dist.pencil_fft import PencilFFT

__all__ = ["DistContext", "PencilFFT", "make_halo_interp"]

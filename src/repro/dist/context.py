"""DistContext: one object tying grid + mesh + pencil FFT + halo interp.

Everything the solver needs to run distributed is derived from a
``(grid, mesh, axes, halo)`` choice:

    ctx = DistContext(grid, mesh, halo=8)            # single-pod 16x16
    ctx = DistContext(grid, mesh,                     # multi-pod 2x16x16
                      axes=(("pod", "data"), "model"), halo=8)

    ops    = ctx.ops      # SpectralOps over the PencilFFT backend
    interp = ctx.interp   # halo-exchange tricubic, plugs into semilag:
                          #   batched (C,N1,N2,N3) fields ride one ghost
                          #   exchange; make_plan/apply_plan cache the
                          #   interpolation weights per Newton iteration
    v      = ctx.shard_vector(v); rho = ctx.shard_scalar(rho)

``axes`` names the two pencil dimensions; tuple entries fold several mesh
axes into one pencil dimension (the multi-pod layout treats pod x data as
a single ``p1``).  The solver code itself (``core/gauss_newton.py``,
``core/objective.py``, ``core/semilag.py``) is layout-agnostic — it only
ever sees ``ctx.ops`` and ``ctx.interp``.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.grid import Grid, make_grid
from repro.core.spectral import SpectralOps
from repro.dist.halo import make_checked_interp, make_halo_interp
from repro.dist.pencil_fft import PencilFFT


class DistContext:
    def __init__(
        self,
        grid: Grid,
        mesh,
        *,
        axes=("data", "model"),
        halo: int = 4,
        packed: bool = True,
        chunk=None,
        interp_method: str = "auto",
        halo_check: str = "error",
        plan_dtype=None,
        field_dtype=None,
        autotune: str = "cache",
    ):
        self.grid = grid
        self.mesh = mesh
        self.axes = tuple(axes)
        self.halo = int(halo)
        self.packed = packed
        # fields per pipelined FFT chunk (None = single ride, "auto" =
        # per-shard-footprint heuristic) — see repro.dist.pencil_fft
        self.chunk = chunk
        self.interp_method = interp_method
        self.halo_check = halo_check
        self.plan_dtype = plan_dtype
        # storage dtype of the transform/transport field path (e.g.
        # jnp.bfloat16 halves a2a payloads and SL-stack HBM; critical
        # accumulations stay >= f32 — see GNConfig.field_dtype)
        self.field_dtype = field_dtype
        self.autotune = autotune
        if autotune != "off":
            # fill knobs still at their default sentinels (chunk None,
            # interp_method "auto", plan/field dtype None) from the tuning
            # cache; explicit constructor arguments always win
            from repro import autotune as _at

            tuned = _at.consult_ctx(self)
            self.chunk = tuned.get("chunk", self.chunk)
            self.interp_method = tuned.get("interp_method", self.interp_method)
            self.plan_dtype = tuned.get("plan_dtype", self.plan_dtype)
            self.field_dtype = tuned.get("field_dtype", self.field_dtype)
        self.fft = PencilFFT(
            grid, mesh, axes=self.axes, packed=packed, chunk=self.chunk,
            field_dtype=self.field_dtype,
        )
        self.ops = SpectralOps(grid, backend=self.fft, field_dtype=self.field_dtype)
        # per-shard kernel dispatch (Pallas on TPU / gather oracle) wrapped by
        # the planner's dynamic halo-budget check ("off" disables the check);
        # plan_dtype packs the cached InterpPlan weights (e.g. jnp.bfloat16
        # halves the plan's HBM footprint; the contraction stays f32)
        self.halo_interp = make_halo_interp(
            grid, mesh, axes=self.axes, halo=self.halo, method=self.interp_method,
            plan_dtype=self.plan_dtype,
        )
        self.interp = (
            self.halo_interp
            if halo_check == "off"
            else make_checked_interp(
                self.halo_interp, mesh, self.axes, self.halo, on_overflow=halo_check
            )
        )
        self._coarse_cache: dict = {}

    def coarsen(self, shape) -> "DistContext":
        """Derive the same-mesh context of a coarser grid (repro.multilevel).

        Same pencil axes, halo budget, and interpolation dispatch; the coarse
        grid must still satisfy the mesh divisibility constraints (validated
        by ``PencilFFT``).  Memoized per shape: the multilevel driver and the
        V-cycle preconditioner both walk the ladder, and each context owns a
        ``PencilFFT``/halo-interp pair whose ``shard_map`` closures should be
        built (and traced) once — the cycle re-shards through these cached
        contexts' pencil transforms, never gathering a fine field.
        """
        shape = tuple(int(n) for n in shape)
        if shape not in self._coarse_cache:
            self._coarse_cache[shape] = DistContext(
                make_grid(shape, self.grid.dtype),
                self.mesh,
                axes=self.axes,
                halo=self.halo,
                packed=self.packed,
                chunk=self.chunk,
                interp_method=self.interp_method,
                halo_check=self.halo_check,
                plan_dtype=self.plan_dtype,
                field_dtype=self.field_dtype,
                # the fine context already resolved its knobs; coarse grids
                # inherit them verbatim rather than re-consulting the cache
                # with a coarse-cell key (tuning targets the fine grid)
                autotune="off",
            )
        return self._coarse_cache[shape]

    # -- shardings ---------------------------------------------------------
    def scalar_sharding(self) -> NamedSharding:
        """(N1, N2, N3) real-space pencil layout."""
        a1, a2 = self.axes
        return NamedSharding(self.mesh, P(a1, a2, None))

    def vector_sharding(self) -> NamedSharding:
        """(3, N1, N2, N3): component axis replicated, space pencil-sharded."""
        a1, a2 = self.axes
        return NamedSharding(self.mesh, P(None, a1, a2, None))

    # -- input placement ---------------------------------------------------
    def shard_scalar(self, f: jax.Array) -> jax.Array:
        return jax.device_put(f, self.scalar_sharding())

    def shard_vector(self, v: jax.Array) -> jax.Array:
        return jax.device_put(v, self.vector_sharding())

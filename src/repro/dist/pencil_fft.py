"""2-D pencil-decomposed distributed FFT (paper §III-C1, Fig. 4).

The paper parallelizes its spectral operators with AccFFT's 2-D pencil
decomposition: the ``N1 x N2 x N3`` grid is split over a ``p1 x p2``
process grid, each 1-D transform runs on a locally-complete axis, and two
all-to-all transposes re-pencil the data between axis passes.  This module
is the same algorithm as a JAX SPMD program: ``shard_map`` gives each
device its pencil, ``lax.all_to_all`` performs the transposes, and XLA
overlaps them with the surrounding elementwise work.

Layouts (per device, global shape ``(B, N1, N2, N3)``):

    real space   (B, N1/p1, N2/p2, N3)        P(None, a1, a2, None)
    after pass 1 (B, N1/p1, N2,    N3/p2)     transpose over a2
    after pass 2 (B, N1,    N2/p1, N3/p2)     transpose over a1
    k space      (B, N1,    N2/p1, N3/p2)     P(None, None, a1, a2)

All three passes are complex-to-complex.  A c2c transform (instead of the
single-device ``rfftn``) keeps every transposed axis length divisible by
the pencil sizes for any valid mesh (an r2c last axis of ``N3/2 + 1``
modes is generally not), at the cost of 2x redundant spectrum storage.
The bandwidth is won back with the classic packing trick on BOTH sides:

* ``inv_packed``: two real-destined spectra ``Fa, Fb`` ride one inverse
  transform as ``Fa + i Fb``, since ``ifft`` is linear and ``a, b`` real
  means ``a = Re ifft``, ``b = Im ifft``.
* ``fwd_packed``: two *real* fields ride one forward transform as
  ``a + i b``; Hermitian symmetry of real spectra unpacks them via
  ``Fa = (Z + conj(Z(-k)))/2``, ``Fb = -i (Z - conj(Z(-k)))/2``.  The
  frequency reversal ``Z(-k)`` is a flip+roll of the sharded spectrum,
  which GSPMD lowers to shard-reversing collective-permutes — far cheaper
  than the all-to-all transposes the second transform would have cost.
  An odd trailing field rides the SAME shard_map call as an unpaired c2c
  (mirroring ``inv_packed``), so every packed ride is exactly one
  transform program — one all-to-all pair per direction, never two.

``SpectralOps`` probes for these via the ``packed`` attribute and routes
every batched real(-destined) transform (gradients of time series, Leray,
``div``, coalesced ``SpectralBatch`` rides) through them — halving the
pencil all-to-all bytes on each routed side.

Communication/computation pipelining (the AccFFT overlap trick, also the
multi-GPU CLAIRE optimization, arXiv:2008.12820): ``PencilFFT(chunk=...)``
splits the flattened batch axis *inside* the shard_map body into chunks
and transforms them as independent dataflow chains.  The all-to-all of
chunk ``i`` has no dependence on the local 1-D FFTs of chunk ``i+1``, so
XLA's async collective scheduler double-buffers them — the transpose of
one chunk hides behind the compute of the next.  ``chunk="auto"`` sizes
chunks off the per-shard pencil footprint (pipelining only pays once a
chunk's transpose is bandwidth- rather than latency-bound); chunking is
exact — the chunked program computes bit-identical results to the
unchunked one for every mesh layout, batch size, and chunk remainder.

Mesh axis entries may be tuples (e.g. ``(("pod", "data"), "model")``) so a
multi-pod mesh can fold two device axes into one pencil dimension.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry
from repro.core.grid import Grid
from repro.launch.mesh import mesh_axes_size, validate_mesh_for_grid

# auto-chunk target: per-shard bytes one chunk moves through each
# all-to-all.  Big enough that a chunk's transpose is bandwidth-bound
# (pipelining overlaps it with the next chunk's FFTs), small enough that
# a large batched ride splits into >= 2 overlappable stages.
AUTO_CHUNK_TARGET_BYTES = 8 << 20


def resolve_chunk(chunk, grid_shape, p1: int, p2: int) -> int:
    """Fields-per-chunk for the pipelined transform; 0 disables chunking.

    ``"auto"`` targets ``AUTO_CHUNK_TARGET_BYTES`` of complex64 (8 B/point)
    per-shard pencil data per chunk: at production shards that is chunk=16
    at 256^3 on 256 chips (0.5 MB/field) down to chunk=2 at 512^3
    (4 MB/field — near-maximal overlap); at toy shards the chunk swallows
    any realistic batch and the path degrades gracefully to the unchunked
    single ride.
    """
    if chunk in (None, 0):
        return 0
    if chunk == "auto":
        per_field = 8 * int(np.prod(grid_shape)) // max(p1 * p2, 1)
        return max(1, AUTO_CHUNK_TARGET_BYTES // max(per_field, 1))
    c = int(chunk)
    if c < 1:
        raise ValueError(f"chunk must be >= 1, 'auto', or None; got {chunk!r}")
    return c


def _fwd_one(x, *, a1, a2, p1, p2):
    """Per-device pencil forward: 3 local 1-D c2c passes + 2 transposes."""
    x = jnp.fft.fft(x, axis=-1)
    if p2 > 1:  # gather N2, scatter N3 over the second pencil axis
        x = lax.all_to_all(x, a2, split_axis=3, concat_axis=2, tiled=True)
    x = jnp.fft.fft(x, axis=-2)
    if p1 > 1:  # gather N1, scatter N2 over the first pencil axis
        x = lax.all_to_all(x, a1, split_axis=2, concat_axis=1, tiled=True)
    return jnp.fft.fft(x, axis=-3)


def _inv_one(s, *, a1, a2, p1, p2):
    """Per-device pencil inverse: exact reversal of ``_fwd_one``."""
    s = jnp.fft.ifft(s, axis=-3)
    if p1 > 1:
        s = lax.all_to_all(s, a1, split_axis=1, concat_axis=2, tiled=True)
    s = jnp.fft.ifft(s, axis=-2)
    if p2 > 1:
        s = lax.all_to_all(s, a2, split_axis=2, concat_axis=3, tiled=True)
    return jnp.fft.ifft(s, axis=-1)


def _pipelined(one, x, *, chunk, p1, p2, **kw):
    """Software-pipelined transform: independent per-chunk dataflow chains.

    The unrolled chunk loop IS the pipeline — chunk ``i``'s all-to-all and
    chunk ``i+1``'s local FFTs share no data, so the async collective
    scheduler issues the transpose of one chunk under the compute of the
    next (double buffering falls out of the dependence structure; no
    manual send/recv choreography needed).  The trailing remainder chunk
    is simply smaller — results are identical to the unchunked call.
    """
    b = x.shape[0]
    if not chunk or b <= chunk or (p1 == 1 and p2 == 1):
        return one(x, p1=p1, p2=p2, **kw)
    parts = [one(x[i : i + chunk], p1=p1, p2=p2, **kw) for i in range(0, b, chunk)]
    return jnp.concatenate(parts, axis=0)


class PencilFFT:
    """Drop-in ``FFTBackend`` running the paper's pencil FFT on a mesh.

    Same interface as ``repro.core.spectral.LocalFFT`` (``fwd``/``inv`` and
    the ``k``/``kd``/``ksq``/``ksq_d`` wavenumber grids), so every operator
    in ``SpectralOps`` works unmodified; the wavenumber grids use the full
    (non-rfft) last axis to match the c2c spectrum layout.

    ``chunk``: fields per pipelined chunk inside the shard_map body
    (``None`` = single ride, ``"auto"`` = footprint heuristic, int = fixed).

    ``field_dtype``: storage dtype of the REAL fields the inverse side
    returns (default ``grid.dtype``); e.g. ``jnp.bfloat16`` halves the
    resident footprint of inverse-transformed stacks.  The transform
    itself stays complex64 — forward inputs are upcast, so precision is
    lost only at the real-space store (the ``repro.autotune``
    mixed-precision knob, threaded here by ``DistContext``).
    """

    def __init__(
        self, grid: Grid, mesh, axes=("data", "model"), packed: bool = True, chunk=None,
        field_dtype=None,
    ):
        validate_mesh_for_grid(mesh, grid.shape, axes)
        self.grid = grid
        self.mesh = mesh
        self.axes = tuple(axes)
        self.packed = packed
        self.real_dtype = grid.dtype if field_dtype is None else jnp.dtype(field_dtype)
        a1, a2 = self.axes
        p1, p2 = mesh_axes_size(mesh, a1), mesh_axes_size(mesh, a2)
        self.pencil = (p1, p2)
        self.chunk = resolve_chunk(chunk, grid.shape, p1, p2)

        f32 = np.float32
        k1, k2, k3 = grid.k_grids(rfft_last=False)
        d1, d2, d3 = grid.k_deriv(rfft_last=False)
        self.k = (k1.astype(f32), k2.astype(f32), k3.astype(f32))
        self.kd = (d1.astype(f32), d2.astype(f32), d3.astype(f32))
        self.ksq = (k1**2 + k2**2 + k3**2).astype(f32)
        self.ksq_d = (d1**2 + d2**2 + d3**2).astype(f32)

        spec_r = P(None, a1, a2, None)  # real-space pencils
        spec_k = P(None, None, a1, a2)  # k-space pencils
        kw = dict(a1=a1, a2=a2, p1=p1, p2=p2, chunk=self.chunk)
        self._fwd4 = shard_map(
            partial(_pipelined, _fwd_one, **kw), mesh=mesh,
            in_specs=(spec_r,), out_specs=spec_k, check_rep=False,
        )
        self._inv4 = shard_map(
            partial(_pipelined, _inv_one, **kw), mesh=mesh,
            in_specs=(spec_k,), out_specs=spec_r, check_rep=False,
        )

    # -- batching: leading dims are flattened into one batch axis so a single
    # rank-4 shard_map program serves scalars, vectors, and time series -----
    def _batched(self, fn, u):
        lead = u.shape[:-3]
        out = fn(u.reshape((-1,) + u.shape[-3:]))
        return out.reshape(lead + out.shape[-3:])

    @staticmethod
    def _wide(u: jnp.ndarray) -> jnp.ndarray:
        """Upcast sub-f32 real fields before the complex transform."""
        if u.dtype in (jnp.bfloat16, jnp.float16):
            return u.astype(jnp.float32)
        return u

    def fwd(self, u: jnp.ndarray) -> jnp.ndarray:
        with telemetry.annotate("pencil_fft.fwd"):
            return self._batched(self._fwd4, self._wide(u))

    def inv(self, spec: jnp.ndarray) -> jnp.ndarray:
        with telemetry.annotate("pencil_fft.inv"):
            return self._batched(self._inv4, spec).real.astype(self.real_dtype)

    def constrain_k(self, spec: jnp.ndarray) -> jnp.ndarray:
        """Pin a k-space array to this backend's pencil sharding.

        An explicit hint for jnp-level spectrum surgery between transforms
        (the multilevel zero-pad scatter): without it GSPMD's propagation
        pass may replicate the operand — on the folded multi-pod
        ``(pod, data)`` axis it all-gathered the whole coarse spectrum per
        chip (EXPERIMENTS §Dry-run).  No-op on layouts where propagation
        already keeps the array sharded.
        """
        a1, a2 = self.axes
        names = (None,) * (spec.ndim - 3) + (None, a1, a2)
        return jax.lax.with_sharding_constraint(
            spec, NamedSharding(self.mesh, P(*names))
        )

    def _reverse_k(self, spec: jnp.ndarray) -> jnp.ndarray:
        """``Z(k) -> Z((N - k) mod N)`` per space axis of a k-space array.

        ``(N - k) mod N`` is a full flip followed by a roll of 1.  Applied at
        the jnp level on the sharded spectrum: the flip/roll of the two
        sharded k axes lower to shard-reversing collective-permutes under
        GSPMD (no all-to-all re-pencilling).
        """
        ax = (-3, -2, -1)
        return jnp.roll(jnp.flip(spec, axis=ax), shift=(1, 1, 1), axis=ax)

    def fwd_packed(self, u: jnp.ndarray) -> jnp.ndarray:
        """Forward transform of ``(B, N1, N2, N3)`` REAL fields, two per ride.

        Pairs ``(u_{2i}, u_{2i+1})`` into ``u_{2i} + i u_{2i+1}``, transforms
        ``ceil(B/2)`` complex fields in ONE shard_map ride (an odd trailing
        field joins the same ride unpaired), and unpacks the two Hermitian
        spectra — halving the forward-side transpose traffic (the mirror of
        ``inv_packed``).
        """
        u = self._wide(u)
        b = u.shape[0]
        h = b // 2
        if h == 0:
            return self.fwd(u)
        with telemetry.annotate("pencil_fft.fwd_packed"):
            pairs = u[0 : 2 * h : 2] + 1j * u[1 : 2 * h : 2]  # (h, space)
            if b % 2:
                pairs = jnp.concatenate([pairs, u[2 * h :].astype(pairs.dtype)], axis=0)
            z = self._fwd4(pairs)
            zr = jnp.conj(self._reverse_k(z[:h]))  # conj Z(-k)
            fa = 0.5 * (z[:h] + zr)
            fb = -0.5j * (z[:h] - zr)
            out = jnp.stack([fa, fb], axis=1).reshape((2 * h,) + z.shape[1:])
            if b % 2:
                out = jnp.concatenate([out, z[h:]], axis=0)
            return out

    def inv_packed(self, spec: jnp.ndarray) -> jnp.ndarray:
        """Inverse of ``(B, N1, N2, N3)`` real-destined spectra, two per ride.

        Pairs ``(F_{2i}, F_{2i+1})`` into ``F_{2i} + i F_{2i+1}``, inverts
        ``ceil(B/2)`` spectra, and unpacks real/imag parts — halving the
        inverse-side transpose traffic (EXPERIMENTS §Perf).
        """
        b = spec.shape[0]
        h = b // 2
        if h == 0:
            return self.inv(spec)
        with telemetry.annotate("pencil_fft.inv_packed"):
            pairs = spec[0 : 2 * h : 2] + 1j * spec[1 : 2 * h : 2]
            if b % 2:
                pairs = jnp.concatenate([pairs, spec[2 * h :]], axis=0)
            z = self._inv4(pairs)
            out = jnp.stack([z[:h].real, z[:h].imag], axis=1).reshape(
                (2 * h,) + z.shape[1:]
            )
            if b % 2:
                out = jnp.concatenate([out, z[h:].real], axis=0)
            return out.astype(self.real_dtype)

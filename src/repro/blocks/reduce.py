"""The reduce step: blend per-block fields into one global field.

``blend`` is the partition-of-unity weighted paste: every block scatters
``w_b * f_b`` (and its window ``w_b``) into a float64 global accumulator
and the result is the normalized quotient, cast back to the field dtype.
Normalizing by the *accumulated* window (instead of trusting the windows
to sum to exactly one) makes the reduction a true convex combination per
voxel, so

* a field on which all blocks agree — in particular any CONSTANT field —
  survives partition -> reduce bit-exactly (f64 accumulation keeps the
  quotient within one float32 ulp of the common value; pinned by
  ``tests/test_blocks.py``), and
* wrap-around overlap on two-block axes needs no special casing.

``seam_report`` is the boundary-consistency diagnostic — the same
disagreement-across-owners question the halo-exchange parity checks of
``repro.dist.halo`` ask per ghost cell, asked per overlap voxel: where
two or more blocks claim a voxel, how far apart are their claims?  Large
seams mean the overlap is thinner than the residual per-block motion (or
a block solve went off the rails) and the blended field will kink there.

``spectral_smooth`` optionally post-smooths the blended field at the
global grid bandwidth (one forward/inverse ride) — CLAIRE-style seam
mollification for downstream consumers that differentiate the field.
"""
from __future__ import annotations

import numpy as np

from repro.blocks.partition import BlockPartition


def _scatter_ix(block):
    i1, i2, i3 = (block.ext_indices(a) for a in range(3))
    return i1[:, None, None], i2[None, :, None], i3[None, None, :]


def blend(fields, part: BlockPartition, dtype=None) -> np.ndarray:
    """Partition-of-unity reduction of per-block fields (``part.blocks``
    order; trailing shape = each block's extended shape, leading axes — a
    velocity's component axis — pass through)."""
    fields = [np.asarray(f) for f in fields]
    if len(fields) != len(part.blocks):
        raise ValueError(f"{len(fields)} fields for {len(part.blocks)} blocks")
    lead = fields[0].shape[:-3]
    dtype = dtype or fields[0].dtype
    num = np.zeros(lead + part.grid_shape, np.float64)
    den = np.zeros(part.grid_shape, np.float64)
    for b, f in zip(part.blocks, fields):
        if f.shape[-3:] != b.ext_shape:
            raise ValueError(
                f"block {b.index}: trailing shape {f.shape[-3:]} != extended "
                f"shape {b.ext_shape}"
            )
        w = part.weights(b)
        ix = _scatter_ix(b)
        num[(Ellipsis,) + ix] += f.astype(np.float64) * w
        den[ix] += w
    return (num / den).astype(dtype)


def seam_report(fields, part: BlockPartition) -> dict:
    """Disagreement between overlapping blocks on their shared voxels.

    Accumulates per-voxel first/second moments of the block claims and
    reports the spread where two or more blocks overlap:

    * ``seam_max`` / ``seam_rms`` — max / rms across-block standard
      deviation over overlap voxels (physical field units);
    * ``seam_rel`` — ``seam_rms`` relative to the blended field's rms
      (the number to alarm on);
    * ``overlap_fraction`` — fraction of voxels claimed more than once.
    """
    fields = [np.asarray(f, np.float64) for f in fields]
    lead = fields[0].shape[:-3]
    m1 = np.zeros(lead + part.grid_shape, np.float64)
    m2 = np.zeros(lead + part.grid_shape, np.float64)
    cnt = np.zeros(part.grid_shape, np.float64)
    for b, f in zip(part.blocks, fields):
        ix = _scatter_ix(b)
        m1[(Ellipsis,) + ix] += f
        m2[(Ellipsis,) + ix] += f * f
        cnt[ix] += 1.0
    shared = cnt >= 2.0
    if not shared.any():  # no overlap anywhere (single block / overlap 0)
        return {"seam_max": 0.0, "seam_rms": 0.0, "seam_rel": 0.0,
                "overlap_fraction": 0.0}
    mean = m1 / cnt
    var = np.maximum(m2 / cnt - mean**2, 0.0)
    sd = np.sqrt(var[..., shared])  # (lead..., n_shared)
    field_rms = float(np.sqrt(np.mean(mean**2)))
    seam_rms = float(np.sqrt(np.mean(sd**2)))
    return {
        "seam_max": float(sd.max()),
        "seam_rms": seam_rms,
        "seam_rel": seam_rms / max(field_rms, 1e-30),
        "overlap_fraction": float(shared.mean()),
    }


def spectral_smooth(v, ops):
    """Gaussian smooth of the blended field at the global grid bandwidth
    (``SpectralOps.smooth`` rides leading axes through its transform pair)."""
    return ops.smooth(v)

"""Out-of-core blockwise registration: map-reduce over overlapping blocks.

``partition`` tiles the global grid into overlapping blocks, ``driver``
registers every block through a cohort server after a coarse global warm
start, ``reduce`` blends the per-block fields with partition-of-unity
windows.  Entry point: ``blocks.solve`` (or ``RegistrationConfig(blocks=)``).
"""
from repro.blocks.driver import BlocksConfig, solve
from repro.blocks.partition import Block, BlockPartition
from repro.blocks.reduce import blend, seam_report, spectral_smooth

__all__ = [
    "Block",
    "BlockPartition",
    "BlocksConfig",
    "blend",
    "seam_report",
    "solve",
    "spectral_smooth",
]

"""Out-of-core blockwise registration: coarse warm start -> cohort-served
blocks -> partition-of-unity reduce.

``blocks.solve`` is the map-reduce driver over a ``BlockPartition``:

1. **Coarse global solve (the warm start).**  The pair is restricted to an
   in-memory ``coarse_shape`` (CLAIRE-style pre-smoothed restriction) and
   registered there — through the multilevel ladder when ``coarse=``
   carries a ``MultilevelConfig`` — then the coarse velocity is prolonged
   to the fine grid.  Every block starts from the globally-consistent bulk
   motion; the block solves only polish local residual deformation, which
   is what keeps per-block motion smaller than the overlap.
2. **Blocks as traffic.**  Extended blocks are extracted host-side
   (the out-of-core read path), their warm starts rescaled into block
   units (``Block.velocity_scale``), and every block becomes a ``RegJob``
   streamed through a ``launch.reg_serve.CohortServer`` — all same-shaped
   blocks share ONE compiled masked-cohort executable, retire
   independently, and are billed per block via ``JobEvent`` (the tile
   index rides the record's ``block`` field).  Each job carries its
   cold-start gradient norm as ``g0_ref`` so warm-started blocks terminate
   at the same absolute tolerance a cold solve would (the multilevel
   ladder's convergence semantics, per tile).
3. **Reduce.**  Per-block velocities are rescaled back to global units and
   blended with the partition-of-unity windows (``reduce.blend``), with a
   seam-consistency report over the overlaps and an optional global
   spectral smooth.

The returned velocity lives on the full fine grid; ``register()`` routes
here via ``RegistrationConfig(blocks=...)`` and runs its usual deformation
/diagnostics pass on the stitched field.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.blocks import reduce as blk_reduce
from repro.blocks.partition import BlockPartition
from repro.core import gauss_newton as gn
from repro.core import objective as obj
from repro.core.grid import Grid, make_grid
from repro.core.spectral import SpectralOps
from repro.launch.reg_serve import RegJob, serve_jobs
from repro.multilevel import transfer
from repro.multilevel.hierarchy import MultilevelConfig
from repro.resilience.policy import RetryPolicy


@dataclasses.dataclass(frozen=True)
class BlocksConfig:
    """Blockwise registration settings (wraps the per-block ``GNConfig``)."""

    solver: gn.GNConfig = dataclasses.field(default_factory=gn.GNConfig)
    block_shape: int | tuple = 32  # target core width per axis
    overlap: int | tuple = 8  # one-sided halo (clamped: see BlockPartition)
    # global warm-start resolution; None = half the fine grid (min 8/axis).
    coarse_shape: int | tuple | None = None
    # ladder for the coarse global solve; None = single-level cfg.solver.
    coarse: MultilevelConfig | None = None
    slots: int = 4  # cohort width per server bucket
    presmooth: bool = True  # spectral Gaussian on the GLOBAL pair first
    smooth_reduce: bool = False  # global spectral smooth after blending
    seam_check: bool = True  # emit the overlap-consistency report
    # resilience: failed tiles (nonfinite/diverged/... JobResult.status)
    # are re-served through the serve layer's degradation ladder before
    # the blend — None keeps the historical fail-fast behavior
    retry: RetryPolicy | None = None

    def __post_init__(self):
        if self.solver.beta_continuation:
            raise ValueError(
                "BlocksConfig.solver must not use beta_continuation (blocks "
                "are cohort-served; put the continuation schedule on the "
                "coarse warm-start solve via coarse=MultilevelConfig(...))"
            )


def _resolve_coarse_shape(cfg: BlocksConfig, grid: Grid) -> tuple[int, int, int]:
    if cfg.coarse_shape is not None:
        cs = cfg.coarse_shape
        cs = (cs, cs, cs) if isinstance(cs, int) else tuple(cs)
        return tuple(int(c) for c in cs)
    return tuple(min(n, max(8, n // 2)) for n in grid.shape)


def _make_cold_g0(block_grid: Grid, cfg: gn.GNConfig):
    """One jitted cold-start gradient norm, shared by every block of a
    bucket (|g(v=0)| is the per-tile convergence reference — the
    ``_cold_gradient_norm`` of the multilevel driver, at block shape)."""
    bops = SpectralOps(block_grid)

    def cold(rho_R, rho_T):
        prob = obj.Problem(
            grid=block_grid, rho_R=rho_R, rho_T=rho_T, beta=cfg.beta,
            n_t=cfg.n_t, incompressible=cfg.incompressible,
        )
        state = obj.newton_state(
            jnp.zeros((3,) + block_grid.shape, block_grid.dtype), prob, bops
        )
        return jnp.sqrt(block_grid.norm_sq(state.g))

    return cold


def solve(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    grid: Grid | None = None,
    cfg: BlocksConfig | None = None,
    *,
    ops: SpectralOps | None = None,
    verbose: bool = False,
):
    """Blockwise registration of one pair; returns a ``gn.solve``-shaped
    dict plus ``partition`` / ``per_block`` / ``seam`` / ``coarse`` stats."""
    cfg = cfg or BlocksConfig()
    grid = grid or make_grid(rho_R.shape)
    ops = ops or SpectralOps(grid)
    t_start = time.time()

    if cfg.presmooth:
        rho_R, rho_T = ops.smooth(rho_R), ops.smooth(rho_T)

    # ---- 1. coarse in-memory global solve -> prolonged warm start ---------
    coarse_shape = _resolve_coarse_shape(cfg, grid)
    with telemetry.span("blocks.coarse", shape=list(coarse_shape)):
        if coarse_shape == grid.shape:
            cgrid, cops = grid, ops
            rho_R_c, rho_T_c = rho_R, rho_T
        else:
            cgrid = make_grid(coarse_shape, grid.dtype)
            cops = SpectralOps(cgrid)
            rho_R_c = transfer.smooth_restrict(rho_R, ops, cops)
            rho_T_c = transfer.smooth_restrict(rho_T, ops, cops)
        if cfg.coarse is not None:
            from repro import multilevel

            cout = multilevel.solve(
                rho_R_c, rho_T_c, cgrid, cfg.coarse, ops=cops, verbose=verbose
            )
        else:
            cout = gn.solve(rho_R_c, rho_T_c, cgrid, cfg.solver, ops=cops,
                            verbose=verbose)
        v_global = (
            cout["v"] if cgrid is grid else transfer.prolong(cout["v"], cops, ops)
        )
    coarse_weight = cgrid.num_points / grid.num_points
    coarse_fe = (
        float(cout.get("total_fine_equiv_matvecs", cout["hessian_matvecs"]))
        * coarse_weight
    )

    # ---- 2. partition; serve every bucket of same-shaped blocks ------------
    part = BlockPartition(grid.shape, cfg.block_shape, cfg.overlap)
    rho_R_h, rho_T_h = np.asarray(rho_R), np.asarray(rho_T)
    v_h = np.asarray(v_global)

    buckets: dict[tuple, list] = {}
    for b in part.blocks:
        buckets.setdefault(b.ext_shape, []).append(b)

    results_by_index: dict[tuple, tuple] = {}  # index -> (JobResult, scale)
    bucket_stats: dict[str, dict] = {}
    cohort_iterations = 0
    compiled_executables = 0
    for ext_shape, blist in buckets.items():
        bgrid = make_grid(ext_shape, grid.dtype)
        bweight = bgrid.num_points / grid.num_points
        bucket_slots = max(1, min(cfg.slots, len(blist)))
        cold_g0 = jax.jit(_make_cold_g0(bgrid, cfg.solver))
        jobs, scales = [], {}
        with telemetry.span("blocks.extract", bucket=list(ext_shape)):
            for b in blist:
                rR_b = jnp.asarray(part.extract(rho_R_h, b))
                rT_b = jnp.asarray(part.extract(rho_T_h, b))
                scale = b.velocity_scale()
                v0_b = jnp.asarray(
                    part.extract(v_h, b) * scale, dtype=grid.dtype
                )
                scales[b.index] = scale
                jobs.append(
                    RegJob(
                        job_id=f"block{b.index}",
                        rho_R=rR_b,
                        rho_T=rT_b,
                        v0=v0_b,
                        g0_ref=float(cold_g0(rR_b, rT_b)),
                        block=b.index,
                    )
                )
        # the serve layer owns the drain loop — and, with cfg.retry, the
        # re-serving of failed tiles through the degradation ladder, so a
        # NaN-poisoned tile is retried instead of blended into the field
        with telemetry.span("blocks.serve", bucket=list(ext_shape),
                            n_blocks=len(blist)):
            out_b = serve_jobs(
                jobs, cfg.solver, slots=bucket_slots, verbose=verbose,
                retry=cfg.retry, grid_dtype=grid.dtype,
            )
        by_id = {r.job_id: r for r in out_b["results"]}
        for b in blist:
            results_by_index[b.index] = (by_id[f"block{b.index}"], scales[b.index])
        bucket_iters = sum(
            st["cohort_iterations"] for st in out_b["buckets"].values()
        )
        cohort_iterations += bucket_iters
        compiled_executables += out_b["compiled_executables"]
        bucket_stats["x".join(map(str, ext_shape))] = {
            "blocks": len(blist),
            "slots": bucket_slots,
            "cohort_iterations": bucket_iters,
            "compiled_executables": out_b["compiled_executables"],
            "fine_equiv_weight": bweight,
            "retries": sum(
                st["jobs"]
                for key, st in out_b["buckets"].items()
                if st["attempt"] > 1
            ),
        }

    per_block = []
    fields = []
    block_matvecs = 0
    block_newton = 0
    block_fe = 0.0
    for b in part.blocks:
        res, scale = results_by_index[b.index]
        fields.append(np.asarray(res.v) / scale)  # back to global units
        bweight = float(np.prod(b.ext_shape)) / grid.num_points
        fe = res.hessian_matvecs * bweight
        block_matvecs += res.hessian_matvecs
        block_newton += res.newton_iters
        block_fe += fe
        per_block.append(
            {
                "block": list(b.index),
                "job_id": res.job_id,
                "newton_iters": int(res.newton_iters),
                "hessian_matvecs": int(res.hessian_matvecs),
                "fine_equiv_matvecs": float(fe),
                "rel_gnorm": float(res.rel_gnorm),
                "converged": bool(res.converged),
                "status": res.status,
                "attempts": int(res.attempts),
            }
        )

    # ---- 3. reduce: partition-of-unity blend (+ seam report, smooth) -------
    with telemetry.span("blocks.reduce", n_blocks=len(part)):
        v_np = blk_reduce.blend(fields, part, dtype=np.dtype(grid.dtype))
        seam = blk_reduce.seam_report(fields, part) if cfg.seam_check else None
        v = jnp.asarray(v_np)
        if cfg.smooth_reduce:
            v = ops.smooth(v)
    if seam is not None:
        telemetry.counter("blocks.seam_rel", seam["seam_rel"])

    wall = time.time() - t_start
    telemetry.emit(
        telemetry.SolveEvent(
            source="blocks.solve",
            newton_iters=cout["newton_iters"] + block_newton,
            hessian_matvecs=cout["hessian_matvecs"] + block_matvecs,
            fine_equiv_matvecs=coarse_fe + block_fe,
            compiled_executables=compiled_executables,
            wall_s=wall,
        )
    )
    return {
        "v": v,
        "history": cout["history"],
        "newton_iters": cout["newton_iters"] + block_newton,
        "hessian_matvecs": cout["hessian_matvecs"] + block_matvecs,
        "fine_equiv_matvecs": coarse_fe + block_fe,
        "coarse": {
            "shape": list(coarse_shape),
            "newton_iters": cout["newton_iters"],
            "hessian_matvecs": cout["hessian_matvecs"],
            "fine_equiv_matvecs": coarse_fe,
        },
        "partition": {
            "grid": list(grid.shape),
            "counts": list(part.counts),
            "overlap": list(part.overlap),
            "n_blocks": len(part),
            "ext_shapes": [list(s) for s in part.ext_shapes],
            "halo_overhead": part.halo_overhead,
        },
        "per_block": per_block,
        "buckets": bucket_stats,
        "block_matvecs": block_matvecs,
        "cohort_iterations": cohort_iterations,
        "compiled_executables": compiled_executables,
        "all_converged": all(p["converged"] for p in per_block),
        "seam": seam,
        "wall_s": wall,
        "grid": grid,
    }

"""Overlapping block decomposition of a periodic ``Grid`` (the map step).

Terabyte-scale volumes exceed what one solve can hold (ROADMAP item 2;
itk-dreg's map-reduce framing): subdivide the global grid into a Cartesian
tiling of *core* regions that partition the volume exactly, grow each core
by a one-sided ``overlap`` halo into an *extended* block, register every
extended block independently, and blend the per-block fields back with
partition-of-unity weight windows (``repro.blocks.reduce``).

Geometry contract (all in global voxel coordinates, periodic wrap):

* cores tile ``[0, N)`` per axis exactly — a plain paste of core interiors
  reconstructs any volume bit-for-bit (property-pinned in
  ``tests/test_property.py``);
* the extended block is ``core ± overlap``; the overlap zone between two
  axis-neighbours is ``2*overlap`` wide and is shared by EXACTLY those two
  blocks (enforced by clamping ``overlap <= min_core // 2``), so the 1-D
  ascending/descending ramp pair sums to one and the separable 3-D windows
  are a partition of unity everywhere (pinned in ``tests/test_blocks.py``);
* an axis tiled by a single block carries no overlap (a block must not
  blend with its own wrap-around image).

Weight windows are float64 on the host: blending runs out-of-band of the
accelerator (the whole point is that the global volume never materializes
on-device), and the f64 accumulation is what makes a constant field
survive partition -> reduce bit-exactly after the cast back.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _as_shape3(x, name: str) -> tuple[int, int, int]:
    if isinstance(x, (int, np.integer)):
        x = (x, x, x)
    out = tuple(int(v) for v in x)
    if len(out) != 3:
        raise ValueError(f"{name} must be an int or a 3-tuple, got {x!r}")
    return out


@dataclasses.dataclass(frozen=True)
class Block:
    """One tile: core region + the applied one-sided halo, in global coords."""

    index: tuple[int, int, int]  # position in the (B1, B2, B3) tiling
    core_start: tuple[int, int, int]
    core_shape: tuple[int, int, int]
    halo: tuple[int, int, int]  # one-sided overlap actually applied per axis
    grid_shape: tuple[int, int, int]

    @property
    def ext_start(self) -> tuple[int, int, int]:
        return tuple(s - h for s, h in zip(self.core_start, self.halo))

    @property
    def ext_shape(self) -> tuple[int, int, int]:
        return tuple(c + 2 * h for c, h in zip(self.core_shape, self.halo))

    def ext_indices(self, axis: int) -> np.ndarray:
        """Global voxel indices of the extended block along ``axis`` (wrapped)."""
        n = self.grid_shape[axis]
        start = self.core_start[axis] - self.halo[axis]
        return (np.arange(self.ext_shape[axis]) + start) % n

    def core_slice(self, axis: int) -> slice:
        """Core region along ``axis`` — contiguous, never wraps."""
        s = self.core_start[axis]
        return slice(s, s + self.core_shape[axis])

    def interior_slice(self, axis: int) -> slice:
        """The core region in the extended block's LOCAL coordinates."""
        h = self.halo[axis]
        return slice(h, h + self.core_shape[axis])

    def velocity_scale(self) -> np.ndarray:
        """Per-component factor mapping a global velocity into block units.

        Both grids span the same [0, 2pi) torus per axis, but the block's
        ``ext_shape[a]`` samples cover only ``ext_shape[a]`` global cells:
        one block coordinate unit is ``grid_shape[a] / ext_shape[a]`` global
        units, so a physical velocity component transfers as
        ``v_block[a] = v_global[a] * N_a / E_a`` (the same displacement in
        voxels — exactly the rescaling ``multilevel.precond.restrict_state``
        applies to SL departure fields).  Shape (3, 1, 1, 1) for broadcast.
        """
        f = [n / e for n, e in zip(self.grid_shape, self.ext_shape)]
        return np.asarray(f, np.float32).reshape(3, 1, 1, 1)


def _axis_cores(n: int, bs: int) -> list[int]:
    """Near-equal core widths tiling ``n`` with blocks of target width ``bs``."""
    b = max(1, -(-n // bs))  # ceil
    base, extra = divmod(n, b)
    return [base + (1 if i < extra else 0) for i in range(b)]


def _ramp(width: int) -> np.ndarray:
    """Ascending half-open linear ramp over an overlap zone of ``width``
    samples; the neighbour's descending ramp is ``1 - _ramp`` at the same
    global positions, so every zone sums to one by construction."""
    return (np.arange(width, dtype=np.float64) + 0.5) / width


class BlockPartition:
    """The overlapping Cartesian tiling of a ``(N1, N2, N3)`` periodic grid.

    ``block_shape`` is the target core width per axis (the last block of an
    axis absorbs the remainder, cores stay within one voxel of each other);
    ``overlap`` is the requested one-sided halo, clamped per axis to half
    the smallest core (partition-of-unity requirement) and to zero on
    single-block axes.
    """

    def __init__(self, grid_shape, block_shape, overlap):
        self.grid_shape = _as_shape3(grid_shape, "grid_shape")
        block_shape = _as_shape3(block_shape, "block_shape")
        overlap = _as_shape3(overlap, "overlap")
        if any(o < 0 for o in overlap):
            raise ValueError(f"overlap must be non-negative, got {overlap}")

        axis_cores = [
            _axis_cores(n, bs) for n, bs in zip(self.grid_shape, block_shape)
        ]
        self.counts = tuple(len(c) for c in axis_cores)
        self.overlap = tuple(
            0 if len(cores) == 1 else min(o, min(cores) // 2)
            for o, cores in zip(overlap, axis_cores)
        )
        starts = [np.concatenate([[0], np.cumsum(c)[:-1]]) for c in axis_cores]

        self.blocks: list[Block] = []
        for i1 in range(self.counts[0]):
            for i2 in range(self.counts[1]):
                for i3 in range(self.counts[2]):
                    idx = (i1, i2, i3)
                    self.blocks.append(
                        Block(
                            index=idx,
                            core_start=tuple(
                                int(starts[a][idx[a]]) for a in range(3)
                            ),
                            core_shape=tuple(
                                int(axis_cores[a][idx[a]]) for a in range(3)
                            ),
                            halo=self.overlap,
                            grid_shape=self.grid_shape,
                        )
                    )

    def __len__(self) -> int:
        return len(self.blocks)

    @property
    def ext_shapes(self) -> tuple[tuple[int, int, int], ...]:
        """Distinct extended-block shapes (one entry == one server bucket ==
        one compiled executable for the whole partition)."""
        return tuple(sorted({b.ext_shape for b in self.blocks}))

    @property
    def halo_overhead(self) -> float:
        """Redundant voxels the overlap re-registers: sum(E^3)/N^3 - 1."""
        total = sum(int(np.prod(b.ext_shape)) for b in self.blocks)
        return total / float(np.prod(self.grid_shape)) - 1.0

    # ---- extraction / paste -------------------------------------------------
    def extract(self, f, block: Block, halo: bool = True) -> np.ndarray:
        """Periodic gather of ``block`` from ``f (..., N1, N2, N3)``.

        ``halo=True`` returns the extended block, ``halo=False`` the bare
        core.  Host-side numpy: this is the out-of-core read path (a real
        deployment replaces the in-memory gather with a chunked file read).
        """
        f = np.asarray(f)
        if halo:
            i1, i2, i3 = (block.ext_indices(a) for a in range(3))
            return f[..., i1[:, None, None], i2[None, :, None], i3[None, None, :]]
        return f[..., block.core_slice(0), block.core_slice(1), block.core_slice(2)]

    def weights(self, block: Block) -> np.ndarray:
        """Separable partition-of-unity window over the extended block (f64).

        Flat 1 on the deep interior, linear cross-fade over each 2*overlap
        zone; the per-axis windows of all blocks sum to one at every global
        voxel, so the 3-D products do too (separability).
        """
        axes = []
        for a in range(3):
            e, h = block.ext_shape[a], block.halo[a]
            w = np.ones(e, np.float64)
            if h > 0:
                ramp = _ramp(2 * h)
                w[: 2 * h] = ramp
                w[e - 2 * h :] = 1.0 - ramp
            axes.append(w)
        return axes[0][:, None, None] * axes[1][None, :, None] * axes[2][None, None, :]

    def weight_sum(self) -> np.ndarray:
        """All windows pasted into the global frame — the partition-of-unity
        diagnostic (== 1 everywhere up to f64 rounding)."""
        out = np.zeros(self.grid_shape, np.float64)
        for b in self.blocks:
            i1, i2, i3 = (b.ext_indices(a) for a in range(3))
            out[i1[:, None, None], i2[None, :, None], i3[None, None, :]] += self.weights(b)
        return out

    def paste_interiors(self, fields) -> np.ndarray:
        """Unweighted paste of every block's core — exact reconstruction.

        ``fields`` are per-block arrays in ``self.blocks`` order, either
        extended (halo cropped here) or bare cores; leading axes pass
        through.  Cores tile the volume disjointly, so this inverts
        ``extract`` bit-for-bit — the partition round-trip property.
        """
        fields = [np.asarray(f) for f in fields]
        lead = fields[0].shape[:-3]
        out = np.zeros(lead + self.grid_shape, fields[0].dtype)
        for b, f in zip(self.blocks, fields):
            if f.shape[-3:] == b.ext_shape and b.ext_shape != b.core_shape:
                f = f[..., b.interior_slice(0), b.interior_slice(1), b.interior_slice(2)]
            elif f.shape[-3:] != b.core_shape:
                raise ValueError(
                    f"block {b.index}: field trailing shape {f.shape[-3:]} is "
                    f"neither core {b.core_shape} nor extended {b.ext_shape}"
                )
            out[..., b.core_slice(0), b.core_slice(1), b.core_slice(2)] = f
        return out

"""Roofline-term extraction from compiled dry-run artifacts (EXPERIMENTS §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s
per ICI link.  The three terms, all in seconds *per step per chip*:

    compute    = HLO_flops / 197e12          (cost_analysis, per-device module)
    memory     = HLO_bytes / 819e9           (cost_analysis "bytes accessed")
    collective = collective_bytes / 50e9     (parsed from post-SPMD HLO)

``collective_bytes`` sums the *output* shapes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the per-device optimized module (all-reduce counted twice: a
bandwidth-optimal ring moves ~2 bytes per reduced byte).  cost_analysis is
not collective-aware — this parser is the required supplement.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes / s / chip
ICI_BW = 50e9  # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# one HLO result shape, e.g. f32[256,4096]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from optimized HLO text."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            # op name appears right after the result shape(s)
            if re.search(rf"\)?\s{k}(?:-start|-done)?\(", rhs) or rhs.startswith(k):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # bytes counted at the -start op
        # result shapes: either "f32[..]" or a tuple "(f32[..], f32[..])"
        paren = rhs.find(f" {kind}")
        head = rhs[: paren if paren > 0 else len(rhs)]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        if kind == "all-reduce":
            nbytes *= 2  # ring all-reduce moves ~2x the payload
        out[kind]["bytes"] += nbytes
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0  # 6 N D (dense) / 6 N_active D (MoE) per step
    hbm_bytes_model: float = 0.0  # analytic TPU-expected traffic (see below)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        """XLA-CPU 'bytes accessed' term — fusion-less upper bound."""
        return self.hbm_bytes / HBM_BW

    @property
    def t_memory_model(self) -> float:
        """Analytic TPU-expected memory term (used for bottleneck calls)."""
        return (self.hbm_bytes_model or self.hbm_bytes) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory_model,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory_model, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO_flops): compiled-compute usefulness."""
        if not self.model_flops:
            return 0.0
        return self.model_flops / max(self.chips * self.flops, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Achievable MFU upper bound: useful flops / (chips*peak*t_bound)."""
        if not self.model_flops:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.t_bound)

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "hbm_bytes_model_per_chip": self.hbm_bytes_model,
            "collective_bytes_per_chip": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_xla_s": self.t_memory,
            "t_memory_s": self.t_memory_model,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "chips": self.chips,
        }


def analyze_compiled(compiled, chips: int, model_flops: float = 0.0) -> tuple[Roofline, dict]:
    """Extract roofline terms from a jax compiled artifact."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(compiled.as_text())
    rl = Roofline(
        flops=flops,
        hbm_bytes=nbytes,
        collective_bytes=float(coll["total_bytes"]),
        chips=chips,
        model_flops=model_flops,
    )
    return rl, coll


def analytic_memory_bytes(cfg, shape: dict, chips: int) -> float:
    """TPU-expected HBM traffic per chip per step (napkin model).

    The XLA-CPU "bytes accessed" metric counts unfused intermediates that a
    TPU compile would keep in registers/VMEM, so it over-estimates HBM
    traffic by ~5-15x.  This model counts only traffic that *must* hit HBM:

      train:   params (bf16 fwd read + bwd read) + grads (f32 w) +
               adam m/v (f32 r+w each) + param write  ~ 26 B/param
               + remat residual stream (store+reload) + logits r/w
      prefill: params read + residuals + logits
      decode:  *active* params read once per token + full cache read +
               one slot write  (the classic decode memory bound)
    """
    b, s, kind = shape["batch"], shape["seq"], shape["kind"]
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    d = cfg.d_model
    layers = cfg.n_layers + (cfg.enc_layers or 0)
    vp = cfg.vocab_padded

    if kind == "train":
        param_traffic = p_total * 26.0
        resid = layers * b * s * d * 2 * 2.0  # store+reload, bf16
        logits = 2 * b * s * vp * 2.0
        return (param_traffic + resid + logits) / chips
    if kind == "prefill":
        param_traffic = p_total * 2.0
        resid = layers * b * s * d * 2.0
        logits = b * s * vp * 2.0
        return (param_traffic + resid + logits) / chips
    # decode: one token
    if cfg.n_experts:  # only routed experts' weights stream in
        frac = min(1.0, b * cfg.top_k / cfg.n_experts)
        expert_all = cfg.n_groups * cfg.n_experts * 3 * d * cfg.d_ff
        p_read = (p_total - expert_all) + frac * expert_all
    else:
        p_read = p_total
    cache = 0.0
    if cfg.ssm_state:  # SSM state r+w
        n_mamba = sum(1 for k in cfg.layer_pattern if k == "mamba") * cfg.n_groups
        cache += 2 * n_mamba * b * cfg.d_inner * cfg.ssm_state / cfg.ssm_head_dim * 4.0 * cfg.ssm_head_dim
    n_attn = sum(1 for k in cfg.layer_pattern if k != "mamba") * cfg.n_groups
    if cfg.enc_layers:
        n_attn = cfg.n_layers * 2  # self + cross
    if n_attn and cfg.n_kv:
        window = cfg.sliding_window
        per_layer_len = []
        for k in cfg.layer_pattern * cfg.n_groups:
            if k == "mamba":
                continue
            per_layer_len.append(min(window, s) if (k == "local" and window) else s)
        if cfg.enc_layers:
            per_layer_len = [s] * (2 * cfg.n_layers)
        cache += sum(per_layer_len) * b * 2 * cfg.n_kv * cfg.head_dim * 2.0
    return (p_read * 2.0 + cache) / chips


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    return {k: getattr(ma, k, None) for k in keys if getattr(ma, k, None) is not None}

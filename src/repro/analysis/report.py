"""Render EXPERIMENTS.md tables from dry-run sweep JSONs.

    PYTHONPATH=src python -m repro.analysis.report results/*.json > tables.md
"""
from __future__ import annotations

import json
import sys


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(paths):
    records = []
    for p in paths:
        with open(p) as f:
            records.extend(json.load(f))
    # dedupe on (arch, shape, mesh), keeping the LAST occurrence
    seen = {}
    for r in records:
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(seen.values())


def dryrun_table(records) -> str:
    out = [
        "| arch | shape | mesh | status | compile(s) | HBM args/chip | HBM temp/chip | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (str(r.get("arch")), str(r.get("shape")), str(r.get("mesh")))):
        mem = r.get("memory", {})
        colls = r.get("collectives", {})
        cstr = " ".join(f"{k.split('-')[1] if '-' in k else k}:{v['count']}" for k, v in colls.items()) or "-"
        out.append(
            f"| {r.get('arch')} | {r.get('shape')} | {r.get('mesh')} | {r.get('status')} "
            f"| {r.get('t_compile_s', '-')} | {_fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {_fmt_bytes(mem.get('temp_size_in_bytes'))} | {cstr} |"
        )
    return "\n".join(out)


def roofline_table(records) -> str:
    out = [
        "| arch | shape | t_compute | t_memory* | t_collective | bottleneck | useful-flops | MFU-bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (str(r.get("arch")), str(r.get("shape")))):
        if r.get("mesh") != "16x16" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.4f}s | {rf['t_memory_s']:.4f}s "
            f"| {rf['t_collective_s']:.4f}s | **{rf['bottleneck']}** "
            f"| {rf['useful_flops_ratio']:.2f} | {rf['mfu_bound']:.3f} |"
        )
    return "\n".join(out)


def registration_table(records) -> str:
    out = [
        "| grid | component | t_compute | t_memory | t_collective | collective split |",
        "|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: str(r.get("arch"))):
        if "components" not in r:
            continue
        for comp, c in r["components"].items():
            colls = c.get("collectives", {})
            cstr = " ".join(
                f"{k}:{_fmt_bytes(v['bytes'])}" for k, v in colls.items() if v.get("bytes")
            )
            out.append(
                f"| {r['arch']} ({r['shape']}) | {comp} | {c['t_compute_s']:.5f}s "
                f"| {c['t_memory_s']:.5f}s | {c['t_collective_s']:.5f}s | {cstr or '-'} |"
            )
    return "\n".join(out)


def main():
    records = load(sys.argv[1:])
    print("## Dry-run matrix\n")
    print(dryrun_table(records))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(records))
    print("\n## Registration components (single-pod)\n")
    print(registration_table(records))


if __name__ == "__main__":
    main()

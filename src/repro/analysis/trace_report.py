"""Render a telemetry JSONL trace as per-phase time / matvec / collective
tables (the paper's Table V shape, from a live run instead of a sweep).

    PYTHONPATH=src python -m repro.analysis.trace_report results/run.jsonl
    PYTHONPATH=src python -m repro.analysis.trace_report run.jsonl --validate
    PYTHONPATH=src python -m repro.analysis.trace_report run.jsonl --json

Reads the schema-versioned event stream written by
``telemetry.jsonl_sink`` (Newton iterations from ``gn.solve`` /
``solve_cohort``, levels from ``multilevel.solve``, jobs/steps from
``launch.reg_serve``, spans, counters, collectives) and renders:

* **phases** — Newton work grouped by (level, beta): iterations, CG
  matvecs, Armijo trials, wall seconds;
* **levels** — the ladder summary with fine-equivalent matvec billing;
* **spans** — wall-clock per span path (count / total / mean);
* **jobs** — per-job billing from the cohort server (matvecs, queue wait,
  slot occupancy) plus the serve-step occupancy aggregate;
* **collectives** — per-kind counted collectives of each labelled program;
* **counters** — final totals (e.g. ``halo_budget_exceeded``).

``--validate`` exits non-zero when any record fails the schema contract
(``telemetry.validate_record``) — the CI tripwire of ``scripts/ci.sh``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.telemetry.events import validate_record


def load(path: str) -> list[dict]:
    """Parse one JSON record per non-blank line."""
    recs = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not valid JSON ({e})") from None
    return recs


def _by_kind(recs: list[dict]) -> dict[str, list[dict]]:
    out: dict[str, list[dict]] = {}
    for r in recs:
        out.setdefault(r.get("kind", "?"), []).append(r)
    return out


def summarize(recs: list[dict]) -> dict:
    """Aggregate a record stream into the report's table-shaped dict."""
    k = _by_kind(recs)

    phases = {}  # (level, beta) -> aggregate newton work
    for r in k.get("newton_iter", []):
        cg = r["cg_iters"]
        cohort = isinstance(cg, (list, tuple))
        key = (r.get("level"), r["beta"])
        p = phases.setdefault(
            key,
            {"level": r.get("level"), "beta": r["beta"], "source": r["source"],
             "iters": 0, "cg_iters": 0, "armijo_trials": 0, "wall_s": 0.0,
             "subjects": r.get("subjects") or 0},
        )
        p["iters"] += 1
        p["cg_iters"] += sum(cg) if cohort else cg
        p["armijo_trials"] += r.get("armijo_trials") or 0
        p["wall_s"] += r.get("wall_s") or 0.0

    spans = {}
    for r in k.get("span", []):
        s = spans.setdefault(r["path"] or r["name"], {"count": 0, "total_s": 0.0})
        s["count"] += 1
        s["total_s"] += r["wall_s"]
    for s in spans.values():
        s["mean_s"] = s["total_s"] / s["count"]

    jobs = [
        {f: r[f] for f in (
            "job_id", "slot", "newton_iters", "hessian_matvecs",
            "fine_equiv_matvecs", "queue_wait_steps", "admitted_step",
            "retired_step", "rel_gnorm", "converged")}
        for r in k.get("job", [])
    ]
    serve = None
    steps = k.get("serve_step", [])
    if steps:
        serve = {
            "steps": len(steps),
            "slots": steps[-1]["slots"],
            "refills": steps[-1]["refills"],
            "mean_occupancy": sum(s["occupancy"] for s in steps) / len(steps),
            "max_queue": max(s["queue_len"] for s in steps),
        }

    collectives = {r["label"]: r["collectives"] for r in k.get("collectives", [])}
    counters = {r["name"]: r["total"] for r in k.get("counter", [])}

    return {
        "n_records": len(recs),
        "kinds": {kind: len(v) for kind, v in sorted(k.items())},
        "phases": [phases[key] for key in sorted(phases, key=lambda t: (
            -1 if t[0] is None else t[0], -t[1]))],
        "levels": k.get("level", []),
        "solves": k.get("solve", []),
        "spans": spans,
        "jobs": jobs,
        "serve": serve,
        "collectives": collectives,
        "counters": counters,
        "bench": k.get("bench", []),
    }


def _table(headers: list[str], rows: list[list], title: str) -> str:
    cells = [[str(c) for c in row] for row in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    lines = [title, "  " + "  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    lines.append("  " + "  ".join("-" * w for w in widths))
    for r in cells:
        lines.append("  " + "  ".join(c.ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)


def _f(x, spec=".3f"):
    return "-" if x is None else format(x, spec)


def render(summary: dict) -> str:
    out = []
    kinds = " ".join(f"{k}={n}" for k, n in summary["kinds"].items())
    out.append(f"{summary['n_records']} records: {kinds}")

    if summary["phases"]:
        rows = [
            [("-" if p["level"] is None else p["level"]), f"{p['beta']:.0e}",
             p["iters"], p["cg_iters"], p["armijo_trials"],
             _f(p["wall_s"]), p["subjects"] or "-"]
            for p in summary["phases"]
        ]
        out.append(_table(
            ["level", "beta", "newton", "cg_matvecs", "armijo", "wall_s", "subjects"],
            rows, "\nphases (newton work by level/beta):"))

    if summary["levels"]:
        rows = [
            ["x".join(map(str, l["shape"])), l["newton_iters"],
             l["hessian_matvecs"], _f(l["fine_equiv_matvecs"], ".1f"),
             _f(l.get("precond_fine_equiv_matvecs"), ".1f"), _f(l["wall_s"], ".2f")]
            for l in summary["levels"]
        ]
        out.append(_table(
            ["grid", "newton", "matvecs", "fine_equiv", "precond_fe", "wall_s"],
            rows, "\nladder levels:"))

    if summary["spans"]:
        rows = [
            [path, s["count"], _f(s["total_s"]), _f(s["mean_s"], ".4f")]
            for path, s in sorted(summary["spans"].items())
        ]
        out.append(_table(["span", "count", "total_s", "mean_s"], rows,
                          "\nspans (wall-clock):"))

    if summary["jobs"]:
        rows = [
            [j["job_id"], j["slot"], j["newton_iters"], j["hessian_matvecs"],
             j["queue_wait_steps"], f"{j['rel_gnorm']:.2e}",
             "yes" if j["converged"] else "NO"]
            for j in summary["jobs"]
        ]
        out.append(_table(
            ["job", "slot", "newton", "matvecs", "queue_wait", "rel_gnorm", "conv"],
            rows, "\njobs (per-tenant billing):"))
    if summary["serve"]:
        sv = summary["serve"]
        out.append(
            f"\nserve: {sv['steps']} cohort steps, mean occupancy "
            f"{sv['mean_occupancy']:.2f}/{sv['slots']}, {sv['refills']} refills, "
            f"max queue {sv['max_queue']}"
        )

    if summary["collectives"]:
        kinds_order = ("all-to-all", "collective-permute", "all-gather",
                       "all-reduce", "reduce-scatter")
        rows = []
        for label, coll in sorted(summary["collectives"].items()):
            rows.append(
                [label]
                + [coll.get(kn, {}).get("count", 0) for kn in kinds_order]
                + [coll.get("total_bytes", 0)]
            )
        out.append(_table(
            ["program", "a2a", "permute", "gather", "reduce", "rscatter", "bytes"],
            rows, "\ncollectives (per compiled program):"))

    if summary["counters"]:
        rows = [[name, total] for name, total in sorted(summary["counters"].items())]
        out.append(_table(["counter", "total"], rows, "\ncounters:"))

    if summary["bench"]:
        rows = [[b["name"], _f(b["us_per_call"], ".1f"), b.get("derived", "")]
                for b in summary["bench"]]
        out.append(_table(["bench", "us/call", "derived"], rows, "\nbench rows:"))

    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="telemetry JSONL trace file")
    ap.add_argument("--validate", action="store_true",
                    help="check every record against the schema; non-zero exit "
                         "on any violation")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of tables")
    args = ap.parse_args(argv)

    recs = load(args.trace)
    if args.validate:
        bad = 0
        for i, r in enumerate(recs, 1):
            for err in validate_record(r):
                print(f"{args.trace}:{i}: {err}", file=sys.stderr)
                bad += 1
        if bad:
            print(f"{bad} schema violation(s) in {len(recs)} records",
                  file=sys.stderr)
            return 1
        print(f"{len(recs)} records validate (schema v"
              f"{recs[0]['v'] if recs else '?'})")

    summary = summarize(recs)
    print(json.dumps(summary, indent=1) if args.json else render(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Periodic Cartesian grid on Omega = [0, 2pi)^3 (paper §II, §III-B1).

All registration fields live on a regular grid with periodic boundary
conditions.  Scalars have shape ``(N1, N2, N3)``; vector fields are stored
component-major as ``(3, N1, N2, N3)``.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import jax.numpy as jnp
import numpy as np

TWO_PI = 2.0 * np.pi


@dataclasses.dataclass(frozen=True)
class Grid:
    """Static description of the spatial grid (hashable; safe as jit static)."""

    shape: tuple[int, int, int]
    dtype: jnp.dtype = jnp.float32

    @property
    def n(self) -> tuple[int, int, int]:
        return self.shape

    @property
    def num_points(self) -> int:
        n1, n2, n3 = self.shape
        return n1 * n2 * n3

    @property
    def spacing(self) -> tuple[float, float, float]:
        return tuple(TWO_PI / ni for ni in self.shape)

    @property
    def cell_volume(self) -> float:
        """Quadrature weight h1*h2*h3 for L2 inner products (mesh independence)."""
        h1, h2, h3 = self.spacing
        return h1 * h2 * h3

    @cached_property
    def coords(self) -> np.ndarray:
        """Physical coordinates x_i = 2*pi*i/N, shape (3, N1, N2, N3)."""
        axes = [np.arange(ni) * (TWO_PI / ni) for ni in self.shape]
        return np.stack(np.meshgrid(*axes, indexing="ij"), axis=0)

    def coords_jnp(self) -> jnp.ndarray:
        return jnp.asarray(self.coords, dtype=self.dtype)

    # --- wavenumbers (integer modes; spectral derivative is i*k) ---------
    def wavenumbers(self, axis: int) -> np.ndarray:
        n = self.shape[axis]
        return np.fft.fftfreq(n, d=1.0 / n)  # integers 0..N/2-1, -N/2..-1

    def wavenumbers_rfft(self) -> np.ndarray:
        n = self.shape[2]
        return np.fft.rfftfreq(n, d=1.0 / n)  # 0..N/2

    def k_grids(self, rfft_last: bool = True) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Broadcastable integer wavenumber grids (k1, k2, k3)."""
        k1 = self.wavenumbers(0).reshape(-1, 1, 1)
        k2 = self.wavenumbers(1).reshape(1, -1, 1)
        k3 = (self.wavenumbers_rfft() if rfft_last else self.wavenumbers(2)).reshape(1, 1, -1)
        return k1, k2, k3

    def k_deriv(self, rfft_last: bool = True) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Wavenumbers for odd-order derivatives: Nyquist mode zeroed.

        The derivative of the real Nyquist mode has no consistent sign; the
        standard spectral convention zeroes it (keeps d/dx skew-adjoint).
        """
        out = []
        for axis, k in enumerate(self.k_grids(rfft_last)):
            n = self.shape[axis]
            if n % 2 == 0:
                k = np.where(np.abs(k) == n // 2, 0.0, k)
            out.append(k)
        return tuple(out)

    def inner(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Weighted L2 inner product <a, b> = h^3 * sum(a*b) (any rank).

        Accumulates in at-least-f32 (bf16 inputs are upcast; f64 preserved).
        """
        acc = jnp.promote_types(jnp.result_type(a, b), jnp.float32)
        return jnp.sum(a.astype(acc) * b.astype(acc)) * self.cell_volume

    def norm_sq(self, a: jnp.ndarray) -> jnp.ndarray:
        return self.inner(a, a)

    def inner_per(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Per-subject inner product over a leading cohort axis.

        ``a``/``b`` are ``(S, ...)`` stacks; reduces every axis but the
        first, returning ``(S,)`` — the cohort solver's masked PCG and
        Armijo tests need one scalar per subject.
        """
        acc = jnp.promote_types(jnp.result_type(a, b), jnp.float32)
        prod = (a.astype(acc) * b.astype(acc)).reshape(a.shape[0], -1)
        return jnp.sum(prod, axis=1) * self.cell_volume

    def norm_sq_per(self, a: jnp.ndarray) -> jnp.ndarray:
        return self.inner_per(a, a)


def make_grid(n, dtype=jnp.float32) -> Grid:
    if isinstance(n, int):
        n = (n, n, n)
    return Grid(shape=tuple(int(x) for x in n), dtype=dtype)

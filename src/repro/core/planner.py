"""Semi-Lagrangian interpolation planner (paper §III-C2).

The paper computes departure points and communication plans *once per
velocity field per Newton iteration* ("interpolation planner") and reuses
them across every transport solve of that iteration (state, adjoint, all
PCG Hessian matvecs).  We reproduce exactly that: an ``SLPlan`` holds the
RK2 departure displacements for +v (state / incremental state) and -v
(adjoint / incremental adjoint), plus ``div v`` for the compressible source
terms.  In the distributed solver the plan additionally fixes the halo
width for the ghost-layer exchange (the TPU analogue of Algorithm 1's
scatter phase).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.grid import Grid
from repro.kernels import ops as kops


class SLPlan(NamedTuple):
    """Everything reusable across transport solves for a fixed velocity."""

    disp_fwd: jnp.ndarray  # (3,N1,N2,N3) departure displacement for +v, grid units
    disp_adj: jnp.ndarray  # same for -v
    divv: jnp.ndarray | None  # div v on the grid (None in incompressible mode)
    dt: float
    n_t: int


def departure_displacement(v: jnp.ndarray, grid: Grid, dt: float, interp=None) -> jnp.ndarray:
    """RK2 departure points, paper eq. (6), returned as grid-unit displacement.

        X* = x - dt * v(x);   X = x - dt/2 * (v(x) + v(X*))

    ``v`` is in physical units on Omega=[0,2pi)^3; the returned displacement
    is ``(X - x)/h`` per dimension so interpolation kernels can use it
    directly.
    """
    interp = interp or kops.tricubic_displace
    ct = jnp.promote_types(v.dtype, jnp.float32)
    h = jnp.asarray(grid.spacing, dtype=ct).reshape(3, 1, 1, 1)
    vg = v.astype(ct) / h  # velocity in grid cells / unit time
    d_star = -dt * vg
    # per-component scalar interpolation (unrolled: keeps distributed
    # implementations free of vmap-over-shard_map)
    v_star = jnp.stack([interp(vg[i], d_star) for i in range(3)])
    return (-0.5 * dt) * (vg + v_star)


def make_plan(
    v: jnp.ndarray,
    grid: Grid,
    spectral_ops,
    n_t: int,
    incompressible: bool,
    interp=None,
) -> SLPlan:
    """Build the per-Newton-iteration plan (one departure solve per sign)."""
    dt = 1.0 / n_t
    disp_fwd = departure_displacement(v, grid, dt, interp)
    disp_adj = departure_displacement(-v, grid, dt, interp)
    divv = None if incompressible else spectral_ops.div(v)
    return SLPlan(disp_fwd=disp_fwd, disp_adj=disp_adj, divv=divv, dt=dt, n_t=n_t)


def required_halo(plan: SLPlan) -> jnp.ndarray:
    """Ghost-layer width needed by the tiled/distributed interpolation.

    ceil(max |displacement|) — the stencil's extra +-(1,2) voxels are part
    of the kernels' fixed padding.  Traced value: the distributed layer
    enforces exactly this bound at runtime — ``DistContext`` wraps its halo
    interp with ``repro.dist.halo.make_checked_interp``, which re-derives
    the bound per displacement field and NaN-poisons (``halo_check="error"``,
    default) or falls back to the global gather (``"gather"``) instead of
    silently reading ring-wrapped ghost data when a line-search step
    overshoots ``DistContext.halo``.
    """
    return jnp.ceil(
        jnp.maximum(kops.max_displacement(plan.disp_fwd), kops.max_displacement(plan.disp_adj))
    )

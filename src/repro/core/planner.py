"""Semi-Lagrangian interpolation planner (paper §III-C2).

The paper computes departure points and communication plans *once per
velocity field per Newton iteration* ("interpolation planner") and reuses
them across every transport solve of that iteration (state, adjoint, all
PCG Hessian matvecs).  We reproduce exactly that, in two layers:

* ``SLPlan`` holds the RK2 departure displacements for +v (state /
  incremental state) and -v (adjoint / incremental adjoint), plus
  ``div v`` for the compressible source terms.
* each displacement additionally carries a precomputed ``InterpPlan``
  (``kernels/ref.py``): per-point stencil base offsets + separable Lagrange
  weights — the ~600-flop §III-C2 weight construction paid once per Newton
  iteration instead of once per interp call.  ``core.semilag`` binds these
  cached operators through the interp protocol (``interp.apply_plan``), so
  the PCG Hessian matvecs, the adjoint sweep, and the line-search
  re-transports all hit precomputed weights.

In the distributed solver the plan also fixes the halo width for the
ghost-layer exchange (the TPU analogue of Algorithm 1's scatter phase);
``InterpPlan.halo_need`` caches the bound so the runtime budget check of
``dist.halo.make_checked_interp`` is free per apply.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.grid import Grid
from repro.kernels import ops as kops
from repro.kernels import ref


class SLPlan(NamedTuple):
    """Everything reusable across transport solves for a fixed velocity."""

    disp_fwd: jnp.ndarray  # (3,N..) departure displacement for +v, grid
    #   units; a cohort plan (velocity (S,3,N..)) carries (S,3,N..)
    disp_adj: jnp.ndarray | None  # same for -v (None in forward-only plans)
    divv: jnp.ndarray | None  # div v on the grid (None in incompressible mode)
    dt: float
    n_t: int
    # precomputed interpolation operators (None when the interp callable
    # does not implement the plan protocol — e.g. ad-hoc test stubs)
    iplan_fwd: ref.InterpPlan | None = None
    iplan_adj: ref.InterpPlan | None = None


def departure_displacement(v: jnp.ndarray, grid: Grid, dt: float, interp=None) -> jnp.ndarray:
    """RK2 departure points, paper eq. (6), returned as grid-unit displacement.

        X* = x - dt * v(x);   X = x - dt/2 * (v(x) + v(X*))

    ``v`` is in physical units on Omega=[0,2pi)^3; the returned displacement
    is ``(X - x)/h`` per dimension so interpolation kernels can use it
    directly.  The three velocity components ride ONE batched interp call
    (single ghost exchange on a mesh; see the batched-field contract in
    ``repro.dist.halo``).

    A cohort velocity ``(S, 3, N..)`` yields per-subject displacements
    ``(S, 3, N..)``: the interp contract puts the subject axis at ``-4`` of
    the *fields*, so the component axis is swapped to the channel slot for
    the one batched self-interpolation and swapped back.
    """
    ct = jnp.promote_types(v.dtype, jnp.float32)
    h = jnp.asarray(grid.spacing, dtype=ct).reshape(3, 1, 1, 1)
    vg = v.astype(ct) / h  # velocity in grid cells / unit time
    d_star = -dt * vg
    if v.ndim == 5:  # cohort: fields (3, S, N..) against disp (S, 3, N..)
        fields = jnp.swapaxes(vg, 0, 1)
        if interp is None:
            out = kops.tricubic_displace_many(fields, d_star)
        else:
            out = interp(fields, d_star)
        v_star = jnp.swapaxes(out, 0, 1)
    elif interp is None:
        v_star = kops.tricubic_displace_many(vg, d_star)  # auto kernel dispatch
    else:
        v_star = interp(vg, d_star)
    return (-0.5 * dt) * (vg + v_star)


def make_plan(
    v: jnp.ndarray,
    grid: Grid,
    spectral_ops,
    n_t: int,
    incompressible: bool,
    interp=None,
    adjoint: bool = True,
    divv: jnp.ndarray | None = None,
) -> SLPlan:
    """Build the per-Newton-iteration plan (one departure solve per sign,
    one precomputed ``InterpPlan`` per departure field).

    ``adjoint=False`` builds a forward-only plan (``disp_adj``/``iplan_adj``
    left ``None``) — what a pure objective evaluation needs; the Armijo line
    search probes many trial velocities and never transports backward.

    ``divv`` optionally supplies a precomputed ``div v`` so the caller can
    coalesce its spectral round trip with other transforms
    (``objective.newton_state`` rides it with the regularization/energy
    stack through one ``SpectralBatch``); when omitted (and compressible)
    it costs one dedicated ride pair here.
    """
    dt = 1.0 / n_t
    disp_fwd = departure_displacement(v, grid, dt, interp)
    disp_adj = departure_displacement(-v, grid, dt, interp) if adjoint else None
    if incompressible:
        divv = None
    elif divv is None:
        divv = spectral_ops.div(v)
    planner = ref.make_interp_plan if interp is None else getattr(interp, "make_plan", None)
    iplan_fwd = planner(disp_fwd) if planner is not None else None
    iplan_adj = planner(disp_adj) if planner is not None and adjoint else None
    return SLPlan(
        disp_fwd=disp_fwd,
        disp_adj=disp_adj,
        divv=divv,
        dt=dt,
        n_t=n_t,
        iplan_fwd=iplan_fwd,
        iplan_adj=iplan_adj,
    )


def required_halo(plan: SLPlan) -> jnp.ndarray:
    """Ghost-layer width needed by the tiled/distributed interpolation.

    ceil(max |displacement|) — the stencil's extra +-(1,2) voxels are part
    of the kernels' fixed padding.  Traced value: the distributed layer
    enforces exactly this bound at runtime — ``DistContext`` wraps its halo
    interp with ``repro.dist.halo.make_checked_interp``, which reads the
    bound off the cached ``InterpPlan.halo_need`` (or re-derives it per
    displacement field) and NaN-poisons (``halo_check="error"``, default)
    or falls back to the exact global gather (``"gather"``) instead of
    silently reading ring-wrapped ghost data when a line-search step
    overshoots ``DistContext.halo``.
    """
    def need(disp, iplan):
        if iplan is not None:
            return iplan.halo_need
        return jnp.ceil(kops.max_displacement(disp))

    fwd = need(plan.disp_fwd, plan.iplan_fwd)
    if plan.disp_adj is None:
        return fwd
    return jnp.maximum(fwd, need(plan.disp_adj, plan.iplan_adj))

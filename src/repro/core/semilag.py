"""Semi-Lagrangian transport solvers (paper §III-B2, eq. (6)-(7), Alg. 2).

Unconditionally stable RK2 along characteristics, so ``n_t = 4`` time steps
suffice (the paper's setting) and storing all time slices is feasible —
which the Gauss-Newton Hessian needs (eq. (5) requires rho(t) at all t).

Every solver takes an ``SLPlan`` (departure points computed once per
velocity — paper's planner) and an ``interp`` callable so the same code
runs single-device (oracle/Pallas kernels via ``repro.kernels.ops``) and
distributed (``repro.dist.halo.make_halo_interp``'s ghost-layer exchange,
available pre-wired as ``DistContext.interp``).

General scheme for  d_t nu + v . grad nu = f  (paper eq. (7)):

    nu0X  = nu(X, t)            (interpolated at departure points)
    f0X   = f(., t) at X        (f formed on the grid, then interpolated)
    nu*   = nu0X + dt f0X
    f*    = f(., t+dt) at x     (on the grid)
    nu(x, t+dt) = nu0X + dt/2 (f0X + f*)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.planner import SLPlan
from repro.kernels import ops as kops


def _default_interp(field, disp):
    return kops.tricubic_displace(field, disp, method="ref")


# --------------------------------------------------------------------------- #
# state equation (2b): pure advection, forward in time
# --------------------------------------------------------------------------- #
def transport_state(rho0: jnp.ndarray, plan: SLPlan, interp=None) -> jnp.ndarray:
    """Solve d_t rho + v.grad rho = 0; returns all slices (n_t+1, N1,N2,N3)."""
    interp = interp or _default_interp

    def step(rho, _):
        nxt = interp(rho, plan.disp_fwd)
        return nxt, nxt

    _, series = jax.lax.scan(step, rho0, None, length=plan.n_t)
    return jnp.concatenate([rho0[None], series], axis=0)


# --------------------------------------------------------------------------- #
# adjoint equation (3): -d_t lam - div(v lam) = 0, backward in time.
# In tau = 1-t:  d_tau lam + (-v).grad lam = lam div v.
# Incompressible (div v = 0): pure advection along -v.
# --------------------------------------------------------------------------- #
def transport_adjoint(lam1: jnp.ndarray, plan: SLPlan, interp=None) -> jnp.ndarray:
    """Returns lam at all *t*-slices, index k = t_k (so [..., -1] is t=1)."""
    interp = interp or _default_interp
    dt = plan.dt

    if plan.divv is None:

        def step(lam, _):
            nxt = interp(lam, plan.disp_adj)
            return nxt, nxt

    else:
        divv = plan.divv

        def step(lam, _):
            lam0X = interp(lam, plan.disp_adj)
            f0X = interp(lam * divv, plan.disp_adj)
            lam_star = lam0X + dt * f0X
            f_star = lam_star * divv
            nxt = lam0X + 0.5 * dt * (f0X + f_star)
            return nxt, nxt

    _, series_tau = jax.lax.scan(step, lam1, None, length=plan.n_t)
    series = jnp.concatenate([lam1[None], series_tau], axis=0)
    return series[::-1]  # tau-order -> t-order


# --------------------------------------------------------------------------- #
# incremental state equation (5a) (Alg. 2):
#   d_t rho~ + v.grad rho~ = -v~ . grad rho(t),  rho~(0) = 0
# --------------------------------------------------------------------------- #
def transport_inc_state(
    vtilde: jnp.ndarray,
    grad_rho_series: jnp.ndarray,  # (n_t+1, 3, N1,N2,N3), precomputed spectrally
    plan: SLPlan,
    interp=None,
) -> jnp.ndarray:
    """Returns rho~(1) (only the final slice is needed for Gauss-Newton)."""
    interp = interp or _default_interp
    dt = plan.dt
    rho0 = jnp.zeros_like(grad_rho_series[0, 0])

    def source(k):
        # f(., t_k) = -v~ . grad rho(t_k) on the grid
        return -jnp.sum(vtilde * grad_rho_series[k], axis=0)

    def step(carry, k):
        rt = carry
        f0 = source(k)
        rt0X = interp(rt, plan.disp_fwd)
        f0X = interp(f0, plan.disp_fwd)
        f_star = source(k + 1)
        nxt = rt0X + 0.5 * dt * (f0X + f_star)
        return nxt, None

    rho1, _ = jax.lax.scan(step, rho0, jnp.arange(plan.n_t))
    return rho1


# --------------------------------------------------------------------------- #
# incremental adjoint (5c), Gauss-Newton form (drop lambda terms):
#   -d_t lam~ - div(lam~ v) = 0,  lam~(1) = -rho~(1)
# Same operator as the adjoint equation.
# --------------------------------------------------------------------------- #
def transport_inc_adjoint(lam1: jnp.ndarray, plan: SLPlan, interp=None) -> jnp.ndarray:
    return transport_adjoint(lam1, plan, interp)


# --------------------------------------------------------------------------- #
# incremental adjoint, FULL NEWTON form (paper eq. (5c) with all terms):
#   -d_t lam~ - div(lam~ v + lam vt) = 0,  lam~(1) = -rho~(1)
# In tau: d_tau lam~ + (-v).grad lam~ = lam~ div v + div(lam(t) vt).
# Needs lam(t) at every slice (stored by newton_state) and one spectral
# divergence per step for the div(lam vt) source.
# --------------------------------------------------------------------------- #
def transport_inc_adjoint_newton(
    lam1: jnp.ndarray,
    lam_series: jnp.ndarray,  # (n_t+1, N..) in t-order
    vtilde: jnp.ndarray,
    plan: SLPlan,
    spectral_ops,
    interp=None,
) -> jnp.ndarray:
    interp = interp or _default_interp
    dt = plan.dt
    n_t = plan.n_t
    divv = plan.divv  # None in incompressible mode

    # div(lam(t_k) vt) on the grid, all slices in one batched spectral call
    lam_vt = lam_series[:, None] * vtilde[None]  # (n_t+1, 3, N..)
    spec = spectral_ops.fft.fwd(lam_vt)
    div_lam_vt = sum(
        spectral_ops.fft.inv(1j * k * spec[:, i]) for i, k in enumerate(spectral_ops.fft.kd)
    )  # (n_t+1, N..)

    def source(lam_t, k):
        f = div_lam_vt[k]
        if divv is not None:
            f = f + lam_t * divv
        return f

    def step(carry, j):
        lamt = carry
        k = n_t - j  # current t-index (tau_j = 1 - t)
        f0 = source(lamt, k)
        lam0X = interp(lamt, plan.disp_adj)
        f0X = interp(f0, plan.disp_adj)
        lam_star = lam0X + dt * f0X
        f_star = source(lam_star, k - 1)
        nxt = lam0X + 0.5 * dt * (f0X + f_star)
        return nxt, nxt

    _, series_tau = jax.lax.scan(step, lam1, jnp.arange(n_t))
    series = jnp.concatenate([lam1[None], series_tau], axis=0)
    return series[::-1]  # t-order


def transport_inc_state_series(
    vtilde: jnp.ndarray, grad_rho_series: jnp.ndarray, plan: SLPlan, interp=None
) -> jnp.ndarray:
    """Like transport_inc_state but returns ALL slices (full Newton needs
    grad rho~(t_k) for the second b~ term)."""
    interp = interp or _default_interp
    dt = plan.dt
    rho0 = jnp.zeros_like(grad_rho_series[0, 0])

    def source(k):
        return -jnp.sum(vtilde * grad_rho_series[k], axis=0)

    def step(carry, k):
        rt = carry
        f0 = source(k)
        rt0X = interp(rt, plan.disp_fwd)
        f0X = interp(f0, plan.disp_fwd)
        f_star = source(k + 1)
        nxt = rt0X + 0.5 * dt * (f0X + f_star)
        return nxt, nxt

    _, series = jax.lax.scan(step, rho0, jnp.arange(plan.n_t))
    return jnp.concatenate([rho0[None], series], axis=0)


# --------------------------------------------------------------------------- #
# time quadrature:  b = int_0^1 lam(t) grad rho(t) dt   (trapezoidal)
# --------------------------------------------------------------------------- #
def time_integral_b(lam_series: jnp.ndarray, grad_rho_series: jnp.ndarray, dt: float) -> jnp.ndarray:
    """lam_series (n_t+1, N..), grad_rho_series (n_t+1, 3, N..) -> (3, N..)."""
    n = lam_series.shape[0]
    w = jnp.full((n,), dt, dtype=jnp.float32).at[0].mul(0.5).at[-1].mul(0.5)
    return jnp.einsum("t,txyz,tcxyz->cxyz", w, lam_series, grad_rho_series)


# --------------------------------------------------------------------------- #
# deformation map (1): d_t y + v.grad y = 0, y(x,0) = x.
# Solved for the periodic displacement u = y - x:
#   d_t u + v.grad u = -v,  u(0) = 0.
# --------------------------------------------------------------------------- #
def deformation_displacement(v: jnp.ndarray, plan: SLPlan, interp=None) -> jnp.ndarray:
    """Returns u(1) (3, N1,N2,N3) in *physical* units; y1 = x + u."""
    interp = interp or _default_interp
    dt = plan.dt
    u0 = jnp.zeros_like(v)

    def comp_step(u_c, f_c):
        u0X = interp(u_c, plan.disp_fwd)
        f0X = interp(f_c, plan.disp_fwd)
        return u0X + 0.5 * dt * (f0X + f_c)  # f is time-independent (-v)

    def step(u, _):
        nxt = jnp.stack([comp_step(u[i], -v[i]) for i in range(3)])
        return nxt, None

    u1, _ = jax.lax.scan(step, u0, None, length=plan.n_t)
    return u1

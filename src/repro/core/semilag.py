"""Semi-Lagrangian transport solvers (paper §III-B2, eq. (6)-(7), Alg. 2).

Unconditionally stable RK2 along characteristics, so ``n_t = 4`` time steps
suffice (the paper's setting) and storing all time slices is feasible —
which the Gauss-Newton Hessian needs (eq. (5) requires rho(t) at all t).

Every solver takes an ``SLPlan`` (departure points + precomputed
``InterpPlan`` operators, built once per velocity — the paper's planner)
and an ``interp`` callable so the same code runs single-device (the
``repro.kernels.ops.Interp`` executor over the oracle/Pallas kernels) and
distributed (``repro.dist.halo``'s ghost-layer exchange, pre-wired as
``DistContext.interp``).

Interp contract (the **batched multi-field** protocol):

    interp(fields, disp)           fields (..., N1,N2,N3); leading dims are
                                   channels evaluated at the same departure
                                   points in one call (one ghost-exchange
                                   round on a mesh, one kernel launch)
    interp.make_plan(disp)         optional: precompute an InterpPlan
    interp.apply_plan(fields, p)   optional: planned apply

``_bind`` resolves the fastest available path once per transport solve:
whenever the ``SLPlan`` carries a cached ``InterpPlan`` and the interp
implements ``apply_plan``, every step of the scan hits precomputed weights;
otherwise it degrades to the plain ``interp(fields, disp)`` form (which
still batches channels).  The transports below exploit the batching by
stacking the fields of each RK2 stage — e.g. ``lam`` with ``lam * div v``
in the compressible adjoint — into single calls.

General scheme for  d_t nu + v . grad nu = f  (paper eq. (7)):

    nu0X  = nu(X, t)            (interpolated at departure points)
    f0X   = f(., t) at X        (f formed on the grid, then interpolated)
    nu*   = nu0X + dt f0X
    f*    = f(., t+dt) at x     (on the grid)
    nu(x, t+dt) = nu0X + dt/2 (f0X + f*)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.planner import SLPlan
from repro.kernels import ref


def _bind(interp, disp, iplan):
    """Resolve one displacement field to a batched applier ``fields -> out``.

    Preference order: cached-plan apply (planner-built operators, the
    plan-once/apply-many fast path) > the interp's own planned path >
    plain per-call interpolation.
    """
    if interp is None:
        iplan = ref.make_interp_plan(disp) if iplan is None else iplan
        return lambda fields: ref.interp_apply(fields, iplan)
    apply_plan = getattr(interp, "apply_plan", None)
    if iplan is not None and apply_plan is not None:
        return lambda fields: apply_plan(fields, iplan)
    return lambda fields: interp(fields, disp)


def _bind_fwd(plan: SLPlan, interp):
    return _bind(interp, plan.disp_fwd, plan.iplan_fwd)


def _bind_adj(plan: SLPlan, interp):
    if plan.disp_adj is None:
        raise ValueError(
            "forward-only SLPlan (make_plan(adjoint=False)) has no adjoint "
            "departure field; rebuild with adjoint=True for backward transports"
        )
    return _bind(interp, plan.disp_adj, plan.iplan_adj)


# --------------------------------------------------------------------------- #
# state equation (2b): pure advection, forward in time
# --------------------------------------------------------------------------- #
def transport_state(
    rho0: jnp.ndarray, plan: SLPlan, interp=None, field_dtype=None
) -> jnp.ndarray:
    """Solve d_t rho + v.grad rho = 0; returns all slices (n_t+1, N1,N2,N3).

    ``field_dtype`` (e.g. ``jnp.bfloat16``) selects the storage dtype of the
    transported stack: the initial condition is cast once and every slice
    inherits it (the planned interpolation applies in >= f32 and casts back
    to the field dtype — ``kernels/ref.py``), halving the series' footprint
    and the ghost-exchange bytes of each step on a mesh.
    """
    if field_dtype is not None:
        rho0 = rho0.astype(field_dtype)
    at_fwd = _bind_fwd(plan, interp)

    def step(rho, _):
        nxt = at_fwd(rho)
        return nxt, nxt

    _, series = jax.lax.scan(step, rho0, None, length=plan.n_t)
    return jnp.concatenate([rho0[None], series], axis=0)


# --------------------------------------------------------------------------- #
# adjoint equation (3): -d_t lam - div(v lam) = 0, backward in time.
# In tau = 1-t:  d_tau lam + (-v).grad lam = lam div v.
# Incompressible (div v = 0): pure advection along -v.
# --------------------------------------------------------------------------- #
def transport_adjoint(
    lam1: jnp.ndarray, plan: SLPlan, interp=None, field_dtype=None
) -> jnp.ndarray:
    """Returns lam at all *t*-slices, index k = t_k (so [..., -1] is t=1).

    ``field_dtype``: storage dtype of the adjoint stack (see
    ``transport_state``)."""
    if field_dtype is not None:
        lam1 = lam1.astype(field_dtype)
    at_adj = _bind_adj(plan, interp)
    dt = plan.dt

    if plan.divv is None:

        def step(lam, _):
            nxt = at_adj(lam)
            return nxt, nxt

    else:
        divv = plan.divv

        def step(lam, _):
            # lam and lam*divv share one batched interpolation (C=2):
            # one ghost exchange on a mesh instead of two.  The carry keeps
            # lam's storage dtype even if divv is wider (mixed field_dtype).
            lam0X, f0X = at_adj(jnp.stack([lam, lam * divv]))
            lam_star = lam0X + dt * f0X
            f_star = lam_star * divv
            nxt = (lam0X + 0.5 * dt * (f0X + f_star)).astype(lam.dtype)
            return nxt, nxt

    _, series_tau = jax.lax.scan(step, lam1, None, length=plan.n_t)
    series = jnp.concatenate([lam1[None], series_tau], axis=0)
    return series[::-1]  # tau-order -> t-order


# --------------------------------------------------------------------------- #
# incremental state equation (5a) (Alg. 2):
#   d_t rho~ + v.grad rho~ = -v~ . grad rho(t),  rho~(0) = 0
# --------------------------------------------------------------------------- #
def transport_inc_state(
    vtilde: jnp.ndarray,
    grad_rho_series: jnp.ndarray,  # (n_t+1, 3, N1,N2,N3), precomputed spectrally
    plan: SLPlan,
    interp=None,
) -> jnp.ndarray:
    """Returns rho~(1) (only the final slice is needed for Gauss-Newton)."""
    at_fwd = _bind_fwd(plan, interp)
    dt = plan.dt
    # carry in the promoted compute dtype: under bf16 field storage the
    # source term -v~.grad rho may be wider than the stored series (v~ is
    # the f32 PCG iterate), and a scan carry must keep one dtype throughout
    ct = jnp.result_type(vtilde, grad_rho_series)
    rho0 = jnp.zeros_like(grad_rho_series[0][..., 0, :, :, :], dtype=ct)

    def source(k):
        # f(., t_k) = -v~ . grad rho(t_k) on the grid; the component axis
        # sits at -4 for both the single (3,N..) and cohort (S,3,N..) layouts
        return -jnp.sum(vtilde * grad_rho_series[k], axis=-4)

    def step(carry, k):
        rt = carry
        rt0X, f0X = at_fwd(jnp.stack([rt, source(k)]))  # C=2 batched
        f_star = source(k + 1)
        nxt = (rt0X + 0.5 * dt * (f0X + f_star)).astype(ct)
        return nxt, None

    rho1, _ = jax.lax.scan(step, rho0, jnp.arange(plan.n_t))
    return rho1


# --------------------------------------------------------------------------- #
# incremental adjoint (5c), Gauss-Newton form (drop lambda terms):
#   -d_t lam~ - div(lam~ v) = 0,  lam~(1) = -rho~(1)
# Same operator as the adjoint equation.
# --------------------------------------------------------------------------- #
def transport_inc_adjoint(lam1: jnp.ndarray, plan: SLPlan, interp=None) -> jnp.ndarray:
    return transport_adjoint(lam1, plan, interp)


# --------------------------------------------------------------------------- #
# incremental adjoint, FULL NEWTON form (paper eq. (5c) with all terms):
#   -d_t lam~ - div(lam~ v + lam vt) = 0,  lam~(1) = -rho~(1)
# In tau: d_tau lam~ + (-v).grad lam~ = lam~ div v + div(lam(t) vt).
# Needs lam(t) at every slice (stored by newton_state) and one spectral
# divergence per step for the div(lam vt) source.
# --------------------------------------------------------------------------- #
def transport_inc_adjoint_newton(
    lam1: jnp.ndarray,
    lam_series: jnp.ndarray,  # (n_t+1, N..) in t-order
    vtilde: jnp.ndarray,
    plan: SLPlan,
    spectral_ops,
    interp=None,
    div_lam_vt: jnp.ndarray | None = None,
) -> jnp.ndarray:
    at_adj = _bind_adj(plan, interp)
    dt = plan.dt
    n_t = plan.n_t
    divv = plan.divv  # None in incompressible mode

    if div_lam_vt is None:
        # div(lam(t_k) vt) on the grid, all slices in one batched spectral
        # call; the full-Newton matvec (objective.full_hessian_matvec)
        # precomputes this series so it can coalesce the ride with the
        # grad rho~(t) series instead
        div_lam_vt = spectral_ops.div(lam_series[:, None] * vtilde[None])  # (n_t+1, N..)

    def source(lam_t, k):
        f = div_lam_vt[k]
        if divv is not None:
            f = f + lam_t * divv
        return f

    def step(carry, j):
        lamt = carry
        k = n_t - j  # current t-index (tau_j = 1 - t)
        lam0X, f0X = at_adj(jnp.stack([lamt, source(lamt, k)]))  # C=2 batched
        lam_star = lam0X + dt * f0X
        f_star = source(lam_star, k - 1)
        nxt = (lam0X + 0.5 * dt * (f0X + f_star)).astype(lam1.dtype)
        return nxt, nxt

    _, series_tau = jax.lax.scan(step, lam1, jnp.arange(n_t))
    series = jnp.concatenate([lam1[None], series_tau], axis=0)
    return series[::-1]  # t-order


def transport_inc_state_series(
    vtilde: jnp.ndarray, grad_rho_series: jnp.ndarray, plan: SLPlan, interp=None
) -> jnp.ndarray:
    """Like transport_inc_state but returns ALL slices (full Newton needs
    grad rho~(t_k) for the second b~ term)."""
    at_fwd = _bind_fwd(plan, interp)
    dt = plan.dt
    # promoted-dtype carry: see transport_inc_state
    ct = jnp.result_type(vtilde, grad_rho_series)
    rho0 = jnp.zeros_like(grad_rho_series[0][..., 0, :, :, :], dtype=ct)

    def source(k):
        return -jnp.sum(vtilde * grad_rho_series[k], axis=-4)

    def step(carry, k):
        rt = carry
        rt0X, f0X = at_fwd(jnp.stack([rt, source(k)]))
        f_star = source(k + 1)
        nxt = (rt0X + 0.5 * dt * (f0X + f_star)).astype(ct)
        return nxt, nxt

    _, series = jax.lax.scan(step, rho0, jnp.arange(plan.n_t))
    return jnp.concatenate([rho0[None], series], axis=0)


# --------------------------------------------------------------------------- #
# time quadrature:  b = int_0^1 lam(t) grad rho(t) dt   (trapezoidal)
# --------------------------------------------------------------------------- #
def time_integral_b(lam_series: jnp.ndarray, grad_rho_series: jnp.ndarray, dt: float) -> jnp.ndarray:
    """lam_series (n_t+1, N..), grad_rho_series (n_t+1, 3, N..) -> (3, N..).

    Cohort layouts — lam (n_t+1, S, N..), grad (n_t+1, S, 3, N..) — yield
    the per-subject stack (S, 3, N..)."""
    n = lam_series.shape[0]
    w = jnp.full((n,), dt, dtype=jnp.float32).at[0].mul(0.5).at[-1].mul(0.5)
    # critical accumulation: the time quadrature sums n_t+1 products, so
    # bf16-stored series (SpectralOps field_dtype) are upcast and the
    # contraction runs in >= f32 regardless of the storage dtype
    acc = jnp.promote_types(jnp.result_type(lam_series, grad_rho_series), jnp.float32)
    lam_series = lam_series.astype(acc)
    grad_rho_series = grad_rho_series.astype(acc)
    if lam_series.ndim == 5:  # cohort
        return jnp.einsum("t,tsxyz,tscxyz->scxyz", w, lam_series, grad_rho_series)
    return jnp.einsum("t,txyz,tcxyz->cxyz", w, lam_series, grad_rho_series)


# --------------------------------------------------------------------------- #
# deformation map (1): d_t y + v.grad y = 0, y(x,0) = x.
# Solved for the periodic displacement u = y - x:
#   d_t u + v.grad u = -v,  u(0) = 0.
# --------------------------------------------------------------------------- #
def deformation_displacement(v: jnp.ndarray, plan: SLPlan, interp=None) -> jnp.ndarray:
    """Returns u(1) (3, N1,N2,N3) in *physical* units; y1 = x + u.

    A cohort velocity ``(S, 3, N..)`` returns per-subject displacements of
    the same shape (the component axis is swapped into the interp channel
    slot around each batched call)."""
    at = _bind_fwd(plan, interp)
    if v.ndim == 5:  # cohort: interp wants the subject axis at -4
        at_fwd = lambda x: jnp.swapaxes(at(jnp.swapaxes(x, 0, 1)), 0, 1)
    else:
        at_fwd = at
    dt = plan.dt
    u0 = jnp.zeros_like(v)
    f = -v
    # f is time-independent, so f(X) is the same every step: interpolate the
    # 3 components once, outside the scan (C=3 batched)
    f0X = at_fwd(f)

    def step(u, _):
        u0X = at_fwd(u)  # C=3 batched
        nxt = u0X + 0.5 * dt * (f0X + f)
        return nxt, None

    u1, _ = jax.lax.scan(step, u0, None, length=plan.n_t)
    return u1

"""Objective, reduced gradient, and Gauss-Newton Hessian matvec (paper §II-B).

    J[v]   = 1/2 ||rho(1) - rho_R||^2_L2 + beta/2 ||Lap v||^2_L2          (2a)
    g(v)   = beta Lap^2 v + P b,    b = int_0^1 lam grad rho dt           (4)
    H vt   = beta Lap^2 vt + P bt,  bt = int_0^1 lamt grad rho dt (GN)    (5e)

``P`` is the Leray projection in incompressible mode, identity otherwise.
A ``NewtonState`` caches everything reusable across the PCG matvecs of one
Newton iteration: the SL plan (departure points AND the precomputed
``InterpPlan`` interpolation operators — base indices + separable Lagrange
weights, built once by ``planner.make_plan`` and bound per transport by
``semilag._bind``), the state series rho(t), and — a deliberate
memory-for-FFTs trade documented in EXPERIMENTS §Perf — the spectral
gradients grad rho(t_k) for all k.  With those caches a GN Hessian matvec
in incompressible mode needs *zero* transport FFTs and *zero* interpolation
weight constructions (only the gathers/contractions themselves plus the
regularization/Leray diagonal ops), versus 8 n_t FFTs in the paper's
Alg. 2 accounting.

**Transform coalescing** (this PR's hot-path restructuring): every spectral
round trip below rides a ``SpectralOps.batch()`` or an explicitly fused
k-space combine, so the per-stage transform count is minimal:

* ``newton_state`` stage A — ``div v`` (compressible), ``beta Lap^2 v``,
  and the regularization energy all depend only on ``v``: one coalesced
  ride pair instead of three, with the energy read off the shared forward
  spectrum by Parseval (``SpectralBatch.reg_energy`` — it joins no
  inverse ride at all).
* ``evaluate_objective`` (every Armijo trial) — the energy is the same
  spectrum-side reduction, so a trial costs one forward of ``v`` (shared
  with ``div v`` when compressible) and ZERO inverse transforms — one
  ride pair fewer than the eager ``reg_energy`` composition (pinned in
  ``tests/test_coalesce.py``).
* the gradient assembly — ``g = beta Lap^2 v + P b`` reuses stage A's
  ``beta Lap^2 v``; only ``P b`` costs a ride (none when compressible).
* ``gn_hessian_matvec`` — ``beta Lap^2 vt + P bt`` is ONE ride pair
  (``reg_plus_project``); compressible mode skips ``bt``'s transform
  entirely.  The all-to-all count per matvec is pinned ≥2x below the
  uncoalesced composition by ``tests/test_coalesce.py``.
* ``full_hessian_matvec`` — the ``div(lam vt)`` series and the
  ``grad rho~(t)`` series share one coalesced ride pair.

**Cohort axis** (the solves/second lever, ROADMAP item 1): every function
here is rank-polymorphic over a leading subjects axis ``S``.  A cohort
``Problem`` carries image stacks ``rho_R``/``rho_T`` of shape ``(S, N..)``
and a velocity stack ``(S, 3, N..)``; the cached series become
``rho (n_t+1, S, N..)`` / ``grad rho (n_t+1, S, 3, N..)``, the component
axis of vector fields always sits at ``-4``, and ``misfit``/``reg``/
``j_val`` are per-subject ``(S,)``.  All S subjects ride the SAME batched
interp calls (one ghost exchange per transport step on a mesh) and the
SAME coalesced transform rides — amortizing the collective-latency cost
of one solve across the whole cohort (``gn.solve_cohort``).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import semilag
from repro.core.grid import Grid
from repro.core.planner import SLPlan, make_plan
from repro.core.spectral import SpectralOps


class Problem(NamedTuple):
    grid: Grid
    rho_R: jnp.ndarray  # (N..) single subject; (S, N..) cohort
    rho_T: jnp.ndarray
    beta: float  # may be a traced scalar (the cohort driver's one-program continuation)
    n_t: int
    incompressible: bool


class NewtonState(NamedTuple):
    """Per-Newton-iteration cache shared by gradient and all Hessian matvecs.

    Cohort problems prepend a subjects axis: ``v (S,3,N..)``, series
    ``(n_t+1, S, ...)``, ``g (S,3,N..)``, and the scalar diagnostics
    become per-subject ``(S,)``.
    """

    v: jnp.ndarray
    plan: SLPlan
    rho_series: jnp.ndarray  # (n_t+1, N1,N2,N3)
    grad_rho_series: jnp.ndarray  # (n_t+1, 3, N1,N2,N3)
    lam_series: jnp.ndarray  # (n_t+1, N1,N2,N3)
    g: jnp.ndarray  # reduced gradient (3, N1,N2,N3)
    misfit: jnp.ndarray  # 1/2 ||rho(1)-rho_R||^2
    reg: jnp.ndarray  # beta/2 ||Lap v||^2
    j_val: jnp.ndarray


def _project(ops: SpectralOps, field: jnp.ndarray, incompressible: bool) -> jnp.ndarray:
    return ops.leray(field) if incompressible else field


def _norm_sq(grid: Grid, x: jnp.ndarray, cohort: bool) -> jnp.ndarray:
    return grid.norm_sq_per(x) if cohort else grid.norm_sq(x)


@telemetry.annotate("objective.evaluate")
def evaluate_objective(
    v: jnp.ndarray, prob: Problem, ops: SpectralOps, interp=None, plan: SLPlan | None = None
):
    """J(v) — one forward transport + one spectral regularization energy.

    Cohort inputs (``v (S,3,N..)``) return per-subject ``(S,)`` values."""
    cohort = v.ndim == 5
    fd = getattr(ops, "field_dtype", None)
    # Parseval lever: the regularization energy is a spectrum-side reduction
    # on the forward spectrum of v, and (compressible) shares that ONE
    # forward ride with div v for the plan — an Armijo trial pays no
    # dedicated forward/inverse pair for the energy (a2a-pinned by
    # tests/test_coalesce.py).
    with ops.batch() as sb:
        h_reg = sb.reg_energy(v, prob.beta)
        h_div = sb.div(v) if (plan is None and not prob.incompressible) else None
    if plan is None:
        # forward-only plan: line-search trials never transport backward
        plan = make_plan(
            v, prob.grid, ops, prob.n_t, prob.incompressible, interp, adjoint=False,
            divv=None if h_div is None else h_div.get(),
        )
    rho_series = semilag.transport_state(prob.rho_T, plan, interp, field_dtype=fd)
    rho1 = rho_series[-1]
    misfit = 0.5 * _norm_sq(prob.grid, rho1 - prob.rho_R, cohort)
    reg = h_reg.get()
    return misfit + reg, (misfit, reg, rho_series, plan)


@telemetry.annotate("objective.newton_state")
def newton_state(
    v: jnp.ndarray, prob: Problem, ops: SpectralOps, interp=None
) -> NewtonState:
    """Forward + adjoint solves, reduced gradient, and the matvec cache.

    Spectral stage A (everything that depends only on ``v``: ``div v``,
    ``beta Lap^2 v``, ``Lap v``) rides ONE coalesced transform pair; the
    cached gradient series ``grad rho(t_k)`` is one batched ride over all
    time slices; in incompressible mode ``P b`` costs one more.  Cohort
    inputs (``v (S,3,N..)``) share all of those rides across subjects.
    """
    cohort = v.ndim == 5
    fd = getattr(ops, "field_dtype", None)
    # ---- stage A: one ride pair for every v-only spectral op; the
    # regularization energy rides the same forward as a spectrum-side
    # Parseval reduction (no Lap v inverse — 3 fewer inverse fields)
    with ops.batch() as sb:
        h_divv = None if prob.incompressible else sb.div(v)
        h_regv = sb.reg_apply(v, prob.beta)
        h_reg_e = sb.reg_energy(v, prob.beta)
    plan = make_plan(
        v, prob.grid, ops, prob.n_t, prob.incompressible, interp,
        divv=None if h_divv is None else h_divv.get(),
    )
    rho_series = semilag.transport_state(prob.rho_T, plan, interp, field_dtype=fd)
    rho1 = rho_series[-1]

    # adjoint terminal condition lam(1) = rho_R - rho(1)   (eq. 3)
    lam_series = semilag.transport_adjoint(
        prob.rho_R - rho1, plan, interp, field_dtype=fd
    )

    # cache grad rho(t_k): ONE batched spectral gradient over all slices
    # (leading dims pass through both FFT backends; no vmap-of-shard_map);
    # the component axis lands at -4 in both layouts:
    # single (n_t+1, 3, N..), cohort (n_t+1, S, 3, N..)
    grad_rho_series = jnp.moveaxis(ops.grad(rho_series), 0, -4)

    b = semilag.time_integral_b(lam_series, grad_rho_series, plan.dt)
    # eq. (4): g = beta Lap^2 v + P b, with lam(1) = rho_R - rho(1);
    # beta Lap^2 v comes from stage A, so only P b can cost a transform.
    # (sanity: at v=0, <g,w> = <(rho_R-rho_T) grad rho_T, w> = dJ/deps.)
    g = h_regv.get() + _project(ops, b, prob.incompressible)

    misfit = 0.5 * _norm_sq(prob.grid, rho1 - prob.rho_R, cohort)
    reg = h_reg_e.get()
    return NewtonState(
        v=v,
        plan=plan,
        rho_series=rho_series,
        grad_rho_series=grad_rho_series,
        lam_series=lam_series,
        g=g,
        misfit=misfit,
        reg=reg,
        j_val=misfit + reg,
    )


@telemetry.annotate("objective.gn_hessian_matvec")
def gn_hessian_matvec(
    vtilde: jnp.ndarray,
    state: NewtonState,
    prob: Problem,
    ops: SpectralOps,
    interp=None,
) -> jnp.ndarray:
    """Gauss-Newton Hessian action, eq. (5) with the lambda terms dropped.

    Two transport solves (incremental state forward, incremental adjoint
    backward) — both interpolation-only thanks to the grad-rho cache — plus
    the elliptic assembly in ONE coalesced ride pair:
    ``beta Lap^2 vt + P bt`` forwards ``[vt, bt]`` together and inverts the
    3-component combine (incompressible); compressible mode adds ``bt`` in
    real space and transforms only ``vt``.  Cohort states apply S
    independent Hessians to a ``(S, 3, N..)`` stack in the same rides.
    """
    rho1_t = semilag.transport_inc_state(vtilde, state.grad_rho_series, state.plan, interp)
    lamt_series = semilag.transport_inc_adjoint(-rho1_t, state.plan, interp)
    bt = semilag.time_integral_b(lamt_series, state.grad_rho_series, state.plan.dt)
    # eq. (5e): H vt = beta Lap^2 vt + P bt, with lam~(1) = -rho~(1);
    # the data block is the Gauss-Newton (J^T J) term — PSD (tested).
    if prob.incompressible:
        return ops.reg_plus_project(vtilde, bt, prob.beta, True)
    return ops.reg_apply(vtilde, prob.beta) + bt


@telemetry.annotate("objective.full_hessian_matvec")
def full_hessian_matvec(
    vtilde: jnp.ndarray, state: NewtonState, prob: Problem, ops: SpectralOps, interp=None
) -> jnp.ndarray:
    """FULL Newton Hessian action — paper eq. (5) with every term.

    vs Gauss-Newton this keeps (i) the div(lam vt) source in the incremental
    adjoint (5c) and (ii) the lam grad(rho~) term in b~.  Costs one stored
    rho~(t) series plus ONE extra coalesced ride pair (the batched
    ``div(lam vt)`` series and the batched ``grad rho~(t)`` series share
    it).  Near the solution (lam -> 0) it coincides with GN (tested); away
    from it the data block may be indefinite, which is exactly why the
    paper defaults to GN (§IV-A3).  Single-subject only: cohort solves
    run the Gauss-Newton form (``GNConfig.gauss_newton=True``).
    """
    if vtilde.ndim == 5:
        raise NotImplementedError(
            "full Newton Hessian has no cohort path; use gauss_newton=True"
        )
    rho_t_series = semilag.transport_inc_state_series(
        vtilde, state.grad_rho_series, state.plan, interp
    )
    # div(lam(t_k) vt) for all k and grad rho~(t_k) for all k are mutually
    # independent diagonal ops: one coalesced ride pair for both series
    lam_vt = state.lam_series[:, None] * vtilde[None]  # (n_t+1, 3, N..)
    with ops.batch() as sb:
        h_div = sb.div(lam_vt)  # (n_t+1, N..)
        h_grad = sb.grad(rho_t_series)  # (3, n_t+1, N..)
    lamt_series = semilag.transport_inc_adjoint_newton(
        -rho_t_series[-1], state.lam_series, vtilde, state.plan, ops, interp,
        div_lam_vt=h_div.get(),
    )
    bt = semilag.time_integral_b(lamt_series, state.grad_rho_series, state.plan.dt)
    # second term of b~: int lam(t) grad rho~(t) dt
    grad_rho_t = jnp.swapaxes(h_grad.get(), 0, 1)  # (n_t+1, 3, N..)
    bt = bt + semilag.time_integral_b(state.lam_series, grad_rho_t, state.plan.dt)
    if prob.incompressible:
        return ops.reg_plus_project(vtilde, bt, prob.beta, True)
    return ops.reg_apply(vtilde, prob.beta) + bt

"""High-level registration API (the paper's end-to-end pipeline).

    result = register(rho_R, rho_T, RegistrationConfig(...))

Pipeline (paper §III): spectral Gaussian smoothing of the input images →
Gauss-Newton-Krylov solve for the stationary velocity v → deformation map
y1 = x + u from eq. (1) → diagnostics (residual, det(grad y1) range —
diffeomorphism check, Figure 7).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax.numpy as jnp

if TYPE_CHECKING:  # runtime import stays inside register(): core must not
    from repro.multilevel.hierarchy import MultilevelConfig  # depend on multilevel

from repro.core import gauss_newton as gn
from repro.core import semilag
from repro.core.grid import Grid, make_grid
from repro.core.planner import make_plan
from repro.core.spectral import SpectralOps


@dataclasses.dataclass(frozen=True)
class RegistrationConfig:
    solver: gn.GNConfig = dataclasses.field(default_factory=gn.GNConfig)
    presmooth: bool = True  # spectral Gaussian at grid bandwidth (paper §III-B1)
    # coarse-to-fine grid continuation (repro.multilevel); None = single level.
    # ``multilevel.solver`` supersedes ``solver`` when set.
    multilevel: "MultilevelConfig | None" = None


def register(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    config: RegistrationConfig | None = None,
    grid: Grid | None = None,
    verbose: bool = False,
    v0: jnp.ndarray | None = None,
):
    config = config or RegistrationConfig()
    grid = grid or make_grid(rho_R.shape)
    ops = SpectralOps(grid)

    if config.presmooth:
        rho_R = ops.smooth(rho_R)
        rho_T = ops.smooth(rho_T)

    if config.multilevel is not None:
        from repro import multilevel

        out = multilevel.solve(
            rho_R, rho_T, grid, config.multilevel, ops=ops, verbose=verbose, v0=v0
        )
        config = dataclasses.replace(config, solver=config.multilevel.solver)
    else:
        out = gn.solve(rho_R, rho_T, grid, config.solver, ops=ops, verbose=verbose, v0=v0)
    v = out["v"]

    # deformation map + diagnostics
    cfg = config.solver
    plan = make_plan(v, grid, ops, cfg.n_t, cfg.incompressible)
    u = semilag.deformation_displacement(v, plan)
    det = ops.jacobian_det(u)
    rho_series = semilag.transport_state(rho_T, plan)
    rho1 = rho_series[-1]

    res0 = float(jnp.linalg.norm((rho_T - rho_R).ravel()))
    res1 = float(jnp.linalg.norm((rho1 - rho_R).ravel()))
    out.update(
        {
            "displacement": u,
            "det_grad_y": det,
            "det_min": float(jnp.min(det)),
            "det_max": float(jnp.max(det)),
            "rho_deformed": rho1,
            "residual_rel": res1 / max(res0, 1e-30),
            "grid": grid,
        }
    )
    return out

"""High-level registration API (the paper's end-to-end pipeline).

    result = register(rho_R, rho_T, RegistrationConfig(...))

Pipeline (paper §III): spectral Gaussian smoothing of the input images →
Gauss-Newton-Krylov solve for the stationary velocity v → deformation map
y1 = x + u from eq. (1) → diagnostics (residual, det(grad y1) range —
diffeomorphism check, Figure 7).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax.numpy as jnp

if TYPE_CHECKING:  # runtime imports stay inside register(): core must not
    from repro.blocks.driver import BlocksConfig  # depend on blocks/multilevel
    from repro.multilevel.hierarchy import MultilevelConfig

from repro.core import gauss_newton as gn
from repro.core import semilag
from repro.core.grid import Grid, make_grid
from repro.core.planner import make_plan
from repro.core.spectral import SpectralOps


@dataclasses.dataclass(frozen=True)
class RegistrationConfig:
    solver: gn.GNConfig = dataclasses.field(default_factory=gn.GNConfig)
    presmooth: bool = True  # spectral Gaussian at grid bandwidth (paper §III-B1)
    # coarse-to-fine grid continuation (repro.multilevel); None = single level.
    # ``multilevel.solver`` supersedes ``solver`` when set.
    multilevel: "MultilevelConfig | None" = None
    # out-of-core blockwise map-reduce (repro.blocks); supersedes both of the
    # above when set — ``blocks.solver`` drives the per-block solves and the
    # final diagnostics.  Mutually exclusive with ``multilevel``.
    blocks: "BlocksConfig | None" = None


def register(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    config: RegistrationConfig | None = None,
    grid: Grid | None = None,
    verbose: bool = False,
    v0: jnp.ndarray | None = None,
    ops: SpectralOps | None = None,
    interp=None,
    ctx=None,
):
    """End-to-end registration.  ``ops``/``interp`` (or a ``DistContext``
    via ``ctx=``, shorthand for ``ops=ctx.ops, interp=ctx.interp``) select
    the execution backend for the SOLVE AND THE FINAL DIAGNOSTICS alike:
    earlier revisions rebuilt a local ``SpectralOps``/default interp for the
    diagnostics pass, so on a mesh the deformation map/residual were
    computed by a different (replicated) backend than the solve — wasteful
    and a silent layout break for sharded inputs (regression-pinned by
    ``tests/test_dist.py::test_register_on_mesh_matches_local``).

    Diagnostics report BOTH residuals: ``residual_rel`` measures the
    registration on the RAW input images (what a user of the deformation
    actually cares about), ``residual_rel_smoothed`` on the presmoothed
    pair the solver optimized — earlier revisions reported only the
    smoothed one under the raw name, overstating convergence whenever
    presmoothing removes significant high-frequency content.  Both
    transports ride one stacked semi-Lagrangian solve.
    """
    config = config or RegistrationConfig()
    grid = grid or make_grid(rho_R.shape)
    if ctx is not None:
        ops = ops or ctx.ops
        interp = interp or ctx.interp
    # resolve tuned perf knobs ONCE up front (idempotent — gn.solve would
    # re-consult to the same values) so the ops built here for presmoothing
    # and diagnostics carry the same field_dtype as the solve itself
    config = dataclasses.replace(config, solver=gn._tuned_cfg(config.solver, grid, ops))
    ops = ops or SpectralOps(grid, field_dtype=config.solver.field_dtype)

    rho_R_raw, rho_T_raw = rho_R, rho_T
    if config.presmooth:
        rho_R = ops.smooth(rho_R)
        rho_T = ops.smooth(rho_T)

    if config.blocks is not None:
        if config.multilevel is not None:
            raise ValueError("RegistrationConfig: blocks and multilevel are "
                             "mutually exclusive")
        if ctx is not None or interp is not None:
            raise NotImplementedError(
                "blockwise registration serves blocks on the local backend; "
                "mesh-served blocks are a ROADMAP follow-up"
            )
        if v0 is not None:
            raise NotImplementedError(
                "blocks.solve builds its own warm start from the coarse "
                "global solve; v0= is not supported with blocks="
            )
        from repro import blocks

        # the global pair was already presmoothed above (when enabled) —
        # blocks.solve must not smooth a second time
        out = blocks.solve(
            rho_R, rho_T, grid,
            dataclasses.replace(config.blocks, presmooth=False),
            ops=ops, verbose=verbose,
        )
        config = dataclasses.replace(config, solver=config.blocks.solver)
    elif config.multilevel is not None:
        from repro import multilevel

        out = multilevel.solve(
            rho_R, rho_T, grid, config.multilevel, ops=ops, ctx=ctx, v0=v0,
            verbose=verbose,
        )
        config = dataclasses.replace(config, solver=config.multilevel.solver)
    else:
        out = gn.solve(
            rho_R, rho_T, grid, config.solver, ops=ops, interp=interp,
            verbose=verbose, v0=v0,
        )
    v = out["v"]

    # deformation map + diagnostics, on the SAME backend as the solve
    cfg = config.solver
    plan = make_plan(v, grid, ops, cfg.n_t, cfg.incompressible, interp)
    u = semilag.deformation_displacement(v, plan, interp)
    det = ops.jacobian_det(u)
    # raw + smoothed templates share one stacked transport (identical when
    # presmoothing is off — skip the duplicate channel)
    if config.presmooth:
        rho1_pair = semilag.transport_state(
            jnp.stack([rho_T, rho_T_raw]), plan, interp
        )[-1]
        rho1, rho1_raw = rho1_pair[0], rho1_pair[1]
    else:
        rho1 = rho1_raw = semilag.transport_state(rho_T, plan, interp)[-1]

    def rel(r1, r0_img, rT_img):
        num = float(jnp.linalg.norm((r1 - r0_img).ravel()))
        den = float(jnp.linalg.norm((rT_img - r0_img).ravel()))
        return num / max(den, 1e-30)

    out.update(
        {
            "displacement": u,
            "det_grad_y": det,
            "det_min": float(jnp.min(det)),
            "det_max": float(jnp.max(det)),
            "rho_deformed": rho1,
            "residual_rel": rel(rho1_raw, rho_R_raw, rho_T_raw),
            "residual_rel_smoothed": rel(rho1, rho_R, rho_T),
            "grid": grid,
        }
    )
    return out

"""Spectral (Fourier) differential operators on the periodic grid.

Everything the paper applies in Fourier space (§III-B1): gradients,
divergence, Laplacian, biharmonic ``Lap^2`` (regularization), their inverses
(preconditioner ``(beta Lap^2)^{-1}``), the Leray projection
``P = I - grad Lap^{-1} div`` that eliminates the incompressibility
constraint, and the Gaussian smoothing applied to input images.

All operators are diagonal scalings of the FFT coefficients, so each costs a
forward transform, an O(N^3) scaling, and an inverse transform.  The
``FFTBackend`` abstraction lets the same operator definitions run on a single
device (``LocalFFT``: rfft) or on the production mesh
(``repro.dist.pencil_fft.PencilFFT``: the paper's pencil-decomposed parallel
FFT expressed with ``shard_map`` + ``lax.all_to_all``; wired up by
``repro.dist.context.DistContext`` as ``ctx.ops``).  The backends may use
different spectrum layouts (rfft vs full c2c) — operators only ever pair a
backend's ``fwd``/``inv`` with that same backend's ``k``/``kd``/``ksq``
grids, so the difference never leaks.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.grid import Grid


# --------------------------------------------------------------------------- #
# spectral truncation helpers (repro.multilevel transfer operators)
#
# Coarsening a periodic spectral discretization is exact mode selection: the
# coarse grid of size M carries the modes k in {0..ceil(M/2)-1, -M//2..-1}.
# ``mode_indices`` maps those modes to their positions in a length-N fine
# spectrum (numpy fft ordering), ``nyquist_mask`` zeroes the +-M/2 plane —
# the coarse Nyquist mode has no consistent counterpart on the fine grid
# (it aliases +M/2 and -M/2), so both restriction and prolongation drop it;
# that symmetric convention keeps the pair exactly adjoint under the grids'
# cell-volume-weighted inner products.
# --------------------------------------------------------------------------- #
def mode_indices(n_fine: int, n_coarse: int, rfft: bool = False) -> np.ndarray:
    """Positions of the coarse grid's modes inside a length-``n_fine`` spectrum.

    Returned in coarse-spectrum order, so ``fine_spec[idx]`` IS the coarse
    spectrum (up to normalization) and ``fine_spec[idx] = coarse_spec``
    zero-pads.  ``rfft=True`` addresses an rfft last axis (modes 0..n/2).
    """
    if n_coarse > n_fine:
        raise ValueError(f"coarse axis {n_coarse} exceeds fine axis {n_fine}")
    if rfft:
        return np.arange(n_coarse // 2 + 1)
    n_pos = n_coarse - n_coarse // 2  # modes 0 .. ceil(M/2)-1
    n_neg = n_coarse // 2  # modes -M//2 .. -1
    return np.concatenate([np.arange(n_pos), np.arange(n_fine - n_neg, n_fine)])


def nyquist_mask(n_fine: int, n_coarse: int, rfft: bool = False) -> np.ndarray:
    """1.0 per retained mode, 0.0 on the coarse Nyquist plane (even M < N)."""
    size = n_coarse // 2 + 1 if rfft else n_coarse
    mask = np.ones(size, np.float32)
    if n_coarse % 2 == 0 and n_coarse < n_fine:
        mask[n_coarse // 2] = 0.0
    return mask


class LocalFFT:
    """Single-device backend: real FFT over the last three axes."""

    def __init__(self, grid: Grid):
        self.grid = grid
        k1, k2, k3 = grid.k_grids(rfft_last=True)
        d1, d2, d3 = grid.k_deriv(rfft_last=True)
        f32 = np.float32
        self.k = (k1.astype(f32), k2.astype(f32), k3.astype(f32))
        self.kd = (d1.astype(f32), d2.astype(f32), d3.astype(f32))
        self.ksq = (k1**2 + k2**2 + k3**2).astype(f32)
        self.ksq_d = (d1**2 + d2**2 + d3**2).astype(f32)

    def fwd(self, u: jnp.ndarray) -> jnp.ndarray:
        return jnp.fft.rfftn(u, axes=(-3, -2, -1))

    def inv(self, spec: jnp.ndarray) -> jnp.ndarray:
        n = self.grid.shape
        return jnp.fft.irfftn(spec, s=n, axes=(-3, -2, -1)).astype(self.grid.dtype)


class SpectralOps:
    """Paper's spectral operator toolbox over a pluggable FFT backend."""

    def __init__(self, grid: Grid, backend=None):
        self.grid = grid
        self.fft = backend if backend is not None else LocalFFT(grid)

    def _inv_real(self, spec: jnp.ndarray) -> jnp.ndarray:
        """Inverse transform of real-destined spectra; uses the backend's
        complex-packed inverse (PencilFFT(packed=True)) when available —
        halves inverse-side all-to-all bytes (EXPERIMENTS §Perf)."""
        if getattr(self.fft, "packed", False) and spec.ndim > 3:
            lead = spec.shape[:-3]
            flat = spec.reshape((-1,) + spec.shape[-3:])
            out = self.fft.inv_packed(flat)
            return out.reshape(lead + out.shape[-3:])
        return self.fft.inv(spec)

    def _fwd_real(self, u: jnp.ndarray) -> jnp.ndarray:
        """Forward transform of REAL fields; pairs of a batched stack ride
        the backend's packed forward (``PencilFFT.fwd_packed``) when
        available — the forward-side mirror of ``_inv_real``, halving the
        forward all-to-all bytes of gradient/Leray/fused-elliptic stacks."""
        if getattr(self.fft, "packed", False) and u.ndim > 3:
            lead = u.shape[:-3]
            flat = u.reshape((-1,) + u.shape[-3:])
            out = self.fft.fwd_packed(flat)
            return out.reshape(lead + out.shape[-3:])
        return self.fft.fwd(u)

    # ------------------------------------------------------------------ #
    # first-order operators (Nyquist-zeroed wavenumbers, skew-adjoint)
    # ------------------------------------------------------------------ #
    def grad(self, f: jnp.ndarray) -> jnp.ndarray:
        """grad f: (..., N1,N2,N3) -> (3, ..., N1,N2,N3).

        One forward FFT, three diagonal scalings, a *batched* inverse FFT —
        the paper's §III-C1 optimization to avoid three full 3-D round trips.
        """
        spec = self._fwd_real(f)
        stacked = jnp.stack([1j * k * spec for k in self.fft.kd], axis=0)
        return self._inv_real(stacked)

    def div(self, v: jnp.ndarray) -> jnp.ndarray:
        """div v: (3, N1,N2,N3) -> (N1,N2,N3)."""
        spec = self._fwd_real(v)  # batched over the component axis
        out = sum(1j * k * spec[i] for i, k in enumerate(self.fft.kd))
        return self.fft.inv(out)

    # ------------------------------------------------------------------ #
    # even-order elliptic operators (full wavenumbers)
    # ------------------------------------------------------------------ #
    def laplacian(self, f: jnp.ndarray) -> jnp.ndarray:
        return self.fft.inv(-self.fft.ksq * self._fwd_real(f))

    def biharmonic(self, f: jnp.ndarray) -> jnp.ndarray:
        return self.fft.inv(self.fft.ksq**2 * self._fwd_real(f))

    def inv_laplacian(self, f: jnp.ndarray) -> jnp.ndarray:
        """Lap^{-1} with the zero mean mode mapped to zero."""
        scale = jnp.where(self.fft.ksq > 0, -1.0 / jnp.maximum(self.fft.ksq, 1e-30), 0.0)
        return self.fft.inv(scale * self._fwd_real(f))

    def inv_biharmonic(self, f: jnp.ndarray, zero_mode: float = 0.0) -> jnp.ndarray:
        ksq = self.fft.ksq
        scale = jnp.where(ksq > 0, 1.0 / jnp.maximum(ksq**2, 1e-30), zero_mode)
        return self.fft.inv(scale * self._fwd_real(f))

    # ------------------------------------------------------------------ #
    # Leray projection: P = I - grad Lap^{-1} div  (paper eq. (4))
    # ------------------------------------------------------------------ #
    def leray(self, v: jnp.ndarray) -> jnp.ndarray:
        """Project a velocity onto the divergence-free subspace.

        In Fourier space ``P_ij = delta_ij - k_i k_j / |k|^2``.  We use the
        Nyquist-zeroed ``k`` in both numerator and denominator so that
        ``P`` is an exact projection (P^2 = P) and ``div(P v) = 0`` exactly
        in the discrete spectral sense.  The k=0 (mean-velocity) mode is
        untouched: a constant field is divergence free.
        """
        spec = self._fwd_real(v)  # (3, ...)
        kd = self.fft.kd
        ksq = self.fft.ksq_d
        kdotv = sum(k * spec[i] for i, k in enumerate(kd))
        inv = jnp.where(ksq > 0, 1.0 / jnp.maximum(ksq, 1e-30), 0.0)
        proj = jnp.stack([spec[i] - kd[i] * inv * kdotv for i in range(3)], axis=0)
        return self.fft.inv(proj)

    # ------------------------------------------------------------------ #
    # regularization operator A = beta Lap^2 and spectral preconditioner
    # ------------------------------------------------------------------ #
    def reg_apply(self, v: jnp.ndarray, beta) -> jnp.ndarray:
        """beta * Lap^2 v  (H^2 seminorm regularization, paper eq. (2a))."""
        return self.fft.inv(beta * self.fft.ksq**2 * self._fwd_real(v))

    def precond_apply(self, r: jnp.ndarray, beta) -> jnp.ndarray:
        """(beta Lap^2)^{-1} r — the paper's spectral preconditioner.

        Singular at k=0; the mean mode is passed through unchanged (there
        the Hessian is dominated by the data term, which is O(1)).
        """
        ksq = self.fft.ksq
        scale = jnp.where(ksq > 0, 1.0 / jnp.maximum(beta * ksq**2, 1e-30), 1.0)
        return self.fft.inv(scale * self._fwd_real(r))

    # ------------------------------------------------------------------ #
    # fused elliptic ops (beyond-paper; EXPERIMENTS §Perf)
    #
    # The paper applies A = beta Lap^2 and the Leray projection as separate
    # spectral round trips (12 c2c-equivalent 1-D transform batches per
    # gradient/Hessian assembly).  Both are diagonal (resp. 3x3-block
    # diagonal) in k-space, so one batched forward over [a, b], a k-space
    # combine, and ONE batched inverse computes  beta Lap^2 a + P b  in 9 —
    # a 25% cut of the elliptic FFT count; the fused preconditioner
    # P (beta Lap^2)^{-1} halves its round trips (12 -> 6).
    # ------------------------------------------------------------------ #
    def _leray_spec(self, spec):
        """Apply P in k-space to a (3, ...) spectrum."""
        kd = self.fft.kd
        ksq = self.fft.ksq_d
        kdotv = sum(k * spec[i] for i, k in enumerate(kd))
        inv = jnp.where(ksq > 0, 1.0 / jnp.maximum(ksq, 1e-30), 0.0)
        return jnp.stack([spec[i] - kd[i] * inv * kdotv for i in range(3)], axis=0)

    def reg_plus_project(self, a: jnp.ndarray, b: jnp.ndarray, beta, incompressible: bool):
        """beta Lap^2 a + P b  (P = I when not incompressible) — one batched
        forward over the 6 stacked components, one batched inverse over 3."""
        spec = self._fwd_real(jnp.stack([a, b], axis=0))  # (2, 3, k...)
        sa, sb = spec[0], spec[1]
        if incompressible:
            sb = self._leray_spec(sb)
        return self._inv_real(beta * self.fft.ksq**2 * sa + sb)

    def precond_project(self, r: jnp.ndarray, beta, incompressible: bool) -> jnp.ndarray:
        """P (beta Lap^2)^{-1} r in a single spectral round trip."""
        ksq = self.fft.ksq
        scale = jnp.where(ksq > 0, 1.0 / jnp.maximum(beta * ksq**2, 1e-30), 1.0)
        spec = scale * self._fwd_real(r)
        if incompressible:
            spec = self._leray_spec(spec)
        return self._inv_real(spec)

    # ------------------------------------------------------------------ #
    # image preprocessing (paper §III-B1)
    # ------------------------------------------------------------------ #
    def smooth(self, f: jnp.ndarray, sigma=None) -> jnp.ndarray:
        """Gaussian spectral filter; default bandwidth = one grid cell."""
        if sigma is None:
            sigma = self.grid.spacing
        if np.isscalar(sigma):
            sigma = (sigma, sigma, sigma)
        k1, k2, k3 = self.fft.k
        expo = -0.5 * ((k1 * sigma[0]) ** 2 + (k2 * sigma[1]) ** 2 + (k3 * sigma[2]) ** 2)
        return self.fft.inv(jnp.exp(expo) * self._fwd_real(f))

    # ------------------------------------------------------------------ #
    # diagnostics
    # ------------------------------------------------------------------ #
    def reg_energy(self, v: jnp.ndarray, beta) -> jnp.ndarray:
        """beta/2 ||Lap v||^2 via real-space quadrature (mesh independent)."""
        lap_v = self.fft.inv(-self.fft.ksq * self._fwd_real(v))
        return 0.5 * beta * self.grid.norm_sq(lap_v)

    def jacobian_det(self, disp: jnp.ndarray) -> jnp.ndarray:
        """det(grad y) for y = x + u given displacement u (3,N1,N2,N3).

        grad u is computed spectrally; det(I + grad u) pointwise.
        """
        g = jnp.swapaxes(self.grad(disp), 0, 1)  # g[i,j] = d_j u_i, one batched FFT
        a = g + jnp.eye(3, dtype=g.dtype)[:, :, None, None, None]
        det = (
            a[0, 0] * (a[1, 1] * a[2, 2] - a[1, 2] * a[2, 1])
            - a[0, 1] * (a[1, 0] * a[2, 2] - a[1, 2] * a[2, 0])
            + a[0, 2] * (a[1, 0] * a[2, 1] - a[1, 1] * a[2, 0])
        )
        return det

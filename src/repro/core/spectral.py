"""Spectral (Fourier) differential operators on the periodic grid.

Everything the paper applies in Fourier space (§III-B1): gradients,
divergence, Laplacian, biharmonic ``Lap^2`` (regularization), their inverses
(preconditioner ``(beta Lap^2)^{-1}``), the Leray projection
``P = I - grad Lap^{-1} div`` that eliminates the incompressibility
constraint, and the Gaussian smoothing applied to input images.

All operators are diagonal scalings of the FFT coefficients, so each costs a
forward transform, an O(N^3) scaling, and an inverse transform.  The
``FFTBackend`` abstraction lets the same operator definitions run on a single
device (``LocalFFT``: rfft) or on the production mesh
(``repro.dist.pencil_fft.PencilFFT``: the paper's pencil-decomposed parallel
FFT expressed with ``shard_map`` + ``lax.all_to_all``; wired up by
``repro.dist.context.DistContext`` as ``ctx.ops``).  The backends may use
different spectrum layouts (rfft vs full c2c) — operators only ever pair a
backend's ``fwd``/``inv`` with that same backend's ``k``/``kd``/``ksq``
grids, so the difference never leaks.

**Transform coalescing** (``SpectralOps.batch()`` / ``SpectralBatch``): on
the mesh every forward/inverse ride is a latency-bound pair of all-to-all
transposes, and one Newton iteration used to issue dozens of them — one
pair per operator call, strictly serialized.  Independent operator calls
are diagonal in k-space, so they compose into ONE big-batch forward over
the (deduplicated) stacked inputs and ONE big-batch inverse over the
stacked outputs:

    with ops.batch() as sb:
        divv = sb.div(v)          # handles resolve after the ride
        regv = sb.reg_apply(v, beta)
        lapv = sb.laplacian(v)
    g = regv.get() + ...          # all three shared ONE fwd + ONE inv

Inputs are deduplicated by identity (``div v``, ``reg v``, ``lap v`` above
transform ``v`` once), and both rides go through the backend's packed
transforms when available — the FFT-side mirror of the plan-once/apply-many
interpolation batching (EXPERIMENTS §Perf).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.grid import Grid


# --------------------------------------------------------------------------- #
# spectral truncation helpers (repro.multilevel transfer operators)
#
# Coarsening a periodic spectral discretization is exact mode selection: the
# coarse grid of size M carries the modes k in {0..ceil(M/2)-1, -M//2..-1}.
# ``mode_indices`` maps those modes to their positions in a length-N fine
# spectrum (numpy fft ordering), ``nyquist_mask`` zeroes the +-M/2 plane —
# the coarse Nyquist mode has no consistent counterpart on the fine grid
# (it aliases +M/2 and -M/2), so both restriction and prolongation drop it;
# that symmetric convention keeps the pair exactly adjoint under the grids'
# cell-volume-weighted inner products.
# --------------------------------------------------------------------------- #
def mode_indices(n_fine: int, n_coarse: int, rfft: bool = False) -> np.ndarray:
    """Positions of the coarse grid's modes inside a length-``n_fine`` spectrum.

    Returned in coarse-spectrum order, so ``fine_spec[idx]`` IS the coarse
    spectrum (up to normalization) and ``fine_spec[idx] = coarse_spec``
    zero-pads.  ``rfft=True`` addresses an rfft last axis (modes 0..n/2).
    The index set is two contiguous runs (head of positive modes, tail of
    negative modes) — ``repro.multilevel.transfer`` exploits that to express
    truncation/zero-padding as slices+concat instead of gather/scatter.
    """
    if n_coarse > n_fine:
        raise ValueError(f"coarse axis {n_coarse} exceeds fine axis {n_fine}")
    if rfft:
        return np.arange(n_coarse // 2 + 1)
    n_pos = n_coarse - n_coarse // 2  # modes 0 .. ceil(M/2)-1
    n_neg = n_coarse // 2  # modes -M//2 .. -1
    return np.concatenate([np.arange(n_pos), np.arange(n_fine - n_neg, n_fine)])


def nyquist_mask(n_fine: int, n_coarse: int, rfft: bool = False) -> np.ndarray:
    """1.0 per retained mode, 0.0 on the coarse Nyquist plane (even M < N)."""
    size = n_coarse // 2 + 1 if rfft else n_coarse
    mask = np.ones(size, np.float32)
    if n_coarse % 2 == 0 and n_coarse < n_fine:
        mask[n_coarse // 2] = 0.0
    return mask


class LocalFFT:
    """Single-device backend: real FFT over the last three axes."""

    def __init__(self, grid: Grid):
        self.grid = grid
        k1, k2, k3 = grid.k_grids(rfft_last=True)
        d1, d2, d3 = grid.k_deriv(rfft_last=True)
        f32 = np.float32
        self.k = (k1.astype(f32), k2.astype(f32), k3.astype(f32))
        self.kd = (d1.astype(f32), d2.astype(f32), d3.astype(f32))
        self.ksq = (k1**2 + k2**2 + k3**2).astype(f32)
        self.ksq_d = (d1**2 + d2**2 + d3**2).astype(f32)
        # Parseval weight of each stored rfft mode: the half-spectrum drops
        # the conjugate partner of every 0 < k3 < N3/2 mode, so those count
        # twice in sum_k |U(k)|^2; k3 = 0 and the (even-N3) Nyquist plane
        # are self-conjugate and count once.
        n3 = grid.shape[2]
        w = np.full(n3 // 2 + 1, 2.0, f32)
        w[0] = 1.0
        if n3 % 2 == 0:
            w[-1] = 1.0
        self.spec_weight = w.reshape(1, 1, -1)

    def fwd(self, u: jnp.ndarray) -> jnp.ndarray:
        if u.dtype not in (jnp.float32, jnp.float64):
            u = u.astype(jnp.float32)  # rfft rejects bf16/f16 payloads
        return jnp.fft.rfftn(u, axes=(-3, -2, -1))

    def inv(self, spec: jnp.ndarray) -> jnp.ndarray:
        n = self.grid.shape
        return jnp.fft.irfftn(spec, s=n, axes=(-3, -2, -1)).astype(self.grid.dtype)


class SpectralRef:
    """Lazy handle for one coalesced op's output (see ``SpectralBatch``)."""

    __slots__ = ("_batch", "_idx")

    def __init__(self, batch: "SpectralBatch", idx: int):
        self._batch = batch
        self._idx = idx

    def get(self) -> jnp.ndarray:
        """Resolve the result (runs the batch's single ride pair if needed)."""
        self._batch.run()
        return self._batch._results[self._idx]


class SpectralBatch:
    """Coalesce independent spectral operator calls into ONE forward and ONE
    inverse transform ride.

    Each enqueued op records (input fields, a k-space transfer function,
    output layout); ``run()`` — triggered by the context-manager exit or the
    first ``SpectralRef.get()`` — concatenates the deduplicated inputs,
    performs one batched real forward (packed on ``PencilFFT``), applies
    every op's diagonal k-space math, and inverts the stacked real-destined
    outputs in one batched ride.  On a pencil mesh this turns K serialized
    all-to-all pairs into 1 per direction; locally it amortizes rfft plan
    overhead across the stack.  Results are exactly the packed-transform
    composition of the eager operators (parity pinned in
    ``tests/test_spectral.py`` / the mesh legs of ``tests/test_coalesce.py``).
    """

    def __init__(self, ops: "SpectralOps"):
        self.ops = ops
        self._in_arrays: list = []  # flat (m, N1, N2, N3) blocks
        self._in_slots: dict = {}  # id(array) -> (start, array)
        self._n_in = 0
        self._jobs: list = []  # (in_slices, kfn, out_lead)
        self._results: list | None = None

    # -- plumbing ----------------------------------------------------------
    def _input(self, u: jnp.ndarray):
        """Register a real input field; dedup by identity. Returns (start, lead)."""
        if self._results is not None:
            raise RuntimeError("SpectralBatch already ran; start a new batch")
        space = u.shape[-3:]
        if space != tuple(self.ops.grid.shape):
            raise ValueError(f"field shape {u.shape} not on grid {self.ops.grid.shape}")
        lead = u.shape[:-3]
        slot = self._in_slots.get(id(u))
        if slot is not None and slot[1] is u:
            return slot[0], lead
        m = int(np.prod(lead)) if lead else 1
        start = self._n_in
        self._in_arrays.append(u.reshape((m,) + space))
        self._n_in += m
        self._in_slots[id(u)] = (start, u)
        return start, lead

    def _job(self, inputs, kfn, out_lead, reduce: bool = False) -> SpectralRef:
        """Enqueue one op: ``kfn(*specs) -> out_lead + kshape`` spectrum.

        ``reduce=True`` marks a *spectrum-side reduction*: ``kfn`` returns
        the job's final value directly (e.g. a Parseval norm) and the job
        contributes nothing to the inverse ride — a batch of only reduction
        jobs costs ONE forward and ZERO inverse transforms.
        """
        slots = [self._input(u) for u in inputs]
        self._jobs.append((slots, kfn, tuple(out_lead), reduce))
        return SpectralRef(self, len(self._jobs) - 1)

    def run(self) -> None:
        """Execute the coalesced ride pair (idempotent)."""
        if self._results is not None:
            return
        if not self._jobs:
            self._results = []
            return
        self._results = [None] * len(self._jobs)
        ins = (
            self._in_arrays[0]
            if len(self._in_arrays) == 1
            else jnp.concatenate(self._in_arrays, axis=0)
        )
        specs = self.ops.fwd_real(ins)  # (B_in,) + kshape, one packed ride
        kshape = specs.shape[1:]
        out_blocks, inv_slots = [], []
        for idx, (slots, kfn, out_lead, reduce) in enumerate(self._jobs):
            args = [
                specs[start : start + max(int(np.prod(lead)), 1)].reshape(lead + kshape)
                for start, lead in slots
            ]
            out = kfn(*args)
            if reduce:  # already real-valued; skips the inverse ride
                self._results[idx] = out
            else:
                out_blocks.append(out.reshape((-1,) + kshape))
                inv_slots.append((idx, out_lead))
        if out_blocks:
            allspec = (
                out_blocks[0]
                if len(out_blocks) == 1
                else jnp.concatenate(out_blocks, axis=0)
            )
            real = self.ops.inv_real(allspec)  # one packed ride
            pos = 0
            for idx, out_lead in inv_slots:
                m = int(np.prod(out_lead)) if out_lead else 1
                self._results[idx] = real[pos : pos + m].reshape(
                    out_lead + real.shape[1:]
                )
                pos += m
        # drop input/job references: in eager use a retained handle must not
        # pin the stacked input buffers (the results are already extracted)
        self._in_arrays.clear()
        self._in_slots.clear()
        self._jobs.clear()

    def __enter__(self) -> "SpectralBatch":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.run()

    # -- coalesced operators (same semantics as the eager SpectralOps) -----
    def grad(self, f: jnp.ndarray) -> SpectralRef:
        return self._job([f], self.ops._grad_spec, (3,) + f.shape[:-3])

    def div(self, v: jnp.ndarray) -> SpectralRef:
        return self._job([v], self.ops._div_spec, v.shape[:-4])

    def laplacian(self, f: jnp.ndarray) -> SpectralRef:
        return self._job([f], lambda s: -self.ops.fft.ksq * s, f.shape[:-3])

    def biharmonic(self, f: jnp.ndarray) -> SpectralRef:
        return self._job([f], lambda s: self.ops.fft.ksq**2 * s, f.shape[:-3])

    def inv_laplacian(self, f: jnp.ndarray) -> SpectralRef:
        return self._job([f], lambda s: self.ops._inv_lap_scale() * s, f.shape[:-3])

    def inv_biharmonic(self, f: jnp.ndarray, zero_mode: float = 0.0) -> SpectralRef:
        return self._job(
            [f], lambda s: self.ops._inv_bihar_scale(zero_mode) * s, f.shape[:-3]
        )

    def reg_apply(self, v: jnp.ndarray, beta) -> SpectralRef:
        return self._job([v], lambda s: self.ops._reg_scale(beta) * s, v.shape[:-3])

    def precond_apply(self, r: jnp.ndarray, beta) -> SpectralRef:
        return self._job([r], lambda s: self.ops._precond_scale(beta) * s, r.shape[:-3])

    def leray(self, v: jnp.ndarray) -> SpectralRef:
        return self._job([v], self.ops._leray_spec, v.shape[:-3])

    def precond_project(self, r: jnp.ndarray, beta, incompressible: bool) -> SpectralRef:
        def kfn(s):
            s = self.ops._precond_scale(beta) * s
            return self.ops._leray_spec(s) if incompressible else s

        return self._job([r], kfn, r.shape[:-3])

    def reg_plus_project(
        self, a: jnp.ndarray, b: jnp.ndarray, beta, incompressible: bool
    ) -> SpectralRef:
        """beta Lap^2 a + P b (P = I when not incompressible): the Newton
        gradient/Hessian assembly, 6 fields forward -> 3 back."""

        def kfn(sa, sb):
            if incompressible:
                sb = self.ops._leray_spec(sb)
            return self.ops._reg_scale(beta) * sa + sb

        return self._job([a, b], kfn, a.shape[:-3])

    def smooth(self, f: jnp.ndarray, sigma=None) -> SpectralRef:
        scale = self.ops._smooth_scale(sigma)
        return self._job([f], lambda s: scale * s, f.shape[:-3])

    def reg_energy(self, v: jnp.ndarray, beta) -> SpectralRef:
        """beta/2 ||Lap v||^2 as a spectrum-side Parseval reduction.

        Shares the batch's one forward ride with every other job on ``v``
        and joins NO inverse ride — the Armijo-trial lever: a line-search
        objective evaluation reads the energy straight off the forward
        spectrum instead of paying a dedicated forward/inverse pair
        (ride-count pinned by ``tests/test_coalesce.py``).
        """
        return self._job(
            [v],
            lambda s: self.ops._reg_energy_spec(s, beta),
            v.shape[:-4],
            reduce=True,
        )


class SpectralOps:
    """Paper's spectral operator toolbox over a pluggable FFT backend.

    ``field_dtype`` (e.g. ``jnp.bfloat16``) selects the storage dtype of
    every real-space field an operator RETURNS — the transport/FFT field
    path of the mixed-precision knob (`repro.autotune`).  The transforms
    and all k-space scalings stay complex64/f32 (inputs are upcast on the
    forward side), so only the stored fields lose precision; critical
    accumulations (inner products, time quadrature, the PCG recursion)
    remain >= f32 by construction elsewhere.
    """

    def __init__(self, grid: Grid, backend=None, field_dtype=None):
        self.grid = grid
        self.fft = backend if backend is not None else LocalFFT(grid)
        self.field_dtype = None if field_dtype is None else jnp.dtype(field_dtype)

    def batch(self) -> SpectralBatch:
        """Open a transform-coalescing batch (see ``SpectralBatch``)."""
        return SpectralBatch(self)

    def inv_real(self, spec: jnp.ndarray) -> jnp.ndarray:
        """Inverse transform of real-destined spectra; uses the backend's
        complex-packed inverse (PencilFFT(packed=True)) when available —
        halves inverse-side all-to-all bytes (EXPERIMENTS §Perf)."""
        if getattr(self.fft, "packed", False) and spec.ndim > 3:
            lead = spec.shape[:-3]
            flat = spec.reshape((-1,) + spec.shape[-3:])
            out = self.fft.inv_packed(flat)
            out = out.reshape(lead + out.shape[-3:])
        else:
            out = self.fft.inv(spec)
        if self.field_dtype is not None:
            out = out.astype(self.field_dtype)
        return out

    def fwd_real(self, u: jnp.ndarray) -> jnp.ndarray:
        """Forward transform of REAL fields; pairs of a batched stack ride
        the backend's packed forward (``PencilFFT.fwd_packed``) when
        available — the forward-side mirror of ``inv_real``, halving the
        forward all-to-all bytes of gradient/Leray/coalesced-batch stacks."""
        if getattr(self.fft, "packed", False) and u.ndim > 3:
            lead = u.shape[:-3]
            flat = u.reshape((-1,) + u.shape[-3:])
            out = self.fft.fwd_packed(flat)
            return out.reshape(lead + out.shape[-3:])
        return self.fft.fwd(u)

    # backwards-compatible aliases (pre-coalescing internal names)
    _inv_real = inv_real
    _fwd_real = fwd_real

    # ------------------------------------------------------------------ #
    # k-space transfer functions, shared by the eager operators below and
    # the coalesced SpectralBatch ops above.  Underscored but package-
    # internal shared API: the multilevel layers compose with them too
    # (precond.py applies _leray_spec/_precond_scale as k-space multipliers
    # inside the V-cycle's spectrum-level split, transfer.smooth_restrict
    # rides _smooth_scale on its own forward) — change signatures here and
    # grep repro/multilevel along with this file.
    # ------------------------------------------------------------------ #
    def _grad_spec(self, spec: jnp.ndarray) -> jnp.ndarray:
        """(...,) spectrum -> (3, ...) gradient spectrum (Nyquist-zeroed)."""
        return jnp.stack([1j * k * spec for k in self.fft.kd], axis=0)

    def _div_spec(self, spec: jnp.ndarray) -> jnp.ndarray:
        """(..., 3, k-shape) spectrum -> (..., k-shape) divergence spectrum."""
        return sum(1j * k * spec[..., i, :, :, :] for i, k in enumerate(self.fft.kd))

    def _leray_spec(self, spec: jnp.ndarray) -> jnp.ndarray:
        """Apply P = I - k k^T/|k|^2 in k-space over the ``-4`` component
        axis of a (..., 3, k-shape) spectrum ((3, ...) single, (S, 3, ...)
        cohort — leading dims batch)."""
        kd = self.fft.kd
        ksq = self.fft.ksq_d
        comp = [spec[..., i, :, :, :] for i in range(3)]
        kdotv = sum(k * comp[i] for i, k in enumerate(kd))
        inv = jnp.where(ksq > 0, 1.0 / jnp.maximum(ksq, 1e-30), 0.0)
        return jnp.stack([comp[i] - kd[i] * inv * kdotv for i in range(3)], axis=-4)

    def _inv_lap_scale(self) -> jnp.ndarray:
        ksq = self.fft.ksq
        return jnp.where(ksq > 0, -1.0 / jnp.maximum(ksq, 1e-30), 0.0)

    def _inv_bihar_scale(self, zero_mode: float) -> jnp.ndarray:
        ksq = self.fft.ksq
        return jnp.where(ksq > 0, 1.0 / jnp.maximum(ksq**2, 1e-30), zero_mode)

    def _reg_scale(self, beta) -> jnp.ndarray:
        """Diagonal of A = beta Lap^2."""
        return beta * self.fft.ksq**2

    def _precond_scale(self, beta) -> jnp.ndarray:
        ksq = self.fft.ksq
        return jnp.where(ksq > 0, 1.0 / jnp.maximum(beta * ksq**2, 1e-30), 1.0)

    def _smooth_scale(self, sigma=None) -> jnp.ndarray:
        if sigma is None:
            sigma = self.grid.spacing
        if np.isscalar(sigma):
            sigma = (sigma, sigma, sigma)
        k1, k2, k3 = self.fft.k
        expo = -0.5 * ((k1 * sigma[0]) ** 2 + (k2 * sigma[1]) ** 2 + (k3 * sigma[2]) ** 2)
        return jnp.exp(expo)

    def _reg_energy_spec(self, spec: jnp.ndarray, beta) -> jnp.ndarray:
        """beta/2 ||Lap v||^2 read off the FORWARD spectrum of ``v`` (Parseval).

        For the unnormalized DFT, ``h^3 sum_x |u|^2 = h^3/N sum_k |U(k)|^2``;
        a half-spectrum backend (``LocalFFT``: rfft last axis) supplies
        ``spec_weight`` to double the modes whose conjugate partners it
        drops.  Equals the real-space quadrature of ``inv(-ksq * spec)`` to
        roundoff — without the inverse transform (the spectrum-side lever
        used by ``SpectralBatch.reg_energy``).  Reduces the component +
        space axes, so a cohort ``(S, 3, k..)`` spectrum yields ``(S,)``.
        """
        mag = spec.real**2 + spec.imag**2  # f32 accumulation from complex64
        w = getattr(self.fft, "spec_weight", None)
        if w is not None:
            mag = mag * w
        e = jnp.sum(self.fft.ksq**2 * mag, axis=(-4, -3, -2, -1))
        scale = self.grid.cell_volume / self.grid.num_points
        return 0.5 * beta * scale * e

    # ------------------------------------------------------------------ #
    # first-order operators (Nyquist-zeroed wavenumbers, skew-adjoint)
    # ------------------------------------------------------------------ #
    def grad(self, f: jnp.ndarray) -> jnp.ndarray:
        """grad f: (..., N1,N2,N3) -> (3, ..., N1,N2,N3).

        One forward FFT, three diagonal scalings, a *batched* inverse FFT —
        the paper's §III-C1 optimization to avoid three full 3-D round trips.
        """
        return self.inv_real(self._grad_spec(self.fwd_real(f)))

    def div(self, v: jnp.ndarray) -> jnp.ndarray:
        """div v: (..., 3, N1,N2,N3) -> (..., N1,N2,N3) (leading dims batch)."""
        spec = self.fwd_real(v)  # batched over the component axis
        return self.inv_real(self._div_spec(spec))

    # ------------------------------------------------------------------ #
    # even-order elliptic operators (full wavenumbers)
    # ------------------------------------------------------------------ #
    def laplacian(self, f: jnp.ndarray) -> jnp.ndarray:
        return self.inv_real(-self.fft.ksq * self.fwd_real(f))

    def biharmonic(self, f: jnp.ndarray) -> jnp.ndarray:
        return self.inv_real(self.fft.ksq**2 * self.fwd_real(f))

    def inv_laplacian(self, f: jnp.ndarray) -> jnp.ndarray:
        """Lap^{-1} with the zero mean mode mapped to zero."""
        return self.inv_real(self._inv_lap_scale() * self.fwd_real(f))

    def inv_biharmonic(self, f: jnp.ndarray, zero_mode: float = 0.0) -> jnp.ndarray:
        return self.inv_real(self._inv_bihar_scale(zero_mode) * self.fwd_real(f))

    # ------------------------------------------------------------------ #
    # Leray projection: P = I - grad Lap^{-1} div  (paper eq. (4))
    # ------------------------------------------------------------------ #
    def leray(self, v: jnp.ndarray) -> jnp.ndarray:
        """Project a velocity onto the divergence-free subspace.

        In Fourier space ``P_ij = delta_ij - k_i k_j / |k|^2``.  We use the
        Nyquist-zeroed ``k`` in both numerator and denominator so that
        ``P`` is an exact projection (P^2 = P) and ``div(P v) = 0`` exactly
        in the discrete spectral sense.  The k=0 (mean-velocity) mode is
        untouched: a constant field is divergence free.
        """
        return self.inv_real(self._leray_spec(self.fwd_real(v)))

    # ------------------------------------------------------------------ #
    # regularization operator A = beta Lap^2 and spectral preconditioner
    # ------------------------------------------------------------------ #
    def reg_apply(self, v: jnp.ndarray, beta) -> jnp.ndarray:
        """beta * Lap^2 v  (H^2 seminorm regularization, paper eq. (2a))."""
        return self.inv_real(self._reg_scale(beta) * self.fwd_real(v))

    def precond_apply(self, r: jnp.ndarray, beta) -> jnp.ndarray:
        """(beta Lap^2)^{-1} r — the paper's spectral preconditioner.

        Singular at k=0; the mean mode is passed through unchanged (there
        the Hessian is dominated by the data term, which is O(1)).
        """
        return self.inv_real(self._precond_scale(beta) * self.fwd_real(r))

    # ------------------------------------------------------------------ #
    # fused elliptic ops (beyond-paper; EXPERIMENTS §Perf)
    #
    # The paper applies A = beta Lap^2 and the Leray projection as separate
    # spectral round trips.  Both are diagonal (resp. 3x3-block diagonal)
    # in k-space, so one batched forward over [a, b], a k-space combine,
    # and ONE batched inverse computes  beta Lap^2 a + P b  — the
    # single-ride-pair form the coalesced Newton hot path uses
    # (core/objective.py); the fused preconditioner P (beta Lap^2)^{-1}
    # likewise halves its round trips.
    # ------------------------------------------------------------------ #
    def reg_plus_project(self, a: jnp.ndarray, b: jnp.ndarray, beta, incompressible: bool):
        """beta Lap^2 a + P b  (P = I when not incompressible) — one batched
        forward over the 6 stacked components, one batched inverse over 3."""
        spec = self.fwd_real(jnp.stack([a, b], axis=0))  # (2, 3, k...)
        sa, sb = spec[0], spec[1]
        if incompressible:
            sb = self._leray_spec(sb)
        return self.inv_real(self._reg_scale(beta) * sa + sb)

    def precond_project(self, r: jnp.ndarray, beta, incompressible: bool) -> jnp.ndarray:
        """P (beta Lap^2)^{-1} r in a single spectral round trip."""
        spec = self._precond_scale(beta) * self.fwd_real(r)
        if incompressible:
            spec = self._leray_spec(spec)
        return self.inv_real(spec)

    # ------------------------------------------------------------------ #
    # image preprocessing (paper §III-B1)
    # ------------------------------------------------------------------ #
    def smooth(self, f: jnp.ndarray, sigma=None) -> jnp.ndarray:
        """Gaussian spectral filter; default bandwidth = one grid cell."""
        return self.inv_real(self._smooth_scale(sigma) * self.fwd_real(f))

    # ------------------------------------------------------------------ #
    # diagnostics
    # ------------------------------------------------------------------ #
    def reg_energy(self, v: jnp.ndarray, beta) -> jnp.ndarray:
        """beta/2 ||Lap v||^2 via Parseval on the forward spectrum (mesh
        independent; equals the real-space quadrature of ``Lap v`` to
        roundoff, pinned by ``tests/test_spectral.py``) — one forward
        transform, NO inverse.

        A cohort velocity ``(S, 3, N..)`` returns per-subject energies
        ``(S,)`` (one batched transform for the whole cohort)."""
        return self._reg_energy_spec(self.fwd_real(v), beta)

    def jacobian_det(self, disp: jnp.ndarray) -> jnp.ndarray:
        """det(grad y) for y = x + u given displacement u (3,N1,N2,N3).

        grad u is computed spectrally; det(I + grad u) pointwise.
        """
        g = jnp.swapaxes(self.grad(disp), 0, 1)  # g[i,j] = d_j u_i, one batched FFT
        a = g + jnp.eye(3, dtype=g.dtype)[:, :, None, None, None]
        det = (
            a[0, 0] * (a[1, 1] * a[2, 2] - a[1, 2] * a[2, 1])
            - a[0, 1] * (a[1, 0] * a[2, 2] - a[1, 2] * a[2, 0])
            + a[0, 2] * (a[1, 0] * a[2, 1] - a[1, 1] * a[2, 0])
        )
        return det

"""Inexact, preconditioned Gauss-Newton-Krylov solver (paper §III-A).

* Newton step from PCG on ``H(v) vt = -g(v)`` with the spectral
  preconditioner ``(beta Lap^2)^{-1}`` (mesh-independent; the paper's choice).
* Inexact solves: Eisenstat-Walker *quadratic* forcing
  ``eta_k = min(eta_max, sqrt(||g_k|| / ||g_0||))`` (paper §IV-A3).
* Globalization: Armijo backtracking line search.
* Optional parameter continuation on beta (paper §III-A).

The whole Newton iteration (plan + forward + adjoint + gradient + PCG +
line search) is one jittable function — on the production mesh this gives
XLA a single program per iteration to schedule collectives in, while the
Python driver loop stays checkpointable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import objective as obj
from repro.core.grid import Grid
from repro.core.spectral import SpectralOps


@dataclasses.dataclass(frozen=True)
class GNConfig:
    beta: float = 1e-2
    n_t: int = 4
    incompressible: bool = False
    max_newton: int = 20
    gtol: float = 1e-2  # relative gradient tolerance (paper: 1e-2)
    max_cg: int = 100
    eta_max: float = 0.5  # forcing-term cap
    armijo_c1: float = 1e-4
    max_line_search: int = 10
    beta_continuation: tuple[float, ...] = ()  # e.g. (1e-1, 1e-2): warm starts
    interp_method: str = "ref"  # "ref" | "pallas" | "auto"
    # e.g. "bfloat16": pack InterpPlan weights.  Local-executor only — an
    # explicit interp= (the distributed path) carries its own setting via
    # DistContext(plan_dtype=...) / make_halo_interp(plan_dtype=...).
    plan_dtype: str | None = None
    # DEPRECATED no-op: the transform-coalesced hot path (SpectralBatch +
    # fused k-space assemblies in core/objective.py) is now unconditional
    # and numerically identical to the old fused=True routing.
    fused_elliptic: bool = False
    gauss_newton: bool = True  # False: full Newton Hessian (paper eq. (5), all terms)


class PCGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    rel_res: jnp.ndarray


class NewtonLog(NamedTuple):
    j_val: jnp.ndarray
    misfit: jnp.ndarray
    reg: jnp.ndarray
    gnorm: jnp.ndarray
    cg_iters: jnp.ndarray
    step_len: jnp.ndarray


def pcg(
    matvec: Callable,
    b: jnp.ndarray,
    precond: Callable,
    inner: Callable,
    rtol: jnp.ndarray,
    max_iter: int,
) -> PCGResult:
    """Matrix-free preconditioned conjugate gradients (lax.while_loop).

    Counts every Hessian matvec (the paper's Table V metric).
    """
    bnorm = jnp.sqrt(inner(b, b))
    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    rz0 = inner(r0, z0)

    def cond(c):
        x, r, p, rz, it = c
        return jnp.logical_and(it < max_iter, jnp.sqrt(inner(r, r)) > rtol * bnorm)

    def body(c):
        x, r, p, rz, it = c
        hp = matvec(p)
        php = inner(p, hp)
        alpha = rz / jnp.maximum(php, 1e-30)
        x = x + alpha * p
        r = r - alpha * hp
        z = precond(r)
        rz_new = inner(r, z)
        p = z + (rz_new / jnp.maximum(rz, 1e-30)) * p
        return (x, r, p, rz_new, it + 1)

    x, r, _, _, it = jax.lax.while_loop(cond, body, (x0, r0, z0, rz0, jnp.int32(0)))
    return PCGResult(x=x, iters=it, rel_res=jnp.sqrt(inner(r, r)) / jnp.maximum(bnorm, 1e-30))


def _interp_fn(cfg: GNConfig):
    from repro.kernels import ops as kops

    # plan-aware executor: core.planner.make_plan caches an InterpPlan per
    # departure field through it, so every PCG Hessian matvec / line-search
    # transport of an iteration reuses precomputed interpolation weights
    return kops.make_interp(method=cfg.interp_method, plan_dtype=cfg.plan_dtype)


def newton_iteration(
    v: jnp.ndarray,
    g0_norm: jnp.ndarray,
    prob: obj.Problem,
    ops: SpectralOps,
    cfg: GNConfig,
    interp=None,
    precond=None,
):
    """One globalized inexact Gauss-Newton step.  Returns (v_new, NewtonLog).

    ``precond`` is an optional factory ``(state, prob) -> (r -> z)``
    replacing the default spectral preconditioner — e.g. the two-level or
    V-cycle multigrid preconditioners built by ``repro.multilevel.precond``.
    It is invoked once per Newton iteration with the fresh ``NewtonState``
    and the current ``Problem`` (whose ``beta`` tracks the continuation
    schedule) so it can assemble state-dependent coarse operators inside the
    same jit program (the V-cycle restricts the state's cached
    ``grad rho``/departure fields right here — Galerkin-consistent coarse
    Hessians with zero extra transport solves).  A factory may carry a
    static ``fine_equiv_cost`` attribute — the fine-grid-equivalent matvec
    cost of one application — which ``solve`` folds into
    ``precond_fine_equiv_matvecs`` (PCG applies the preconditioner
    ``iters + 1`` times per solve).  The Armijo steepest-descent safeguard always uses the cheap
    spectral preconditioner: the safeguard direction only needs descent, and
    a custom factory may be arbitrarily expensive (XLA's select evaluates
    both ``jnp.where`` operands).
    """
    interp = interp or _interp_fn(cfg)
    grid = prob.grid
    state = obj.newton_state(v, prob, ops, interp)
    gnorm = jnp.sqrt(grid.norm_sq(state.g))

    # ---- Newton step: PCG on H dv = -g with (beta Lap^2)^{-1} preconditioner
    def matvec(p):
        if cfg.gauss_newton:
            return obj.gn_hessian_matvec(p, state, prob, ops, interp)
        return obj.full_hessian_matvec(p, state, prob, ops, interp)

    def spectral_precond(r):
        # single coalesced ride pair: P (beta Lap^2)^{-1} r
        return ops.precond_project(r, prob.beta, prob.incompressible)

    precond = spectral_precond if precond is None else precond(state, prob)

    eta = jnp.minimum(cfg.eta_max, jnp.sqrt(gnorm / jnp.maximum(g0_norm, 1e-30)))
    rhs = -state.g
    if prob.incompressible:
        rhs = ops.leray(rhs)
    sol = pcg(matvec, rhs, precond, grid.inner, eta, cfg.max_cg)
    dv = sol.x
    if prob.incompressible:
        dv = ops.leray(dv)

    # ---- Armijo backtracking on J
    gdv = grid.inner(state.g, dv)
    # fall back to steepest descent if PCG returned a non-descent direction
    dv = jnp.where(gdv < 0, dv, -spectral_precond(state.g))
    gdv = jnp.minimum(gdv, grid.inner(state.g, dv))

    def j_of(vv):
        jval, _ = obj.evaluate_objective(vv, prob, ops, interp)
        return jval

    def ls_cond(c):
        alpha, jnew, it = c
        armijo = jnew <= state.j_val + cfg.armijo_c1 * alpha * gdv
        return jnp.logical_and(~armijo, it < cfg.max_line_search)

    def ls_body(c):
        alpha, _, it = c
        alpha = alpha * 0.5
        return (alpha, j_of(v + alpha * dv), it + 1)

    alpha0 = jnp.float32(1.0)
    j1 = j_of(v + alpha0 * dv)
    alpha, j_new, ls_it = jax.lax.while_loop(ls_cond, ls_body, (alpha0, j1, jnp.int32(0)))
    accepted = j_new < state.j_val
    v_new = jnp.where(accepted, v + alpha * dv, v)

    log = NewtonLog(
        j_val=state.j_val,
        misfit=state.misfit,
        reg=state.reg,
        gnorm=gnorm,
        cg_iters=sol.iters,
        step_len=jnp.where(accepted, alpha, 0.0),
    )
    return v_new, log


def solve(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    grid: Grid,
    cfg: GNConfig,
    ops: SpectralOps | None = None,
    v0: jnp.ndarray | None = None,
    verbose: bool = False,
    callback: Callable[[int, dict], None] | None = None,
    interp=None,
    precond=None,
    g0_ref: float | None = None,
):
    """Full registration drive: (optional) beta continuation + Newton loop.

    The per-iteration work is jit-compiled once per (grid, beta); the Python
    loop handles convergence, logging, and checkpoint callbacks.  On a mesh,
    pass ``ops=ctx.ops, interp=ctx.interp`` from a ``DistContext``.

    ``precond`` is the factory forwarded to ``newton_iteration``.  ``g0_ref``
    overrides the reference gradient norm of the convergence test: the
    multilevel driver passes the *cold-start* fine-grid norm so a warm-started
    level terminates at the same absolute tolerance a single-level solve
    would, instead of chasing gtol relative to its already-small gradient.
    """
    ops = ops or SpectralOps(grid)
    v = v0 if v0 is not None else jnp.zeros((3,) + grid.shape, grid.dtype)
    interp = interp or _interp_fn(cfg)

    betas = tuple(cfg.beta_continuation) + (cfg.beta,)
    history: list[dict] = []
    total_matvecs = 0
    total_newton = 0
    # static per-application cost of a multigrid precond (0.0 for spectral)
    pc_cost = float(getattr(precond, "fine_equiv_cost", 0.0))
    total_precond_fe = 0.0

    for beta in betas:
        prob = obj.Problem(
            grid=grid,
            rho_R=rho_R,
            rho_T=rho_T,
            beta=float(beta),
            n_t=cfg.n_t,
            incompressible=cfg.incompressible,
        )
        step_fn = jax.jit(
            partial(
                newton_iteration, prob=prob, ops=ops, cfg=cfg, interp=interp, precond=precond
            )
        )
        # reference gradient norm at this continuation level
        if g0_ref is not None:
            g0 = jnp.float32(g0_ref)
        else:
            state0 = jax.jit(partial(obj.newton_state, prob=prob, ops=ops, interp=interp))(v)
            g0 = jnp.sqrt(grid.norm_sq(state0.g))
        gnorm = g0
        for it in range(cfg.max_newton):
            v, log = step_fn(v, g0)
            gnorm = log.gnorm
            total_matvecs += int(log.cg_iters)
            total_newton += 1
            total_precond_fe += (int(log.cg_iters) + 1) * pc_cost
            rec = {
                "beta": float(beta),
                "iter": it,
                "J": float(log.j_val),
                "misfit": float(log.misfit),
                "reg": float(log.reg),
                "gnorm": float(log.gnorm),
                "rel_gnorm": float(log.gnorm / max(float(g0), 1e-30)),
                "cg_iters": int(log.cg_iters),
                "step": float(log.step_len),
            }
            history.append(rec)
            if callback:
                callback(it, rec)
            if verbose:
                print(
                    f"[beta={beta:.0e}] it={it:2d} J={rec['J']:.4e} "
                    f"misfit={rec['misfit']:.4e} |g|/|g0|={rec['rel_gnorm']:.3e} "
                    f"cg={rec['cg_iters']} step={rec['step']:.3f}"
                )
            if rec["rel_gnorm"] <= cfg.gtol or rec["step"] == 0.0:
                break

    return {
        "v": v,
        "history": history,
        "newton_iters": total_newton,
        "hessian_matvecs": total_matvecs,
        "precond_fine_equiv_matvecs": total_precond_fe,
    }

"""Inexact, preconditioned Gauss-Newton-Krylov solver (paper §III-A).

* Newton step from PCG on ``H(v) vt = -g(v)`` with the spectral
  preconditioner ``(beta Lap^2)^{-1}`` (mesh-independent; the paper's choice).
* Inexact solves: Eisenstat-Walker *quadratic* forcing
  ``eta_k = min(eta_max, sqrt(||g_k|| / ||g_0||))`` (paper §IV-A3).
* Globalization: Armijo backtracking line search.
* Optional parameter continuation on beta (paper §III-A).

The whole Newton iteration (plan + forward + adjoint + gradient + PCG +
line search) is one jittable function — on the production mesh this gives
XLA a single program per iteration to schedule collectives in, while the
Python driver loop stays checkpointable.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import objective as obj
from repro.core.grid import Grid
from repro.core.spectral import SpectralOps
from repro import telemetry
from repro.resilience import health


@dataclasses.dataclass(frozen=True)
class GNConfig:
    beta: float = 1e-2
    n_t: int = 4
    incompressible: bool = False
    max_newton: int = 20
    gtol: float = 1e-2  # relative gradient tolerance (paper: 1e-2)
    max_cg: int = 100
    eta_max: float = 0.5  # forcing-term cap
    armijo_c1: float = 1e-4
    max_line_search: int = 10
    beta_continuation: tuple[float, ...] = ()  # e.g. (1e-1, 1e-2): warm starts
    interp_method: str = "ref"  # "ref" | "pallas" | "auto"
    # e.g. "bfloat16": pack InterpPlan weights.  Local-executor only — an
    # explicit interp= (the distributed path) carries its own setting via
    # DistContext(plan_dtype=...) / make_halo_interp(plan_dtype=...).
    plan_dtype: str | None = None
    # e.g. "bfloat16": storage dtype of the transport/FFT field path (the
    # SL-transported stacks and every real field the spectral operators
    # return).  Applies to the SpectralOps this solver builds itself; an
    # explicit ops= carries its own via SpectralOps(field_dtype=...) /
    # DistContext(field_dtype=...).  Critical accumulations stay >= f32:
    # inner products (grid.inner), the time quadrature, the k-space
    # scalings, and the PCG recursion (guarded in newton_iteration).
    field_dtype: str | None = None
    # tuning-cache consult for the perf knobs above: "cache" fills knobs
    # still at their defaults from the repro.autotune cache (missing cache
    # == no-op), "off" disables, "sweep" additionally sweeps on a miss.
    autotune: str = "cache"
    # DEPRECATED no-op: the transform-coalesced hot path (SpectralBatch +
    # fused k-space assemblies in core/objective.py) is now unconditional
    # and numerically identical to the old fused=True routing.  Setting it
    # True emits a DeprecationWarning; the field will be removed.
    fused_elliptic: bool = False
    gauss_newton: bool = True  # False: full Newton Hessian (paper eq. (5), all terms)

    def __post_init__(self):
        if self.fused_elliptic:
            warnings.warn(
                "GNConfig.fused_elliptic is deprecated and has no effect: the "
                "transform-coalesced elliptic assembly (core/objective.py + "
                "SpectralBatch) is unconditional and numerically identical to "
                "the old fused=True routing",
                DeprecationWarning,
                stacklevel=2,
            )


class PCGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    rel_res: jnp.ndarray


class NewtonLog(NamedTuple):
    j_val: jnp.ndarray
    misfit: jnp.ndarray
    reg: jnp.ndarray
    gnorm: jnp.ndarray
    cg_iters: jnp.ndarray
    step_len: jnp.ndarray
    ls_iters: jnp.ndarray | int = 0  # Armijo backtracking trials
    # in-graph health code (``repro.resilience.health``): scalar for the
    # single solve, per-subject (S,) for the cohort step
    status: jnp.ndarray | int = 0


def pcg(
    matvec: Callable,
    b: jnp.ndarray,
    precond: Callable,
    inner: Callable,
    rtol: jnp.ndarray,
    max_iter: int,
) -> PCGResult:
    """Matrix-free preconditioned conjugate gradients (lax.while_loop).

    Counts every Hessian matvec (the paper's Table V metric).
    """
    bnorm = jnp.sqrt(inner(b, b))
    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    rz0 = inner(r0, z0)

    def cond(c):
        x, r, p, rz, it = c
        return jnp.logical_and(it < max_iter, jnp.sqrt(inner(r, r)) > rtol * bnorm)

    def body(c):
        x, r, p, rz, it = c
        hp = matvec(p)
        php = inner(p, hp)
        alpha = rz / jnp.maximum(php, 1e-30)
        x = x + alpha * p
        r = r - alpha * hp
        z = precond(r)
        rz_new = inner(r, z)
        p = z + (rz_new / jnp.maximum(rz, 1e-30)) * p
        return (x, r, p, rz_new, it + 1)

    x, r, _, _, it = jax.lax.while_loop(cond, body, (x0, r0, z0, rz0, jnp.int32(0)))
    return PCGResult(x=x, iters=it, rel_res=jnp.sqrt(inner(r, r)) / jnp.maximum(bnorm, 1e-30))


def pcg_masked(
    matvec: Callable,
    b: jnp.ndarray,
    precond: Callable,
    inner_per: Callable,
    rtol: jnp.ndarray,
    max_iter: int,
    active: jnp.ndarray,
) -> PCGResult:
    """Per-subject masked PCG over a cohort stack ``b (S, 3, N..)``.

    All subjects advance in lockstep through the SAME batched matvec (one
    set of transform/exchange rides per iteration), but each subject runs
    its OWN scalar-``pcg`` recursion: ``rtol``/``active`` are per-subject
    ``(S,)``, a subject whose residual test or iteration cap trips freezes
    (``x``/``r``/``p``/``rz`` masked in place, zero contribution from then
    on), and the loop ends when no subject is live.  Live trajectories are
    identical to independent ``pcg`` runs up to batched-transform roundoff.

    ``iters`` is per-subject ``(S,)`` — the paper's Table V matvec count as
    a billing meter: a retired (or never-active) subject accrues nothing.
    """

    def bc(s):  # (S,) -> (S, 1, 1, 1, 1): broadcast over field dims
        return s.reshape(s.shape + (1,) * (b.ndim - 1))

    bnorm = jnp.sqrt(inner_per(b, b))
    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    rz0 = inner_per(r0, z0)
    iters0 = jnp.zeros((b.shape[0],), jnp.int32)

    def live(r, iters):
        return active & (jnp.sqrt(inner_per(r, r)) > rtol * bnorm) & (iters < max_iter)

    def cond(c):
        x, r, p, rz, iters = c
        return jnp.any(live(r, iters))

    def body(c):
        x, r, p, rz, iters = c
        lv = live(r, iters)
        hp = matvec(p)
        php = inner_per(p, hp)
        alpha = jnp.where(lv, rz / jnp.maximum(php, 1e-30), 0.0)
        x = x + bc(alpha) * p
        r = r - bc(alpha) * hp
        z = precond(r)
        rz_new = inner_per(r, z)
        beta_cg = jnp.where(lv, rz_new / jnp.maximum(rz, 1e-30), 0.0)
        p = jnp.where(bc(lv), z + bc(beta_cg) * p, p)
        rz = jnp.where(lv, rz_new, rz)
        return (x, r, p, rz, iters + lv.astype(jnp.int32))

    x, r, _, _, iters = jax.lax.while_loop(cond, body, (x0, r0, z0, rz0, iters0))
    rel = jnp.sqrt(inner_per(r, r)) / jnp.maximum(bnorm, 1e-30)
    return PCGResult(x=x, iters=iters, rel_res=rel)


def _tuned_cfg(cfg: GNConfig, grid: Grid, ops) -> GNConfig:
    """Fill still-at-default perf knobs of ``cfg`` from the tuning cache.

    No-op when ``cfg.autotune == "off"``, when the cache has no entry for
    this ``(grid, devices, beta)`` cell, or when every knob was set
    explicitly (an explicit value always wins — the resolver only touches
    knobs still at their dataclass defaults).  Lazy import keeps
    ``repro.autotune`` out of the core dependency graph.
    """
    if cfg.autotune == "off":
        return cfg
    from repro import autotune

    return autotune.consult_gn(cfg, grid, ops)


def _interp_fn(cfg: GNConfig):
    from repro.kernels import ops as kops

    # plan-aware executor: core.planner.make_plan caches an InterpPlan per
    # departure field through it, so every PCG Hessian matvec / line-search
    # transport of an iteration reuses precomputed interpolation weights
    return kops.make_interp(method=cfg.interp_method, plan_dtype=cfg.plan_dtype)


def newton_iteration(
    v: jnp.ndarray,
    g0_forcing: jnp.ndarray,
    prob: obj.Problem,
    ops: SpectralOps,
    cfg: GNConfig,
    interp=None,
    precond=None,
):
    """One globalized inexact Gauss-Newton step.  Returns (v_new, NewtonLog).

    ``g0_forcing`` is the Eisenstat-Walker *forcing* reference only — the
    denominator in ``eta = min(eta_max, sqrt(gnorm / g0_forcing))``.  It is
    deliberately decoupled from the convergence reference (``solve``'s
    ``g0_ref``): a warm-started multilevel stage passes its own first-iterate
    gradient norm here, so PCG is solved loosely again (eta near eta_max)
    instead of to the near-machine tolerance that conflating the two
    references forced (``gnorm/g0_ref`` is already ~gtol on a warm level,
    driving eta -> sqrt(gtol) * 0 and over-solving every inner system).
    Pass a tiny sentinel (e.g. ``1e-30``) on the first call of a stage to
    get ``eta = eta_max`` — the classical cold-start choice.

    ``precond`` is an optional factory ``(state, prob) -> (r -> z)``
    replacing the default spectral preconditioner — e.g. the two-level or
    V-cycle multigrid preconditioners built by ``repro.multilevel.precond``.
    It is invoked once per Newton iteration with the fresh ``NewtonState``
    and the current ``Problem`` (whose ``beta`` tracks the continuation
    schedule) so it can assemble state-dependent coarse operators inside the
    same jit program (the V-cycle restricts the state's cached
    ``grad rho``/departure fields right here — Galerkin-consistent coarse
    Hessians with zero extra transport solves).  A factory may carry a
    static ``fine_equiv_cost`` attribute — the fine-grid-equivalent matvec
    cost of one application — which ``solve`` folds into
    ``precond_fine_equiv_matvecs`` (PCG applies the preconditioner
    ``iters + 1`` times per solve).  The Armijo steepest-descent safeguard always uses the cheap
    spectral preconditioner: the safeguard direction only needs descent, and
    a custom factory may be arbitrarily expensive (XLA's select evaluates
    both ``jnp.where`` operands).
    """
    interp = interp or _interp_fn(cfg)
    grid = prob.grid
    state = obj.newton_state(v, prob, ops, interp)
    gnorm = jnp.sqrt(grid.norm_sq(state.g))

    # ---- Newton step: PCG on H dv = -g with (beta Lap^2)^{-1} preconditioner
    def matvec(p):
        if cfg.gauss_newton:
            return obj.gn_hessian_matvec(p, state, prob, ops, interp)
        return obj.full_hessian_matvec(p, state, prob, ops, interp)

    def spectral_precond(r):
        # single coalesced ride pair: P (beta Lap^2)^{-1} r
        return ops.precond_project(r, prob.beta, prob.incompressible)

    precond = spectral_precond if precond is None else precond(state, prob)

    # Critical-accumulation guard: the PCG recursion runs in >= f32 even when
    # ``field_dtype`` stores fields in bf16.  Casting the rhs and the
    # preconditioner output (z0 seeds p0) up to ``ct`` keeps x/r/p/rz wide for
    # the whole while_loop — JAX promotion then absorbs any bf16 matvec output
    # into f32 updates — while matvec/precond internals keep the cheap
    # storage dtype for their transform rides.
    ct = jnp.promote_types(v.dtype, jnp.float32)
    base_precond = precond

    def wide_precond(r):
        return base_precond(r).astype(ct)

    eta = jnp.minimum(cfg.eta_max, jnp.sqrt(gnorm / jnp.maximum(g0_forcing, 1e-30)))
    rhs = -state.g
    if prob.incompressible:
        rhs = ops.leray(rhs)
    rhs = rhs.astype(ct)
    sol = pcg(matvec, rhs, wide_precond, grid.inner, eta, cfg.max_cg)
    dv = sol.x
    if prob.incompressible:
        dv = ops.leray(dv).astype(ct)

    # ---- Armijo backtracking on J
    gdv = grid.inner(state.g, dv)
    # fall back to steepest descent if PCG returned a non-descent direction
    dv = jnp.where(gdv < 0, dv, -spectral_precond(state.g).astype(ct))
    gdv = jnp.minimum(gdv, grid.inner(state.g, dv))

    def j_of(vv):
        jval, _ = obj.evaluate_objective(vv, prob, ops, interp)
        return jval

    def ls_cond(c):
        alpha, jnew, it = c
        armijo = jnew <= state.j_val + cfg.armijo_c1 * alpha * gdv
        return jnp.logical_and(~armijo, it < cfg.max_line_search)

    def ls_body(c):
        alpha, _, it = c
        alpha = alpha * 0.5
        return (alpha, j_of(v + alpha * dv), it + 1)

    alpha0 = jnp.float32(1.0)
    j1 = j_of(v + alpha0 * dv)
    alpha, j_new, ls_it = jax.lax.while_loop(ls_cond, ls_body, (alpha0, j1, jnp.int32(0)))
    accepted = j_new < state.j_val
    v_new = jnp.where(accepted, v + alpha * dv, v)

    # in-graph health guard: classify the step (NaN/Inf, divergence, PCG
    # breakdown) and revert a non-finite iterate to the last good one
    status = health.classify(
        v_in=v,
        v_out=v_new,
        j_val=state.j_val,
        j_new=j_new,
        gnorm=gnorm,
        pcg_x=sol.x,
        pcg_rel=sol.rel_res,
        accepted=accepted,
    )
    v_new = health.freeze(v_new, v, status)

    log = NewtonLog(
        j_val=state.j_val,
        misfit=state.misfit,
        reg=state.reg,
        gnorm=gnorm,
        cg_iters=sol.iters,
        step_len=jnp.where(accepted, alpha, 0.0),
        ls_iters=ls_it,
        status=status,
    )
    return v_new, log


def solve(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    grid: Grid,
    cfg: GNConfig,
    ops: SpectralOps | None = None,
    v0: jnp.ndarray | None = None,
    verbose: bool = False,
    callback: Callable[[int, dict], None] | None = None,
    interp=None,
    precond=None,
    g0_ref: float | None = None,
):
    """Full registration drive: (optional) beta continuation + Newton loop.

    The per-iteration work is jit-compiled once per (grid, beta); the Python
    loop handles convergence, logging, and checkpoint callbacks.  On a mesh,
    pass ``ops=ctx.ops, interp=ctx.interp`` from a ``DistContext``.

    ``precond`` is the factory forwarded to ``newton_iteration``.  ``g0_ref``
    overrides the reference gradient norm of the CONVERGENCE test only: the
    multilevel driver passes the *cold-start* fine-grid norm so a warm-started
    level terminates at the same absolute tolerance a single-level solve
    would, instead of chasing gtol relative to its already-small gradient.
    The Eisenstat-Walker FORCING reference is decoupled from it (see
    ``newton_iteration``): each beta stage forces against its own first
    gradient norm (first call uses a tiny sentinel, i.e. ``eta = eta_max``),
    so warm stages keep loose inner solves rather than inheriting the tight
    ``gnorm/g0_ref`` ratio and over-solving PCG.
    """
    cfg = _tuned_cfg(cfg, grid, ops)
    ops = ops or SpectralOps(grid, field_dtype=cfg.field_dtype)
    v = v0 if v0 is not None else jnp.zeros((3,) + grid.shape, grid.dtype)
    interp = interp or _interp_fn(cfg)

    betas = tuple(cfg.beta_continuation) + (cfg.beta,)
    history: list[dict] = []
    total_matvecs = 0
    total_newton = 0
    # static per-application cost of a multigrid precond (0.0 for spectral)
    pc_cost = float(getattr(precond, "fine_equiv_cost", 0.0))
    total_precond_fe = 0.0
    status_code = health.OK

    for beta in betas:
        prob = obj.Problem(
            grid=grid,
            rho_R=rho_R,
            rho_T=rho_T,
            beta=float(beta),
            n_t=cfg.n_t,
            incompressible=cfg.incompressible,
        )
        step_fn = jax.jit(
            partial(
                newton_iteration, prob=prob, ops=ops, cfg=cfg, interp=interp, precond=precond
            )
        )
        # convergence reference: g0_ref if supplied, else this stage's first
        # gradient norm; forcing reference: ALWAYS the stage's first gradient
        # norm (sentinel 1e-30 on the first call -> eta = eta_max).
        g0 = None if g0_ref is None else jnp.float32(g0_ref)
        g_forcing = None
        for it in range(cfg.max_newton):
            with telemetry.span("gn.newton_iter", beta=float(beta), iter=it) as sp:
                v, log = sp.sync(
                    step_fn(v, g_forcing if g_forcing is not None else jnp.float32(1e-30))
                )
            if g_forcing is None:
                g_forcing = log.gnorm
            if g0 is None:
                g0 = log.gnorm
            total_matvecs += int(log.cg_iters)
            total_newton += 1
            total_precond_fe += (int(log.cg_iters) + 1) * pc_cost
            status_code = int(log.status)
            rec = {
                "beta": float(beta),
                "iter": it,
                "J": float(log.j_val),
                "misfit": float(log.misfit),
                "reg": float(log.reg),
                "gnorm": float(log.gnorm),
                "rel_gnorm": float(log.gnorm / max(float(g0), 1e-30)),
                "cg_iters": int(log.cg_iters),
                "step": float(log.step_len),
                "armijo_trials": int(log.ls_iters),
                "status": health.status_name(status_code),
            }
            history.append(rec)
            if callback:
                callback(it, rec)
            # the single console sink renders this exactly as the old
            # verbose print did; a JSONL sink gets the typed record
            telemetry.emit(
                telemetry.NewtonIterEvent(
                    source="gn.solve",
                    beta=rec["beta"],
                    iter=it,
                    j_val=rec["J"],
                    misfit=rec["misfit"],
                    reg=rec["reg"],
                    gnorm=rec["gnorm"],
                    rel_gnorm=rec["rel_gnorm"],
                    cg_iters=rec["cg_iters"],
                    step_len=rec["step"],
                    armijo_trials=rec["armijo_trials"],
                    wall_s=sp.wall_s,
                    level=rec.get("level"),
                ),
                echo=verbose,
            )
            if health.is_failure(status_code):
                # a NaN-poisoned / diverging / broken-down solve will not
                # heal by iterating further: stop the stage, surface the
                # reason, and let the caller's retry policy take over
                telemetry.counter(
                    "resilience.guard_tripped", status=rec["status"], source="gn.solve"
                )
                break
            if rec["rel_gnorm"] <= cfg.gtol or rec["step"] == 0.0:
                break
        if health.is_failure(status_code):
            break

    # final status of the last beta stage (host maps convergence/iteration
    # cap onto the codes the in-graph guard cannot decide)
    if history and health.is_failure(status_code):
        final_status = history[-1]["status"]
    elif history and history[-1]["rel_gnorm"] <= cfg.gtol:
        final_status = health.status_name(health.CONVERGED)
    elif history and history[-1]["step"] == 0.0:
        final_status = health.status_name(health.STAGNATED)
    else:
        final_status = health.status_name(health.MAX_NEWTON)

    telemetry.emit(
        telemetry.SolveEvent(
            source="gn.solve",
            newton_iters=total_newton,
            hessian_matvecs=total_matvecs,
            fine_equiv_matvecs=float(total_matvecs),
            precond_fine_equiv_matvecs=total_precond_fe,
            compiled_executables=None,
        )
    )
    return {
        "v": v,
        "history": history,
        "newton_iters": total_newton,
        "hessian_matvecs": total_matvecs,
        "precond_fine_equiv_matvecs": total_precond_fe,
        "status": final_status,
    }


# ---------------------------------------------------------------------------
# Cohort-parallel solver: a subjects axis S through the whole GN iteration
# ---------------------------------------------------------------------------


def newton_iteration_cohort(
    v: jnp.ndarray,
    g0_forcing: jnp.ndarray,
    active: jnp.ndarray,
    prob: obj.Problem,
    ops: SpectralOps,
    cfg: GNConfig,
    interp=None,
):
    """One masked Gauss-Newton step for a cohort ``v (S, 3, N..)``.

    Structurally ``newton_iteration`` with every scalar recursion made
    per-subject ``(S,)``: Eisenstat-Walker forcing, PCG termination
    (``pcg_masked``), the descent safeguard, and Armijo backtracking all
    mask on ``active`` so a converged/rejected subject freezes (zero step,
    velocity unchanged) without perturbing the others — the live subjects'
    trajectories match independent single solves up to batched-transform
    roundoff.  All S subjects share every transport/interp/transform ride,
    which is the whole point: one ghost exchange and one coalesced FFT pair
    serve the entire cohort (docstring of ``solve_cohort``).

    ``active`` gates cost too: an all-False cohort still traces one program
    but ``pcg_masked``/line-search loops exit immediately, so retired
    subjects accrue no Hessian matvecs in the ``(S,)`` ``cg_iters`` meter.
    """
    interp = interp or _interp_fn(cfg)
    grid = prob.grid
    state = obj.newton_state(v, prob, ops, interp)
    gnorm = jnp.sqrt(grid.norm_sq_per(state.g))

    def bc(s):  # (S,) -> (S,1,1,1,1)
        return s.reshape(s.shape + (1,) * (v.ndim - 1))

    def matvec(p):
        return obj.gn_hessian_matvec(p, state, prob, ops, interp)

    def spectral_precond(r):
        return ops.precond_project(r, prob.beta, prob.incompressible)

    # >= f32 PCG recursion guard — same rationale as ``newton_iteration``
    ct = jnp.promote_types(v.dtype, jnp.float32)

    def wide_precond(r):
        return spectral_precond(r).astype(ct)

    eta = jnp.minimum(cfg.eta_max, jnp.sqrt(gnorm / jnp.maximum(g0_forcing, 1e-30)))
    rhs = -state.g
    if prob.incompressible:
        rhs = ops.leray(rhs)
    rhs = rhs.astype(ct)
    sol = pcg_masked(matvec, rhs, wide_precond, grid.inner_per, eta, cfg.max_cg, active)
    dv = sol.x
    if prob.incompressible:
        dv = ops.leray(dv).astype(ct)

    # per-subject steepest-descent safeguard
    gdv = grid.inner_per(state.g, dv)
    dv = jnp.where(bc(gdv < 0), dv, -spectral_precond(state.g).astype(ct))
    gdv = jnp.minimum(gdv, grid.inner_per(state.g, dv))

    def j_of(vv):
        jval, _ = obj.evaluate_objective(vv, prob, ops, interp)
        return jval  # (S,)

    # lockstep per-subject Armijo: each halving step shares one objective
    # evaluation (one forward transport for the whole cohort); subjects that
    # already satisfy the condition freeze their (alpha, j_new).
    def ls_cond(c):
        alpha, jnew, it = c
        armijo = jnew <= state.j_val + cfg.armijo_c1 * alpha * gdv
        return jnp.logical_and(jnp.any(active & ~armijo), it < cfg.max_line_search)

    def ls_body(c):
        alpha, jnew, it = c
        armijo = jnew <= state.j_val + cfg.armijo_c1 * alpha * gdv
        halve = active & ~armijo
        alpha = jnp.where(halve, alpha * 0.5, alpha)
        jtrial = j_of(v + bc(alpha) * dv)
        jnew = jnp.where(halve, jtrial, jnew)
        return (alpha, jnew, it + 1)

    alpha0 = jnp.ones((v.shape[0],), jnp.float32)
    j1 = j_of(v + bc(alpha0) * dv)
    alpha, j_new, ls_it = jax.lax.while_loop(ls_cond, ls_body, (alpha0, j1, jnp.int32(0)))
    accepted = active & (j_new < state.j_val)
    v_new = jnp.where(bc(accepted), v + bc(alpha) * dv, v)

    # per-subject in-graph health guard: the reductions keep the subjects
    # axis, and a sick subject's iterate is frozen so its NaNs never feed
    # the cohort's shared transform rides on the next step
    status = health.classify(
        v_in=v,
        v_out=v_new,
        j_val=state.j_val,
        j_new=j_new,
        gnorm=gnorm,
        pcg_x=sol.x,
        pcg_rel=sol.rel_res,
        accepted=accepted,
        active=active,
        axes=tuple(range(1, v.ndim)),
    )
    v_new = health.freeze(v_new, v, status)

    log = NewtonLog(
        j_val=state.j_val,
        misfit=state.misfit,
        reg=state.reg,
        gnorm=gnorm,
        cg_iters=sol.iters,
        step_len=jnp.where(accepted, alpha, 0.0),
        ls_iters=ls_it,  # shared lockstep halvings (scalar, not per-subject)
        status=status,
    )
    return v_new, log


def _cohort_step(
    v: jnp.ndarray,
    g0_forcing: jnp.ndarray,
    active: jnp.ndarray,
    beta: jnp.ndarray,
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    *,
    grid: Grid,
    cfg: GNConfig,
    ops: SpectralOps,
    interp,
):
    """Jit body for one cohort Newton iteration with EVERYTHING that varies
    across a serving session traced: ``beta`` (continuation stage), the image
    stacks (slot refills swap subjects without recompiling), the per-subject
    forcing references and the active mask.  ``beta`` flows traced through
    ``Problem`` into the spectral scale factories, which accept traced
    scalars — so one (grid, mesh, cfg) bucket compiles exactly ONE
    executable for its whole lifetime (pinned by ``tests/test_cohort.py``).
    """
    prob = obj.Problem(
        grid=grid,
        rho_R=rho_R,
        rho_T=rho_T,
        beta=beta,
        n_t=cfg.n_t,
        incompressible=cfg.incompressible,
    )
    return newton_iteration_cohort(v, g0_forcing, active, prob, ops, cfg, interp)


def make_cohort_step(grid: Grid, cfg: GNConfig, ops: SpectralOps | None = None, interp=None):
    """Build the shared jitted cohort step for a (grid, mesh, cfg) bucket.

    The returned function has signature
    ``step_fn(v, g0_forcing, active, beta, rho_R, rho_T)`` and is what
    ``solve_cohort`` iterates and what ``launch/reg_serve.py`` keeps hot in
    its executable cache across job admissions.
    """
    if not cfg.gauss_newton:
        raise NotImplementedError(
            "cohort solves support the Gauss-Newton Hessian only (cfg.gauss_newton=True)"
        )
    cfg = _tuned_cfg(cfg, grid, ops)
    ops = ops or SpectralOps(grid, field_dtype=cfg.field_dtype)
    interp = interp or _interp_fn(cfg)
    return jax.jit(partial(_cohort_step, grid=grid, cfg=cfg, ops=ops, interp=interp))


def solve_cohort(
    rho_R: jnp.ndarray,
    rho_T: jnp.ndarray,
    grid: Grid,
    cfg: GNConfig,
    ops: SpectralOps | None = None,
    v0: jnp.ndarray | None = None,
    verbose: bool = False,
    callback: Callable[[int, dict], None] | None = None,
    interp=None,
    g0_ref: float | None = None,
    active: jnp.ndarray | None = None,
    step_fn=None,
):
    """Register S subjects at once: ``rho_R``/``rho_T`` are ``(S, N..)``.

    The cohort axis amortizes the fixed cost of a distributed solve — the
    collective latency of each ghost exchange / pencil all-to-all and the
    per-call dispatch overhead — across S independent registrations that
    ride the SAME batched kernels (counted-collective pin: an S=4 cohort
    issues strictly fewer all-to-alls than 4 single solves).  Per-subject
    masking keeps the numerics faithful: each subject follows its own
    Eisenstat-Walker forcing, PCG termination, Armijo schedule, and
    termination test, and a converged subject retires (frozen velocity,
    zero further matvec cost) while the rest continue.

    ``active`` optionally deactivates subjects from the start (a serving
    front end admits a partially-filled cohort).  ``step_fn`` optionally
    supplies a pre-built ``make_cohort_step`` executable so many cohorts
    share one compilation (the reg_serve bucket cache); its static config
    must match ``(grid, cfg)``.

    Returns per-subject lists for ``newton_iters``/``hessian_matvecs``/
    ``fine_equiv_matvecs`` (single-level: fine-equivalent == raw matvecs)
    and ``compiled_executables`` — the jit cache size of ``step_fn``, which
    the one-executable acceptance test pins to 1 across a full
    continuation schedule.
    """
    if not cfg.gauss_newton:
        raise NotImplementedError(
            "cohort solves support the Gauss-Newton Hessian only (cfg.gauss_newton=True)"
        )
    S = rho_R.shape[0]
    v = v0 if v0 is not None else jnp.zeros((S, 3) + grid.shape, grid.dtype)
    if step_fn is None:
        step_fn = make_cohort_step(grid, cfg, ops=ops, interp=interp)
    active0 = (
        jnp.ones((S,), bool) if active is None else jnp.asarray(active, bool)
    )

    betas = tuple(cfg.beta_continuation) + (cfg.beta,)
    history: list[dict] = []
    newton_counts = np.zeros(S, np.int64)
    cg_counts = np.zeros(S, np.int64)
    status_codes = np.zeros(S, np.int64)

    for beta in betas:
        stage_act = active0
        # every stage re-activates its subjects; final statuses are the
        # final stage's retirement reasons
        status_codes[np.asarray(active0)] = health.OK
        g0 = None if g0_ref is None else jnp.full((S,), g0_ref, jnp.float32)
        g_forcing = jnp.full((S,), 1e-30, jnp.float32)
        have_forcing = False
        for it in range(cfg.max_newton):
            act_np = np.asarray(stage_act)
            if not act_np.any():
                break
            with telemetry.span("gn.cohort_iter", beta=float(beta), iter=it) as sp:
                v, log = sp.sync(
                    step_fn(v, g_forcing, stage_act, jnp.float32(beta), rho_R, rho_T)
                )
            if not have_forcing:
                g_forcing = log.gnorm
                have_forcing = True
            if g0 is None:
                g0 = log.gnorm
            newton_counts += act_np
            cg_counts += np.asarray(log.cg_iters, np.int64)
            rel = np.asarray(log.gnorm) / np.maximum(np.asarray(g0), 1e-30)
            step = np.asarray(log.step_len)
            code = np.asarray(log.status, np.int64)
            failed = act_np & np.isin(code, health.FAILED_CODES)
            done = act_np & ((rel <= cfg.gtol) | (step == 0.0) | failed)
            stage_act = jnp.asarray(act_np & ~done)
            # retirement-reason bookkeeping (host decides converged/stagnated;
            # the in-graph guard decides the failure modes)
            status_codes[failed] = code[failed]
            conv = done & ~failed & (rel <= cfg.gtol)
            status_codes[conv] = health.CONVERGED
            stag = done & ~failed & ~conv
            status_codes[stag] = np.where(
                code[stag] == health.OK, health.STAGNATED, code[stag]
            )
            if failed.any():
                telemetry.counter(
                    "resilience.guard_tripped",
                    value=int(failed.sum()),
                    source="gn.solve_cohort",
                )
            rec = {
                "beta": float(beta),
                "iter": it,
                "J": [float(x) for x in np.asarray(log.j_val)],
                "misfit": [float(x) for x in np.asarray(log.misfit)],
                "reg": [float(x) for x in np.asarray(log.reg)],
                "gnorm": [float(x) for x in np.asarray(log.gnorm)],
                "rel_gnorm": [float(x) for x in rel],
                "cg_iters": [int(x) for x in np.asarray(log.cg_iters)],
                "step": [float(x) for x in step],
                "active": [bool(x) for x in act_np],
                "armijo_trials": int(log.ls_iters),
                "status": [int(x) for x in code],
            }
            history.append(rec)
            if callback:
                callback(it, rec)
            telemetry.emit(
                telemetry.NewtonIterEvent(
                    source="gn.solve_cohort",
                    beta=rec["beta"],
                    iter=it,
                    j_val=rec["J"],
                    misfit=rec["misfit"],
                    reg=rec["reg"],
                    gnorm=rec["gnorm"],
                    rel_gnorm=rec["rel_gnorm"],
                    cg_iters=rec["cg_iters"],
                    step_len=rec["step"],
                    armijo_trials=rec["armijo_trials"],
                    wall_s=sp.wall_s,
                    subjects=S,
                    active=rec["active"],
                ),
                echo=verbose,
            )

    # subjects still live after the final stage exhausted max_newton
    act0_np = np.asarray(active0)
    status_codes[act0_np & (status_codes == health.OK)] = health.MAX_NEWTON

    out = {
        "v": v,
        "history": history,
        "newton_iters": [int(x) for x in newton_counts],
        "hessian_matvecs": [int(x) for x in cg_counts],
        # single-level cohort: every matvec is a fine-grid matvec
        "fine_equiv_matvecs": [float(x) for x in cg_counts],
        "active": [bool(x) for x in np.asarray(active0)],
        "compiled_executables": int(step_fn._cache_size()),
        "status": [health.status_name(c) for c in status_codes],
    }
    telemetry.emit(
        telemetry.SolveEvent(
            source="gn.solve_cohort",
            newton_iters=out["newton_iters"],
            hessian_matvecs=out["hessian_matvecs"],
            fine_equiv_matvecs=out["fine_equiv_matvecs"],
            compiled_executables=out["compiled_executables"],
        )
    )
    return out

"""End-to-end Gauss-Newton-Krylov registration (paper §IV behaviours)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


@pytest.fixture(scope="module")
def solved():
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(24)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=12, gtol=1e-2, max_cg=50)
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    return out


def test_gradient_reduced_to_paper_tolerance(solved):
    """Paper: g_tol = 1e-2 relative gradient reduction (§IV-A3)."""
    assert solved["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6


def test_misfit_reduced(solved):
    h = solved["history"]
    assert h[-1]["misfit"] < 0.3 * h[0]["misfit"]


def test_residual_reduced(solved):
    assert solved["residual_rel"] < 0.7


def test_residual_reported_on_raw_inputs():
    """``residual_rel`` measures the RAW input images (regression: it used
    to be computed on the presmoothed pair, overstating convergence when
    smoothing removes high-frequency content), with the smoothed residual
    kept under ``residual_rel_smoothed``."""
    from repro.core import semilag
    from repro.core.planner import make_plan
    from repro.core.spectral import SpectralOps

    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16, n_t=2)
    # high-frequency detail the presmoother attenuates hard
    x = grid.coords_jnp()
    noise = 0.05 * jnp.sin(7 * x[0]) * jnp.sin(6 * x[1])
    rho_R, rho_T = rho_R + noise, rho_T - noise
    scfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=4, gtol=1e-2, max_cg=20)
    out = register(rho_R, rho_T, RegistrationConfig(solver=scfg), grid=grid)

    # independent recomputation on the raw pair with the solved velocity
    ops = SpectralOps(grid)
    plan = make_plan(out["v"], grid, ops, scfg.n_t, scfg.incompressible)
    rho1_raw = semilag.transport_state(rho_T, plan)[-1]
    expect = float(jnp.linalg.norm((rho1_raw - rho_R).ravel())) / float(
        jnp.linalg.norm((rho_T - rho_R).ravel())
    )
    assert abs(out["residual_rel"] - expect) < 1e-5, (out["residual_rel"], expect)
    # the solver optimized the smoothed pair, so its residual is smaller —
    # reporting it as THE residual was the bug
    assert out["residual_rel_smoothed"] < out["residual_rel"], out


def test_deformation_is_diffeomorphic(solved):
    """det(grad y1) > 0 everywhere (paper Fig. 7)."""
    assert solved["det_min"] > 0.0


def test_monotone_objective(solved):
    js = [h["J"] for h in solved["history"]]
    assert all(b <= a + 1e-6 for a, b in zip(js, js[1:]))


@pytest.mark.slow
def test_newton_mesh_independence():
    """Paper §IV-B: Newton iteration counts are mesh-independent."""
    iters = {}
    for n in (16, 24):
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(n)
        cfg = RegistrationConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=20, gtol=1e-2, max_cg=50)
        )
        out = register(rho_R, rho_T, cfg, grid=grid)
        iters[n] = out["newton_iters"]
    assert abs(iters[16] - iters[24]) <= 2


@pytest.mark.slow
def test_incompressible_volume_preservation():
    """div v = 0 => det(grad y) = 1 (locally volume preserving, §II-A)."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16, incompressible=True, amplitude=0.5)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30, incompressible=True)
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    assert abs(out["det_min"] - 1.0) < 0.1 and abs(out["det_max"] - 1.0) < 0.1


@pytest.mark.slow
def test_beta_sensitivity_matvecs_increase():
    """Paper Table V: smaller beta => more Hessian matvecs."""
    counts = {}
    for beta in (1e-1, 1e-3):
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
        cfg = RegistrationConfig(
            solver=gn.GNConfig(beta=beta, n_t=4, max_newton=4, gtol=1e-3, max_cg=100)
        )
        out = register(rho_R, rho_T, cfg, grid=grid)
        counts[beta] = out["hessian_matvecs"]
    assert counts[1e-3] > counts[1e-1]


@pytest.mark.slow
def test_beta_continuation_warm_start():
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(
            beta=1e-3, beta_continuation=(1e-1, 1e-2), n_t=4, max_newton=4, gtol=1e-2, max_cg=30
        )
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    assert out["residual_rel"] < 0.6
    assert out["det_min"] > 0.0

"""End-to-end Gauss-Newton-Krylov registration (paper §IV behaviours)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


@pytest.fixture(scope="module")
def solved():
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(24)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=12, gtol=1e-2, max_cg=50)
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    return out


def test_gradient_reduced_to_paper_tolerance(solved):
    """Paper: g_tol = 1e-2 relative gradient reduction (§IV-A3)."""
    assert solved["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6


def test_misfit_reduced(solved):
    h = solved["history"]
    assert h[-1]["misfit"] < 0.3 * h[0]["misfit"]


def test_residual_reduced(solved):
    assert solved["residual_rel"] < 0.7


def test_deformation_is_diffeomorphic(solved):
    """det(grad y1) > 0 everywhere (paper Fig. 7)."""
    assert solved["det_min"] > 0.0


def test_monotone_objective(solved):
    js = [h["J"] for h in solved["history"]]
    assert all(b <= a + 1e-6 for a, b in zip(js, js[1:]))


@pytest.mark.slow
def test_newton_mesh_independence():
    """Paper §IV-B: Newton iteration counts are mesh-independent."""
    iters = {}
    for n in (16, 24):
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(n)
        cfg = RegistrationConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=20, gtol=1e-2, max_cg=50)
        )
        out = register(rho_R, rho_T, cfg, grid=grid)
        iters[n] = out["newton_iters"]
    assert abs(iters[16] - iters[24]) <= 2


@pytest.mark.slow
def test_incompressible_volume_preservation():
    """div v = 0 => det(grad y) = 1 (locally volume preserving, §II-A)."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16, incompressible=True, amplitude=0.5)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30, incompressible=True)
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    assert abs(out["det_min"] - 1.0) < 0.1 and abs(out["det_max"] - 1.0) < 0.1


@pytest.mark.slow
def test_beta_sensitivity_matvecs_increase():
    """Paper Table V: smaller beta => more Hessian matvecs."""
    counts = {}
    for beta in (1e-1, 1e-3):
        rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
        cfg = RegistrationConfig(
            solver=gn.GNConfig(beta=beta, n_t=4, max_newton=4, gtol=1e-3, max_cg=100)
        )
        out = register(rho_R, rho_T, cfg, grid=grid)
        counts[beta] = out["hessian_matvecs"]
    assert counts[1e-3] > counts[1e-1]


@pytest.mark.slow
def test_beta_continuation_warm_start():
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(
            beta=1e-3, beta_continuation=(1e-1, 1e-2), n_t=4, max_newton=4, gtol=1e-2, max_cg=30
        )
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    assert out["residual_rel"] < 0.6
    assert out["det_min"] > 0.0

"""Documentation consistency: the measured records and the documents that
cite them must not drift apart.

Every ``BENCH_*.json`` committed at the repo root is a measured artefact
(written by ``python -m benchmarks.run``) that EXPERIMENTS.md folds into
the paper's tables — a record nobody references is either dead weight or
a table the docs forgot.  Cheap structural pins only; the numeric pins
live next to the suites that produce each record (``test_interp_plan.py``,
``test_multilevel.py``).
"""
import glob
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(name: str) -> str:
    path = os.path.join(ROOT, name)
    assert os.path.exists(path), f"{name} missing from repo root"
    with open(path) as f:
        return f.read()


def test_every_bench_record_is_referenced_from_experiments():
    experiments = _read("EXPERIMENTS.md")
    records = sorted(
        os.path.basename(p) for p in glob.glob(os.path.join(ROOT, "BENCH_*.json"))
    )
    assert records, "no BENCH_*.json records at repo root"
    missing = [r for r in records if r not in experiments]
    assert not missing, f"EXPERIMENTS.md does not reference: {missing}"


def test_experiments_citations_exist():
    """Files EXPERIMENTS.md points at (benchmarks, scripts, results) exist."""
    experiments = _read("EXPERIMENTS.md")
    for rel in ("benchmarks/README.md", "ROADMAP.md", "docs/ARCHITECTURE.md"):
        assert rel in experiments, f"EXPERIMENTS.md should cross-reference {rel}"
        assert os.path.exists(os.path.join(ROOT, rel)), f"{rel} missing"


def test_architecture_doc_names_the_layers():
    arch = _read(os.path.join("docs", "ARCHITECTURE.md"))
    for module in ("core", "kernels", "dist", "multilevel", "launch", "blocks"):
        assert f"{module}/" in arch, (
            f"docs/ARCHITECTURE.md should map the {module} layer"
        )

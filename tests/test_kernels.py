"""Pallas kernel sweeps vs pure-jnp oracles (deliverable c).

tricubic: shapes x dtypes x halos (also in test_interp.py);
spectral_diag: the fused biharmonic diagonal vs numpy k-grids.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grid import make_grid
from repro.kernels import ref
from repro.kernels.spectral_diag import biharmonic_scale_pallas
from repro.kernels.tricubic import tricubic_displace_pallas


@pytest.mark.parametrize("n", [(8, 16, 128), (16, 8, 256)])
@pytest.mark.parametrize("betas", [(1.0,), (1e-2, 1.0)])
def test_spectral_diag_matches_kgrid(rng, n, betas):
    grid = make_grid(n)
    k1, k2, k3 = grid.k_grids(rfft_last=False)
    ksq = (k1**2 + k2**2 + k3**2).astype(np.float32)
    re = jnp.asarray(rng.standard_normal(n), jnp.float32)
    im = jnp.asarray(rng.standard_normal(n), jnp.float32)
    out_re, out_im = biharmonic_scale_pallas(re, im, betas=betas, tile=(8, 128), interpret=True)
    for c, beta in enumerate(betas):
        sym = beta * ksq**2
        np.testing.assert_allclose(out_re[c], np.asarray(re) * sym, rtol=2e-5)
        np.testing.assert_allclose(out_im[c], np.asarray(im) * sym, rtol=2e-5)


def test_spectral_diag_is_reg_apply(rng):
    """Kernel output ifft'd == SpectralOps.reg_apply (the paper's operator)."""
    from repro.core.spectral import SpectralOps

    n = (8, 16, 128)
    grid = make_grid(n)
    ops = SpectralOps(grid)
    f = jnp.asarray(rng.standard_normal(n), jnp.float32)
    spec = jnp.fft.fftn(f)
    out_re, out_im = biharmonic_scale_pallas(
        spec.real.astype(jnp.float32), spec.imag.astype(jnp.float32),
        betas=(1e-2,), tile=(8, 128), interpret=True,
    )
    got = jnp.fft.ifftn(out_re[0] + 1j * out_im[0]).real
    np.testing.assert_allclose(got, ops.reg_apply(f, 1e-2), atol=2e-2, rtol=1e-3)


@pytest.mark.parametrize("halo", [2, 4, 6])
def test_tricubic_pallas_halo_sweep(rng, halo):
    shape, tile = (16, 16, 32), (8, 8, 16)
    f = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    d = jnp.asarray(rng.uniform(-halo + 0.05, halo - 0.05, (3,) + shape), jnp.float32)
    out = tricubic_displace_pallas(f, d, tile=tile, halo=halo, interpret=True)
    np.testing.assert_allclose(out, ref.tricubic_displace(f, d), atol=2e-5, rtol=1e-4)


def test_tricubic_pallas_zero_disp_exact(rng):
    shape = (8, 8, 32)
    f = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    out = tricubic_displace_pallas(f, jnp.zeros((3,) + shape), tile=(4, 4, 16), halo=2, interpret=True)
    np.testing.assert_allclose(out, f, atol=1e-6)

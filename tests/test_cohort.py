"""Cohort-parallel registration (gn.solve_cohort + launch.reg_serve).

Acceptance pins:
* an S=4 cohort matches 4 independent ``gn.solve`` runs — per-subject
  velocities within fp tolerance AND identical Newton/PCG iteration counts
  (the masked per-subject recursions reproduce independent trajectories);
* per-subject masked termination retires early-convergers without
  perturbing the rest;
* ONE compiled executable serves a whole continuation schedule / serve
  session (beta, image stacks, active mask are traced);
* on the 2x4 mesh, one cohort Newton program issues the same all-to-all
  count as one single-subject program — strictly fewer than 4 single
  solves' worth (slow/dist, via subprocess).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from conftest import run_multidevice as _run  # noqa: E402

from repro.core import gauss_newton as gn  # noqa: E402
from repro.data.synthetic import synthetic_problem  # noqa: E402

AMPS = (0.2, 0.6, 1.0, 1.4)  # spread convergence speeds across the cohort
CFG = gn.GNConfig(beta=1e-2, n_t=2, max_newton=8, gtol=1e-2, max_cg=20)


@pytest.fixture(scope="module")
def cohort_and_singles():
    probs = [synthetic_problem(12, n_t=2, amplitude=a) for a in AMPS]
    grid = probs[0][3]
    singles = [gn.solve(rR, rT, grid, CFG) for rR, rT, _, _ in probs]
    rho_R = jnp.stack([p[0] for p in probs])
    rho_T = jnp.stack([p[1] for p in probs])
    cohort = gn.solve_cohort(rho_R, rho_T, grid, CFG)
    return grid, rho_R, rho_T, singles, cohort


def test_cohort_matches_independent_solves(cohort_and_singles):
    _, _, _, singles, cohort = cohort_and_singles
    for s, single in enumerate(singles):
        dv = float(jnp.max(jnp.abs(cohort["v"][s] - single["v"])))
        ref = max(float(jnp.max(jnp.abs(single["v"]))), 1e-30)
        assert dv / ref < 5e-4, (s, dv / ref)
        # identical masked trajectories: same Newton count, same PCG billing
        assert cohort["newton_iters"][s] == single["newton_iters"], s
        assert cohort["hessian_matvecs"][s] == single["hessian_matvecs"], s


def test_masked_termination_retires_early_convergers(cohort_and_singles):
    _, _, _, singles, cohort = cohort_and_singles
    iters = cohort["newton_iters"]
    # the amplitude spread guarantees a genuine early retirement
    assert min(iters) < max(iters), iters
    # a retired subject stops accruing matvecs: every iteration after its
    # retirement logs 0 cg_iters and 0 step for it
    for s in range(len(iters)):
        post = [rec for rec in cohort["history"] if rec["iter"] >= iters[s]]
        assert all(rec["cg_iters"][s] == 0 for rec in post), s
        assert all(not rec["active"][s] for rec in post), s


def test_single_executable_across_continuation(cohort_and_singles):
    grid, rho_R, rho_T, _, cohort = cohort_and_singles
    assert cohort["compiled_executables"] == 1
    # a full continuation schedule (two betas) still compiles ONE program:
    # beta is a traced argument all the way through the spectral scales
    cfg = gn.GNConfig(beta=1e-3, beta_continuation=(1e-2,), n_t=2,
                      max_newton=3, gtol=1e-2, max_cg=10)
    res = gn.solve_cohort(rho_R, rho_T, grid, cfg)
    assert res["compiled_executables"] == 1


def test_inactive_subjects_are_frozen_and_free(cohort_and_singles):
    grid, rho_R, rho_T, singles, _ = cohort_and_singles
    active = jnp.asarray([True, False, True, False])
    res = gn.solve_cohort(rho_R, rho_T, grid, CFG, active=active)
    for s in (1, 3):  # never-active: zero velocity, zero billing
        assert float(jnp.max(jnp.abs(res["v"][s]))) == 0.0
        assert res["newton_iters"][s] == 0
        assert res["hessian_matvecs"][s] == 0
    for s in (0, 2):  # live subjects unperturbed by the frozen ones
        dv = float(jnp.max(jnp.abs(res["v"][s] - singles[s]["v"])))
        ref = max(float(jnp.max(jnp.abs(singles[s]["v"]))), 1e-30)
        assert dv / ref < 5e-4, s
        assert res["newton_iters"][s] == singles[s]["newton_iters"]


def test_serve_refill_one_executable(cohort_and_singles):
    from repro.launch.reg_serve import CohortServer, RegJob

    grid, rho_R, rho_T, singles, _ = cohort_and_singles
    server = CohortServer(grid, CFG, slots=2)
    server.admit(*(RegJob(job_id=s, rho_R=rho_R[s], rho_T=rho_T[s])
                   for s in range(rho_R.shape[0])))
    results = {r.job_id: r for r in server.run()}
    assert len(results) == 4
    # slot refills never recompile: one executable for the whole session
    assert server.compiled_executables() == 1
    for s, single in enumerate(singles):
        r = results[s]
        assert r.converged, s
        # per-subject billing matches the job's own independent solve
        assert r.newton_iters == single["newton_iters"], s
        assert r.hessian_matvecs == single["hessian_matvecs"], s
        dv = float(np.max(np.abs(r.v - np.asarray(single["v"]))))
        ref = max(float(jnp.max(jnp.abs(single["v"]))), 1e-30)
        assert dv / ref < 5e-4, s


def test_retirement_status_splits_converged_from_max_newton(cohort_and_singles):
    """Regression for the JobResult.converged=False conflation: the explicit
    ``status`` field distinguishes a clean convergence from an iteration-cap
    exit (and JobEvent carries the same reason)."""
    from repro.launch.reg_serve import CohortServer, RegJob
    from repro import telemetry

    grid, rho_R, rho_T, _, _ = cohort_and_singles
    # iteration cap too small to converge the hardest subject
    capped = gn.GNConfig(beta=1e-2, n_t=2, max_newton=1, gtol=1e-6, max_cg=20)
    server = CohortServer(grid, capped, slots=2)
    server.admit(RegJob(job_id="hard", rho_R=rho_R[3], rho_T=rho_T[3]))
    with telemetry.ListSink() as sink:
        res = server.run()[0]
    assert not res.converged and res.status == "max_newton"
    assert res.attempts == 1
    job_recs = [r for r in sink.records if r["kind"] == "job"]
    assert job_recs[0]["status"] == "max_newton"

    server2 = CohortServer(grid, CFG, slots=2)
    server2.admit(RegJob(job_id="easy", rho_R=rho_R[0], rho_T=rho_T[0]))
    res2 = server2.run()[0]
    assert res2.converged and res2.status == "converged"


def test_server_rejects_continuation():
    from repro.launch.reg_serve import CohortServer

    grid = synthetic_problem(12, n_t=2)[3]
    cfg = gn.GNConfig(beta_continuation=(1e-1,), n_t=2)
    with pytest.raises(ValueError):
        CohortServer(grid, cfg, slots=2)


def test_cohort_requires_gauss_newton():
    grid = synthetic_problem(12, n_t=2)[3]
    cfg = gn.GNConfig(n_t=2, gauss_newton=False)
    with pytest.raises(NotImplementedError):
        gn.make_cohort_step(grid, cfg)


# --------------------------------------------------------------------------- #
# distributed: the collective-amortization claim, counted in compiled HLO
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.dist
def test_cohort_collectives_beat_independent_solves_on_mesh():
    """One S=4 cohort Newton program on the 2x4 mesh issues the SAME
    all-to-all/ppermute count as one single-subject program — i.e. strictly
    fewer collectives than the 4 programs of 4 independent solves — and its
    velocities match the local cohort."""
    _run(
        """
        from functools import partial
        from repro.core import objective as obj, gauss_newton as gn
        from repro.core.spectral import SpectralOps
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.data.synthetic import synthetic_problem

        probs = [synthetic_problem(16, n_t=2, amplitude=a) for a in (0.4, 0.7, 0.9, 1.0)]
        grid = probs[0][3]
        rho_R = jnp.stack([p[0] for p in probs])
        rho_T = jnp.stack([p[1] for p in probs])
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        cfg = gn.GNConfig(n_t=2, max_cg=10)

        def count(txt, op):
            return sum(1 for l in txt.splitlines() if op in l and "=" in l)

        prob_1 = obj.Problem(grid, ctx.shard_scalar(probs[0][0]),
                             ctx.shard_scalar(probs[0][1]), 1e-2, 2, False)
        single = jax.jit(partial(gn.newton_iteration, prob=prob_1, ops=ctx.ops,
                                 cfg=cfg, interp=ctx.interp))
        v1 = jnp.zeros((3,) + grid.shape, jnp.float32)
        txt1 = single.lower(ctx.shard_vector(v1), jnp.float32(1)).compile().as_text()

        prob_c = obj.Problem(grid, rho_R, rho_T, 1e-2, 2, False)
        coh = jax.jit(partial(gn.newton_iteration_cohort, prob=prob_c, ops=ctx.ops,
                              cfg=cfg, interp=ctx.interp))
        vc = jnp.zeros((4, 3) + grid.shape, jnp.float32)
        gf = jnp.full((4,), 1e-30, jnp.float32)
        act = jnp.ones((4,), bool)
        lowered = coh.lower(vc, gf, act)
        txt4 = lowered.compile().as_text()

        for op in ("all-to-all", "collective-permute"):
            c1, c4 = count(txt1, op), count(txt4, op)
            # the cohort program's collective count is independent of S: the
            # S=4 program stays under TWO single programs' worth (vs the 4x
            # of 4 independent solves) — the whole exchange/transform stack
            # rides once per call regardless of cohort size
            assert c4 < 2 * c1, (op, c1, c4)

        # numerics: mesh cohort step == local cohort step
        local = SpectralOps(grid)
        prob_l = obj.Problem(grid, rho_R, rho_T, 1e-2, 2, False)
        vl, ll = jax.jit(partial(gn.newton_iteration_cohort, prob=prob_l,
                                 ops=local, cfg=cfg))(vc, gf, act)
        vd, ld = coh(vc, gf, act)
        assert float(jnp.max(jnp.abs(vl - vd))) < 1e-4
        assert np.array_equal(np.asarray(ll.cg_iters), np.asarray(ld.cg_iters))
        """
    )

"""ISSUE 5 tentpole pins: transform coalescing, the pipelined pencil FFT's
collective structure, and the sharded multilevel prolongation.

Three layers of regression:

* the GN Hessian matvec's HLO-counted all-to-alls are >= 2x below the
  uncoalesced composition (``reg_apply`` + ``leray`` as separate round
  trips — what the pre-coalescing code issued), the FFT-side mirror of
  PR 3's ppermute-count pin;
* ``transfer.prolong`` lowers WITHOUT a coarse-spectrum all-gather on the
  folded multi-pod pencil axis (the ROADMAP pathology: 74 MB/chip at
  256^3 on 2x16x16 from the old ``.at[idx].set`` scatter);
* the V-cycle's spectrum-level split/merge equals the field-level
  composition it replaced, and the committed ``BENCH_fft.json`` record
  keeps the measured structure.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_fused_elliptic_flag_deprecated():
    """The coalesced elliptic assembly is unconditional; the old opt-in flag
    is a documented no-op that warns (and its dead plumbing — the ``fused=``
    kwargs of ``core.objective`` — is gone)."""
    import inspect
    import warnings

    from repro.core import gauss_newton as gn
    from repro.core import objective as obj

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        gn.GNConfig(fused_elliptic=True)
    assert any(issubclass(w.category, DeprecationWarning) for w in rec), rec
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        gn.GNConfig()
    assert not rec, [str(w.message) for w in rec]
    for fn in (obj.newton_state, obj.gn_hessian_matvec):
        assert "fused" not in inspect.signature(fn).parameters


# --------------------------------------------------------------------------- #
# mesh pins (subprocess, 8 placeholder devices)
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.dist
def test_gn_matvec_coalesced_all_to_all_pin():
    """The acceptance metric: counted all-to-alls per incompressible GN
    Hessian matvec, coalesced vs the uncoalesced composition — >= 2x."""
    run_multidevice(
        """
        from repro.core import objective as obj, semilag
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=2)
        rng = np.random.default_rng(0)
        prob = obj.Problem(
            grid,
            ctx.shard_scalar(jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)),
            ctx.shard_scalar(jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)),
            1e-2, 2, True,
        )
        v = jax.device_put(
            0.1 * jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
            ctx.vector_sharding())
        p = jax.device_put(
            jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
            ctx.vector_sharding())
        state = jax.jit(lambda vv: obj.newton_state(vv, prob, ctx.ops, ctx.interp))(v)

        def coalesced(p):
            return obj.gn_hessian_matvec(p, state, prob, ctx.ops, ctx.interp)

        def composed(p):  # the pre-coalescing elliptic assembly
            rho1_t = semilag.transport_inc_state(
                p, state.grad_rho_series, state.plan, ctx.interp)
            lamt = semilag.transport_inc_adjoint(-rho1_t, state.plan, ctx.interp)
            bt = semilag.time_integral_b(lamt, state.grad_rho_series, state.plan.dt)
            return ctx.ops.reg_apply(p, prob.beta) + ctx.ops.leray(bt)

        def a2a(fn):
            txt = jax.jit(fn).lower(p).compile().as_text()
            return sum(1 for l in txt.splitlines() if "all-to-all" in l and "=" in l)

        n_co, n_cm = a2a(coalesced), a2a(composed)
        assert n_co > 0, n_co
        assert 2 * n_co <= n_cm, (n_co, n_cm)
        # identical operator up to packed-transform f32 roundoff
        ref = jax.jit(composed)(p)
        err = float(jnp.max(jnp.abs(jax.jit(coalesced)(p) - ref))
                    / jnp.maximum(jnp.max(jnp.abs(ref)), 1.0))
        assert err < 1e-3, err
        """
    )


@pytest.mark.slow
@pytest.mark.dist
def test_spectral_batch_coalesces_on_mesh():
    """One SpectralBatch ride pair replaces K eager round trips: counted
    all-to-alls drop accordingly and every handle matches its eager op."""
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=2)
        ops = ctx.ops
        rng = np.random.default_rng(1)
        v = jax.device_put(
            jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
            ctx.vector_sharding())

        def eager(v):
            return ops.div(v), ops.reg_apply(v, 1e-2), ops.laplacian(v)

        def coalesced(v):
            with ops.batch() as sb:
                d, r, l = sb.div(v), sb.reg_apply(v, 1e-2), sb.laplacian(v)
            return d.get(), r.get(), l.get()

        def a2a(fn):
            txt = jax.jit(fn).lower(v).compile().as_text()
            return sum(1 for l in txt.splitlines() if "all-to-all" in l and "=" in l)

        n_e, n_c = a2a(eager), a2a(coalesced)
        assert n_c > 0 and 2 * n_c <= n_e, (n_c, n_e)
        for a, b in zip(jax.jit(eager)(v), jax.jit(coalesced)(v)):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-3
        """
    )


@pytest.mark.slow
@pytest.mark.dist
def test_prolong_stays_sharded_on_folded_multipod_axis():
    """The ROADMAP multi-pod pathology, pinned at PRODUCTION mesh scale
    (GSPMD's cost model replicates toy-sized spectra regardless, so the
    8-device meshes cannot discriminate): on the 16x16 and folded-axis
    2x16x16 meshes the zero-pad of the coarse spectrum must lower to
    sharded slice/pad + all-to-all — NEVER an all-gather OR all-reduce of
    the spectrum (the old `.at[idx].set` scatter all-gathered 1.2 MB/chip
    even at 64^3; 74 MB/chip at 256^3).  Lowering only — nothing runs."""
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_production_mesh
        from repro.multilevel import transfer
        from repro.analysis.roofline import parse_collective_bytes

        gf, gc = make_grid((64,) * 3), make_grid((32,) * 3)
        for multi_pod in (True, False):
            mesh = make_production_mesh(multi_pod=multi_pod)
            axes = (("pod", "data"), "model") if multi_pod else ("data", "model")
            ctx_f = DistContext(gf, mesh, axes=axes, halo=4)
            ctx_c = ctx_f.coarsen(gc.shape)
            pv = jax.ShapeDtypeStruct(
                (3,) + gc.shape, jnp.float32, sharding=ctx_c.vector_sharding())
            fv = jax.ShapeDtypeStruct(
                (3,) + gf.shape, jnp.float32, sharding=ctx_f.vector_sharding())
            pro = jax.jit(
                lambda x: transfer.prolong(x, ctx_c.ops, ctx_f.ops)).lower(pv).compile()
            res = jax.jit(
                lambda x: transfer.restrict(x, ctx_f.ops, ctx_c.ops)).lower(fv).compile()
            for name, comp in [("prolong", pro), ("restrict", res)]:
                coll = parse_collective_bytes(comp.as_text())
                assert coll["all-gather"]["count"] == 0, (name, multi_pod, coll)
                assert coll["all-reduce"]["count"] == 0, (name, multi_pod, coll)
                assert coll["all-to-all"]["count"] > 0, (name, multi_pod, coll)
        """,
        devices=512,
    )


# --------------------------------------------------------------------------- #
# local: the V-cycle's spectrum-level split/merge vs the field composition
# --------------------------------------------------------------------------- #
def test_vcycle_split_merge_matches_field_composition(rng):
    """One application of the rewritten V-cycle level (2 fine + 2 coarse
    rides) equals the old field-level composition (restrict, prolong,
    precond_apply, leray as separate round trips) it replaced."""
    from repro.core import gauss_newton as gn
    from repro.core import objective as obj
    from repro.core.grid import make_grid
    from repro.core.spectral import SpectralOps
    from repro.data import synthetic
    from repro.multilevel import transfer
    from repro.multilevel.precond import make_vcycle_precond

    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16, incompressible=True)
    ops_f, ops_c = SpectralOps(grid), SpectralOps(make_grid(8))
    prob = obj.Problem(grid, rho_R, rho_T, 1e-3, 4, True)
    state = obj.newton_state(0.4 * v_star, prob, ops_f)
    apply_new = make_vcycle_precond(prob, [ops_c, ops_f], n_cg=3, n_cg_coarse=3)(
        state, prob
    )

    from repro.multilevel.precond import restrict_state

    st_c, pr_c = restrict_state(state, prob, ops_f, ops_c)

    def apply_old(r):  # the pre-spectrum-level composition
        r_c = transfer.restrict(r, ops_f, ops_c)
        r_high = r - transfer.prolong(r_c, ops_c, ops_f)
        r_c = ops_c.leray(r_c)
        sol = gn.pcg(
            matvec=lambda p: obj.gn_hessian_matvec(p, st_c, pr_c, ops_c),
            b=r_c,
            precond=lambda x: ops_c.leray(ops_c.precond_apply(x, prob.beta)),
            inner=ops_c.grid.inner,
            rtol=0.0,
            max_iter=3,
        )
        z = transfer.prolong(sol.x, ops_c, ops_f)
        z = z + ops_f.precond_apply(r_high, prob.beta)
        return ops_f.leray(z)

    r = jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32)
    z_new, z_old = apply_new(r), apply_old(r)
    scale = float(jnp.max(jnp.abs(z_old)))
    err = float(jnp.max(jnp.abs(z_new - z_old)))
    assert err < 1e-3 * max(scale, 1.0), (err, scale)


# --------------------------------------------------------------------------- #
# committed benchmark record (written by `benchmarks.run --suite fft`)
# --------------------------------------------------------------------------- #
def test_bench_fft_record():
    path = os.path.join(ROOT, "BENCH_fft.json")
    assert os.path.exists(path), "run: PYTHONPATH=src python -m benchmarks.run --suite fft"
    rec = json.load(open(path))
    a2a = rec["mesh"]["all_to_alls"]
    # the acceptance pin, as measured and committed
    assert a2a["gn_matvec_coalesced"] > 0
    assert 2 * a2a["gn_matvec_coalesced"] <= a2a["gn_matvec_composed"], a2a
    assert 2 * a2a["stage_a_coalesced"] <= a2a["stage_a_eager"], a2a
    pf = rec["mesh"]["packed_fwd"]
    assert pf["a2a_bytes_packed"] < pf["a2a_bytes_unpacked"], pf
    assert rec["mesh"]["chunks"], rec["mesh"]
    for row in rec["mesh"]["chunks"]:
        assert row["fwd_max_err"] < 1e-3, row
        assert row["a2a_count"] > 0, row
    assert rec["mesh"]["gn_matvec_rel_err"] < 1e-3
    assert rec["single_device"]["max_err"] < 1e-3
    # ISSUE 8 pins: the committed record carries the Armijo-trial ride saving
    # and the chunk-sweep winner that seeds the tuning cache
    at = rec["mesh"]["armijo_trial"]
    assert at["a2a_composed"] - at["a2a_parseval"] >= 2, at
    assert at["rel_err"] < 1e-4, at
    cw = rec["mesh"]["chunk_winner"]
    assert cw["auto_resolved_fields"] >= 1, cw
    assert any(r["label"] == cw["label"] for r in rec["mesh"]["chunks"]), cw


@pytest.mark.slow
@pytest.mark.dist
def test_armijo_trial_drops_transform_ride_pin():
    """ISSUE 8 satellite (Parseval lever): a line-search objective trial
    evaluates the regularization energy as a spectrum-side reduction on the
    forward ride, so an incompressible Armijo trial counts one full
    transform ride (2 all-to-alls on the 2x4 mesh) FEWER than the
    pre-Parseval composition reg = 0.5 <v, A v> — at identical J."""
    run_multidevice(
        """
        from repro.core import objective as obj, semilag
        from repro.core.grid import make_grid
        from repro.core.planner import make_plan
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=4, autotune="off")
        rng = np.random.default_rng(7)
        prob = obj.Problem(
            grid,
            ctx.shard_scalar(jnp.asarray(np.exp(0.2 * rng.standard_normal(grid.shape)), jnp.float32)),
            ctx.shard_scalar(jnp.asarray(np.exp(0.2 * rng.standard_normal(grid.shape)), jnp.float32)),
            1e-2, 2, True,
        )
        v = jax.device_put(
            0.05 * jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32),
            ctx.vector_sharding())

        def trial_new(vv):  # the Armijo trial gn.newton_iteration runs
            jval, _ = obj.evaluate_objective(vv, prob, ctx.ops, ctx.interp)
            return jval

        def trial_old(vv):  # pre-Parseval: reg needs a dedicated inverse ride
            reg = 0.5 * grid.inner(vv, ctx.ops.reg_apply(vv, prob.beta))
            plan = make_plan(vv, grid, ctx.ops, prob.n_t, prob.incompressible,
                             ctx.interp, adjoint=False)
            rho1 = semilag.transport_state(prob.rho_T, plan, ctx.interp)[-1]
            return 0.5 * grid.inner(rho1 - prob.rho_R, rho1 - prob.rho_R) + reg

        def a2a(fn):
            txt = jax.jit(fn).lower(v).compile().as_text()
            return sum(1 for l in txt.splitlines() if "all-to-all" in l and "=" in l)

        n_new, n_old = a2a(trial_new), a2a(trial_old)
        assert n_new > 0, n_new
        assert n_old - n_new >= 2, (n_old, n_new)
        j_new = float(jax.jit(trial_new)(v))
        j_old = float(jax.jit(trial_old)(v))
        assert abs(j_new - j_old) <= 1e-4 * max(abs(j_old), 1.0), (j_new, j_old)
        """
    )

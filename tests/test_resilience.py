"""Chaos suite: fault-tolerant solve & serve (repro.resilience).

Acceptance pins (ISSUE 10):
* NaN injected mid-cohort: the sick subject is caught in-graph
  (``status="nonfinite"``), frozen finite, and retried through the
  degradation ladder to completion — while every un-faulted job's
  velocity is BIT-IDENTICAL to the fault-free run (per-lane independence
  of the masked cohort recursions);
* ONE compiled executable across injection / retirement / retry churn —
  the beta-only degrade rung re-uses the primary bucket's program;
* kill the serve loop at an arbitrary step, resume from the latest
  snapshot: only unfinished jobs are re-served and every job's final
  velocity and billing equal the uninterrupted run's.
"""
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro import telemetry  # noqa: E402
from repro.core import gauss_newton as gn  # noqa: E402
from repro.data.synthetic import synthetic_problem  # noqa: E402
from repro.launch.reg_serve import RegJob, serve_jobs  # noqa: E402
from repro.resilience import health  # noqa: E402
from repro.resilience.atomic import atomic_write_json  # noqa: E402
from repro.resilience.faults import (  # noqa: E402
    KillAt,
    NaNInjector,
    SimulatedCrash,
)
from repro.resilience.policy import (  # noqa: E402
    DEFAULT_LADDER,
    RetryPolicy,
    static_key,
)

AMPS = (0.2, 0.6, 1.0, 1.4)
CFG = gn.GNConfig(beta=1e-2, n_t=2, max_newton=8, gtol=1e-2, max_cg=20)


@pytest.fixture(scope="module")
def problems():
    probs = [synthetic_problem(12, n_t=2, amplitude=a) for a in AMPS]
    return probs[0][3], probs  # grid, [(rho_R, rho_T, v*, grid)...]


def _jobs(probs):
    return [
        RegJob(job_id=f"job{s}", rho_R=p[0], rho_T=p[1])
        for s, p in enumerate(probs)
    ]


@pytest.fixture(scope="module")
def baseline(problems):
    _, probs = problems
    return serve_jobs(_jobs(probs), CFG, slots=2)


# --------------------------------------------------------------------------- #
# in-graph guard (solver level)
# --------------------------------------------------------------------------- #
def test_guard_flags_nan_input_and_freezes(problems):
    grid, probs = problems
    rho_R, rho_T = probs[0][0], probs[0][1]
    out = gn.solve(rho_R, rho_T.at[0, 0, 0].set(jnp.nan), grid, CFG)
    assert out["status"] == "nonfinite"
    # guard short-circuits the stage: no silent max_newton spin
    assert len(out["history"]) == 1
    # the returned iterate is the last good one (here: the zero init)
    assert np.isfinite(np.asarray(out["v"])).all()


def test_guard_cohort_isolates_sick_subject(problems):
    grid, probs = problems
    R = jnp.stack([probs[0][0], probs[1][0]])
    T_good = jnp.stack([probs[0][1], probs[1][1]])
    T_bad = T_good.at[1].set(jnp.nan)
    good = gn.solve_cohort(R, T_good, grid, CFG)
    bad = gn.solve_cohort(R, T_bad, grid, CFG)
    assert bad["status"][1] == "nonfinite"
    assert np.isfinite(np.asarray(bad["v"])).all()
    # the healthy lane is bit-identical despite its poisoned neighbor:
    # batched transforms/reductions are per-lane independent and frozen
    # lanes are masked out of every update
    np.testing.assert_array_equal(np.asarray(bad["v"][0]), np.asarray(good["v"][0]))
    assert bad["newton_iters"][0] == good["newton_iters"][0]
    assert bad["hessian_matvecs"][0] == good["hessian_matvecs"][0]


def test_guard_splits_stagnation_from_divergence():
    # identical images: J(0) is already the minimum -> first step stagnates
    # benignly (roundoff increases stay under DIVERGE_RTOL)
    rho_R, _, _, grid = synthetic_problem(12, n_t=2, amplitude=0.5)
    out = gn.solve(rho_R, rho_R, grid, CFG)
    assert out["status"] in ("converged", "stagnated")
    assert health.DIVERGE_RTOL > 0


# --------------------------------------------------------------------------- #
# retry policy (pure functions)
# --------------------------------------------------------------------------- #
def test_policy_beta_rung_shares_executable_key():
    d2 = RetryPolicy().degraded(CFG, 2)
    assert d2.beta == pytest.approx(CFG.beta * DEFAULT_LADDER[0].beta_scale)
    # rung 1 is beta-only: same static (compiled-in) identity
    assert static_key(d2) == static_key(CFG)
    d3 = RetryPolicy().degraded(CFG, 3)
    assert d3.field_dtype == "float32" and d3.interp_method == "ref"
    assert d3.max_line_search >= 20
    assert static_key(d3) != static_key(CFG)
    # pure in (cfg, attempt): resume re-derives identical bucket configs
    assert RetryPolicy().degraded(CFG, 3) == d3
    assert RetryPolicy().degraded(CFG, 1) is CFG


# --------------------------------------------------------------------------- #
# chaos: NaN injection mid-serve
# --------------------------------------------------------------------------- #
def test_nan_injection_isolated_retried_one_executable(problems, baseline):
    _, probs = problems
    fault = NaNInjector(job_id="job1", field="v", at_iteration=1)
    with telemetry.ListSink() as sink:
        out = serve_jobs(
            _jobs(probs), CFG, slots=2,
            retry=RetryPolicy(max_attempts=2), faults=[fault],
        )
    assert fault.fired
    res = {r.job_id: r for r in out["results"]}
    ref = {r.job_id: r for r in baseline["results"]}
    assert set(res) == set(ref)

    # the faulted job was caught in-graph, retried degraded, and completed
    assert res["job1"].attempts == 2
    assert res["job1"].status not in health.FAILED_NAMES
    assert np.isfinite(res["job1"].v).all()

    # un-faulted jobs: bit-identical velocities and identical billing
    for jid in ("job0", "job2", "job3"):
        np.testing.assert_array_equal(res[jid].v, ref[jid].v)
        assert res[jid].newton_iters == ref[jid].newton_iters, jid
        assert res[jid].hessian_matvecs == ref[jid].hessian_matvecs, jid
        assert res[jid].status == ref[jid].status, jid
        assert res[jid].attempts == 1, jid

    # ONE compiled executable across injection/retirement/retry churn:
    # the beta-only rung re-uses the primary bucket's program
    assert out["compiled_executables"] == 1
    retry_keys = [k for k, st in out["buckets"].items() if st["attempt"] > 1]
    assert len(retry_keys) == 1
    assert out["buckets"][retry_keys[0]]["jobs"] == 1

    # typed chaos trace: FaultEvent + RecoveryEvent + per-attempt JobEvents
    kinds = [r["kind"] for r in sink.records]
    assert "fault" in kinds and "recovery" in kinds
    faults_ = [r for r in sink.records if r["kind"] == "fault"]
    assert faults_[0]["fault"] == "nan_injection" and faults_[0]["target"] == "job1"
    recov = [r for r in sink.records if r["kind"] == "recovery"]
    assert recov[0]["action"] == "retry_degraded" and recov[0]["attempts"] == 2
    job_evts = [r for r in sink.records if r["kind"] == "job" and r["job_id"] == "job1"]
    assert [e["attempts"] for e in job_evts] == [1, 2]
    assert job_evts[0]["status"] == "nonfinite"
    for rec in sink.records:
        assert telemetry.validate_record(rec) == [], rec["kind"]


# --------------------------------------------------------------------------- #
# chaos: kill + resume from checkpointed job stream
# --------------------------------------------------------------------------- #
def test_kill_and_resume_reserves_only_unfinished(problems, tmp_path):
    _, probs = problems
    # uninterrupted reference (checkpointing on: identical code path)
    ref_out = serve_jobs(
        _jobs(probs), CFG, slots=2,
        checkpoint=str(tmp_path / "ref"), checkpoint_every=2,
    )
    ref = {r.job_id: r for r in ref_out["results"]}

    ck = str(tmp_path / "ck")
    kill = KillAt(at_iteration=4)
    with pytest.raises(SimulatedCrash):
        serve_jobs(_jobs(probs), CFG, slots=2,
                   checkpoint=ck, checkpoint_every=2, faults=[kill])
    assert kill.fired

    # resume: the snapshot is standalone — the job list is NOT re-passed
    with telemetry.ListSink() as sink:
        out2 = serve_jobs([], CFG, slots=2, checkpoint=ck,
                          checkpoint_every=2, resume=True)
    res = {r.job_id: r for r in out2["results"]}
    assert set(res) == set(ref)
    for jid, r in ref.items():
        np.testing.assert_array_equal(res[jid].v, r.v)
        assert res[jid].newton_iters == r.newton_iters, jid
        assert res[jid].hessian_matvecs == r.hessian_matvecs, jid
        assert res[jid].status == r.status, jid

    # only unfinished jobs were re-served: the resumed session picked up
    # mid-stream (iterations continued, not restarted) and some jobs were
    # already completed in the snapshot
    recov = [r for r in sink.records if r["kind"] == "recovery"]
    assert recov and recov[0]["action"] == "resume_from_checkpoint"
    assert recov[0]["attrs"]["completed"] + recov[0]["attrs"]["unfinished"] == len(probs)
    assert recov[0]["attrs"]["unfinished"] < len(probs)
    shape_key = tuple(np.shape(probs[0][0]))
    assert out2["buckets"][shape_key]["cohort_iterations"] == \
        ref_out["buckets"][shape_key]["cohort_iterations"]
    # jobs completed before the kill emit no new JobEvent on resume
    served_ids = {r["job_id"] for r in sink.records if r["kind"] == "job"}
    assert len(served_ids) == recov[0]["attrs"]["unfinished"]

    # resuming a COMPLETED stream re-serves nothing and returns everything
    out3 = serve_jobs([], CFG, slots=2, checkpoint=ck, resume=True)
    assert {r.job_id for r in out3["results"]} == set(ref)


# --------------------------------------------------------------------------- #
# crash-safe JSON writes
# --------------------------------------------------------------------------- #
def test_atomic_write_json_roundtrip_and_failure_keeps_old(tmp_path):
    import json

    path = str(tmp_path / "nested" / "out.json")
    atomic_write_json(path, {"a": 1})
    assert json.load(open(path)) == {"a": 1}
    # a serialization failure mid-write never touches the real file ...
    with pytest.raises(TypeError):
        atomic_write_json(path, {"bad": object()})
    assert json.load(open(path)) == {"a": 1}
    # ... and leaves no temp debris behind
    assert os.listdir(os.path.dirname(path)) == ["out.json"]


def test_autotune_cache_write_is_atomic(tmp_path, monkeypatch):
    """Concurrent-writer hazard: the cache's temp names are pid-unique."""
    from repro.autotune import cache as ac

    path = str(tmp_path / "tuning.json")
    c = ac.TuningCache(path)
    seen = {}

    real_replace = os.replace

    def spy(src, dst):
        seen["tmp"] = os.path.basename(src)
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", spy)
    c._write({})
    assert seen["tmp"].endswith(f".tmp.{os.getpid()}")

"""repro.autotune: tuning cache robustness, resolver semantics, sweep
persistence, and the mixed-precision (field_dtype) numerics contract.

Fast cases run on the local backend; the mesh legs (tuned-vs-default solver
parity, bf16 registration on a 2x4 pencil mesh) are slow subprocess tests
like the rest of the dist suite.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.autotune import (
    KNOBS_REV,
    SCHEMA_VERSION,
    TunedConfig,
    TuningCache,
    cell_key,
    consult_gn,
    resolve_tuned,
    tuned_replace,
)
from repro.core import gauss_newton as gn
from repro.core import objective as obj
from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps

from conftest import run_multidevice


@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    p = str(tmp_path / "autotune_cache.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", p)
    telemetry.reset_counters()
    yield p
    telemetry.reset_counters()


def _invalid_count():
    return telemetry.counters().get("autotune.cache_invalid", 0.0)


# --------------------------------------------------------------------------- #
# cache file robustness
# --------------------------------------------------------------------------- #
def test_cache_roundtrip(cache_path):
    c = TuningCache()
    key = cell_key((64, 64, 64), 8, 1e-2)
    assert key == "64x64x64/8dev/beta-0.01"
    c.put(key, TunedConfig(chunk=4, interp_method="pallas", mode="wall", cost=1.25))
    t = c.get(key)
    assert t.chunk == 4 and t.interp_method == "pallas" and t.mode == "wall"
    assert c.validate() == []
    # beta-agnostic fallback
    c.put(cell_key((64, 64, 64), 8, None), TunedConfig(chunk=2, mode="wall"))
    assert resolve_tuned((64, 64, 64), 8, beta=3e-3).chunk == 2


def test_cache_corrupt_file_falls_back(cache_path):
    with open(cache_path, "w") as fh:
        fh.write("{this is not json")
    assert TuningCache().get("anything") is None
    assert _invalid_count() >= 1.0
    assert resolve_tuned((8, 8, 8), 1, 1e-2) is None
    assert TuningCache().validate()  # non-empty problem list


def test_cache_schema_version_mismatch_falls_back(cache_path):
    with open(cache_path, "w") as fh:
        json.dump({"schema": SCHEMA_VERSION + 1, "cells": {"k": {}}}, fh)
    assert TuningCache().get("k") is None
    assert _invalid_count() >= 1.0


def test_cache_stale_knobs_rev_falls_back(cache_path):
    cells = {
        cell_key((8, 8, 8), 1, 1e-2): {
            "knobs": {"chunk": 2},
            "mode": "counted",
            "knobs_rev": KNOBS_REV - 1,
        }
    }
    with open(cache_path, "w") as fh:
        json.dump({"schema": SCHEMA_VERSION, "cells": cells}, fh)
    assert resolve_tuned((8, 8, 8), 1, 1e-2) is None
    assert _invalid_count() >= 1.0


def test_cache_rejects_unknown_and_invalid_knobs(cache_path):
    bad_entries = [
        {"knobs": {"warp_factor": 9}, "mode": "counted", "knobs_rev": KNOBS_REV},
        {"knobs": {"chunk": -3}, "mode": "counted", "knobs_rev": KNOBS_REV},
        {"knobs": {"field_dtype": "float8"}, "mode": "counted", "knobs_rev": KNOBS_REV},
        {"knobs": {"interp_method": "cubic"}, "mode": "counted", "knobs_rev": KNOBS_REV},
        {"knobs": {}, "mode": "vibes", "knobs_rev": KNOBS_REV},
    ]
    for entry in bad_entries:
        with open(cache_path, "w") as fh:
            json.dump({"schema": SCHEMA_VERSION, "cells": {"cell": entry}}, fh)
        telemetry.reset_counters()
        assert TuningCache().get("cell") is None, entry
        assert _invalid_count() >= 1.0, entry
        assert TuningCache().validate(), entry


def test_put_refuses_invalid_entry(cache_path):
    with pytest.raises(ValueError):
        TuningCache().put("cell", TunedConfig(chunk="sideways"))


def test_missing_cache_is_valid_and_a_miss(cache_path):
    assert TuningCache().validate() == []
    assert resolve_tuned((8, 8, 8), 1, 1e-2) is None
    assert telemetry.counters().get("autotune.cache_miss", 0.0) >= 1.0


# --------------------------------------------------------------------------- #
# resolver semantics
# --------------------------------------------------------------------------- #
def test_counted_entries_never_apply_dtype_knobs(cache_path):
    c = TuningCache()
    key = cell_key((8, 8, 8), 1, 1e-2)
    c.put(key, TunedConfig(chunk=2, plan_dtype="bfloat16", field_dtype="bfloat16",
                           mode="counted"))
    t = resolve_tuned((8, 8, 8), 1, 1e-2)
    assert t.chunk == 2
    assert t.plan_dtype is None and t.field_dtype is None
    # wall-measured entries do apply them
    c.put(key, TunedConfig(field_dtype="bfloat16", mode="wall"))
    assert resolve_tuned((8, 8, 8), 1, 1e-2).field_dtype == "bfloat16"


def test_tuned_replace_explicit_value_wins(cache_path):
    tuned = TunedConfig(interp_method="pallas", field_dtype="bfloat16", mode="wall")
    defaults = {"interp_method": "ref", "plan_dtype": None, "field_dtype": None}
    cfg = tuned_replace(gn.GNConfig(), tuned, defaults)
    assert cfg.interp_method == "pallas" and cfg.field_dtype == "bfloat16"
    # user-pinned knobs survive
    cfg = tuned_replace(gn.GNConfig(interp_method="auto", field_dtype="float32"),
                        tuned, defaults)
    assert cfg.interp_method == "auto" and cfg.field_dtype == "float32"


def test_consult_gn_cache_hit_skips_sweep(cache_path, monkeypatch):
    """autotune="sweep" must resolve an existing entry WITHOUT re-sweeping."""
    from types import SimpleNamespace

    grid = make_grid((8, 8, 8))
    TuningCache().put(cell_key((8, 8, 8), 4, None),
                      TunedConfig(field_dtype="bfloat16", mode="wall"))
    import repro.autotune.search as search

    def boom(*a, **k):
        raise AssertionError("sweep must not run on a cache hit")

    monkeypatch.setattr(search, "sweep_cell", boom)
    fake_ops = SimpleNamespace(
        fft=SimpleNamespace(mesh=SimpleNamespace(devices=np.zeros(4)),
                            axes=("data", "model"))
    )
    cfg = consult_gn(gn.GNConfig(autotune="sweep"), grid, fake_ops)
    assert cfg.field_dtype == "bfloat16"


def test_gn_autotune_off_ignores_cache(cache_path):
    grid = make_grid((8, 8, 8))
    TuningCache().put(cell_key((8, 8, 8), 1, None),
                      TunedConfig(field_dtype="bfloat16", mode="wall"))
    cfg = gn._tuned_cfg(gn.GNConfig(autotune="off"), grid, None)
    assert cfg.field_dtype is None
    cfg = gn._tuned_cfg(gn.GNConfig(), grid, None)
    assert cfg.field_dtype == "bfloat16"


# --------------------------------------------------------------------------- #
# mixed precision: storage dtype flows, critical accumulations stay f32
# --------------------------------------------------------------------------- #
def _toy_problem(n=12, dtype=jnp.float32):
    grid = make_grid((n, n, n))
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    X, Y, Z = np.meshgrid(x, x, x, indexing="ij")
    rho_R = jnp.asarray(np.exp(np.cos(X) * np.cos(Y)), dtype) / np.e
    rho_T = jnp.asarray(np.exp(np.cos(X - 0.5) * np.cos(Y + 0.3)), dtype) / np.e
    return grid, rho_R, rho_T


def test_field_dtype_flows_to_storage():
    grid, rho_R, rho_T = _toy_problem(8)
    ops = SpectralOps(grid, field_dtype="bfloat16")
    v = jnp.zeros((3,) + grid.shape, jnp.float32)
    assert ops.div(v + 1.0).dtype == jnp.bfloat16
    prob = obj.Problem(grid, rho_R, rho_T, 1e-2, 2, False)
    from repro.kernels import ops as kops

    state = obj.newton_state(v, prob, ops, kops.make_interp(method="ref"))
    assert state.rho_series.dtype == jnp.bfloat16
    assert state.lam_series.dtype == jnp.bfloat16
    # the gradient comes out of the f32 time quadrature — never bf16
    assert state.g.dtype == jnp.float32


def test_pcg_recursion_stays_f32_under_bf16_storage():
    """The critical-accumulation pin: with bf16 field storage the PCG
    residual recursion (what the preconditioner sees every iteration) and
    the returned Newton direction must still be f32."""
    grid, rho_R, rho_T = _toy_problem(8)
    ops = SpectralOps(grid, field_dtype="bfloat16")
    prob = obj.Problem(grid, rho_R, rho_T, 1e-2, 2, False)
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_cg=3, autotune="off",
                      field_dtype="bfloat16")
    seen = []

    def recording_precond(state, prob):
        def pc(r):
            seen.append(r.dtype)
            return ops.precond_project(r, prob.beta, prob.incompressible)

        return pc

    v = jnp.zeros((3,) + grid.shape, jnp.float32)
    v_new, _ = gn.newton_iteration(
        v, jnp.float32(1e-30), prob, ops, cfg, precond=recording_precond
    )
    assert seen, "preconditioner never invoked"
    assert all(d == jnp.float32 for d in seen), seen
    # the bf16 preconditioner output was upcast before seeding p0
    assert ops.precond_project(v + 1.0, 1e-2, False).dtype == jnp.bfloat16
    assert v_new.dtype == jnp.float32


@pytest.mark.slow
def test_bf16_registration_matches_f32_local():
    """ISSUE 8 acceptance: bf16 field storage registers to a residual within
    tolerance of the f32 run at 32^3 on the local backend."""
    from repro.core.registration import RegistrationConfig, register

    grid, rho_R, rho_T = _toy_problem(32)
    base = gn.GNConfig(beta=1e-2, n_t=2, max_newton=4, max_cg=10, autotune="off")
    out32 = register(rho_R, rho_T, RegistrationConfig(solver=base), grid=grid)
    out16 = register(
        rho_R, rho_T,
        RegistrationConfig(solver=dataclasses.replace(base, field_dtype="bfloat16")),
        grid=grid,
    )
    assert out32["residual_rel"] < 0.75
    # bf16 storage must track the f32 solve, not merely "converge somewhat"
    assert abs(out16["residual_rel"] - out32["residual_rel"]) < 0.05, (
        out16["residual_rel"], out32["residual_rel"])
    assert float(jnp.max(jnp.abs(out16["v"] - out32["v"]))) < 0.15


# --------------------------------------------------------------------------- #
# mesh legs (subprocess, 8 placeholder devices)
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.dist
def test_tuned_vs_default_solver_parity_on_mesh(tmp_path):
    """A counted tuning-cache entry (chunked a2a tiling) must not change the
    solve: tuned-consulting and autotune="off" runs agree to roundoff."""
    cache = str(tmp_path / "cache.json")
    run_multidevice(
        f"""
        import os
        os.environ["REPRO_AUTOTUNE_CACHE"] = {cache!r}
        from repro.autotune import TuningCache, TunedConfig, cell_key
        from repro.core import gauss_newton as gn
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        grid = make_grid((16, 16, 32))
        TuningCache().put(cell_key(grid.shape, 8, None),
                          TunedConfig(chunk=2, mode="counted", cost=1.0))
        mesh = make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(TEST_SEED + 3)
        rho_R = jnp.asarray(np.exp(0.3 * rng.standard_normal(grid.shape)), jnp.float32)
        rho_T = jnp.asarray(np.exp(0.3 * rng.standard_normal(grid.shape)), jnp.float32)
        cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=2, max_cg=5, autotune="off")

        outs = {{}}
        for label, at in (("tuned", "cache"), ("off", "off")):
            ctx = DistContext(grid, mesh, halo=4, autotune=at)
            if label == "tuned":
                assert ctx.chunk == 2, ctx.chunk
            else:
                assert ctx.chunk is None, ctx.chunk
            out = gn.solve(ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T),
                           grid, cfg, ops=ctx.ops, interp=ctx.interp)
            outs[label] = np.asarray(out["v"])
        err = float(np.max(np.abs(outs["tuned"] - outs["off"])))
        assert err < 1e-4, err
        """
    )


@pytest.mark.slow
@pytest.mark.dist
def test_bf16_registration_matches_f32_on_mesh():
    """bf16 field storage through the pencil FFT + halo-exchange transport
    path: mesh registration residual within tolerance of the f32 run."""
    run_multidevice(
        """
        import dataclasses
        from repro.core import gauss_newton as gn
        from repro.core.registration import RegistrationConfig, register
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        grid = make_grid((16, 16, 32))
        mesh = make_mesh((2, 4), ("data", "model"))
        x = [np.linspace(0, 2*np.pi, n, endpoint=False) for n in grid.shape]
        X, Y, Z = np.meshgrid(*x, indexing="ij")
        rho_R = jnp.asarray(np.exp(np.cos(X) * np.cos(Y)), jnp.float32) / np.e
        rho_T = jnp.asarray(np.exp(np.cos(X - 0.5) * np.cos(Y + 0.3)), jnp.float32) / np.e
        base = gn.GNConfig(beta=1e-2, n_t=2, max_newton=3, max_cg=8, autotune="off")

        res = {}
        for label, fd in (("f32", None), ("bf16", "bfloat16")):
            ctx = DistContext(grid, mesh, halo=4, autotune="off", field_dtype=fd)
            cfg = RegistrationConfig(solver=base)
            out = register(ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T),
                           cfg, grid=grid, ctx=ctx)
            res[label] = out["residual_rel"]
        assert res["f32"] < 0.9, res
        assert abs(res["bf16"] - res["f32"]) < 0.05, res
        """
    )


# --------------------------------------------------------------------------- #
# committed benchmark record (written by `benchmarks.run --suite autotune`)
# --------------------------------------------------------------------------- #
def test_bench_autotune_record():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_autotune.json")
    assert os.path.exists(path), (
        "run: PYTHONPATH=src python -m benchmarks.run --suite autotune")
    rec = json.load(open(path))
    assert len(rec["cells"]) >= 2, rec.keys()
    for cell in rec["cells"]:
        assert cell["mode"] in ("counted", "wall"), cell
        assert cell["trials"] and "cost" in cell["trials"][0], cell["cell"]
        # defaults are always trialed first; the winner never loses to them
        assert cell["trials"][0]["knobs"] == {}, cell["trials"][0]
        assert cell["cost"] <= cell["trials"][0]["cost"] * (1 + 1e-9), cell["cell"]
        assert cell["layouts"]["winner"], cell["cell"]
    # the acceptance pin: a second run is pure cache resolution, no re-sweep
    assert rec["second_run"], rec.keys()
    for s in rec["second_run"]:
        assert s["resolved_from_cache"], s

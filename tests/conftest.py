"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device; the
multi-device checks live in test_dist.py and spawn subprocesses."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def single_mesh():
    return jax.make_mesh((1,), ("data",))

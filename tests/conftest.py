"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device; the
multi-device checks (test_dist.py, test_pencil_fft.py, test_dist_interp.py)
spawn subprocesses via ``run_multidevice`` because XLA locks the device
count at first jax init.

Markers (fast tier: ``pytest -m "not slow"``, see ROADMAP):
    slow — subprocess-spawning / minutes-long cases
    dist — exercises the multi-device repro.dist path

Randomness: every test draws through the shared seeded fixtures below
(``rng`` for numpy streams, ``jax_key`` for jax PRNG keys), all derived
from ONE session seed.  ``REPRO_TEST_SEED=<int>`` re-seeds the whole
suite — the flake-hunting knob: a failure that appears under one seed
and not another is a tolerance problem, not a logic problem.  ``rng`` is
function-scoped so each test owns a deterministic stream regardless of
which subset of the suite runs (a session-scoped stream made any
``-k``-selected run draw different numbers than the full suite).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: subprocess-spawning or minutes-long test")
    config.addinivalue_line("markers", "dist: exercises the multi-device repro.dist path")


def run_multidevice(body: str, devices: int = 8, timeout: int = 520) -> str:
    """Run a test body in a fresh interpreter with N placeholder devices."""
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(ROOT, "src")!r})
        import jax, jax.numpy as jnp, numpy as np
        TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))
        """
    ) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


@pytest.fixture(scope="session")
def test_seed():
    """The suite-wide base seed (override with REPRO_TEST_SEED=<int>)."""
    return TEST_SEED


@pytest.fixture
def rng(test_seed):
    return np.random.default_rng(test_seed)


@pytest.fixture
def jax_key(test_seed):
    return jax.random.PRNGKey(test_seed)


@pytest.fixture(scope="session")
def single_mesh():
    return jax.make_mesh((1,), ("data",))

"""Plan-once / apply-many interpolation: InterpPlan + batched multi-field
contract (ISSUE 3 tentpole).

Covers, single-device: batched-vs-looped equivalence on the ref oracle and
the Pallas kernel (interpret mode), planned-vs-unplanned equivalence on
both, the ``kernels.ops.Interp`` executor protocol, plan construction and
reuse inside ``SLPlan``, and plan reuse across GN Hessian (PCG) matvecs.
The 8-device mesh counterparts live in ``tests/test_dist_interp.py``.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import objective as obj
from repro.core import semilag
from repro.core.grid import make_grid
from repro.core.planner import make_plan, required_halo
from repro.core.spectral import SpectralOps
from repro.data import synthetic
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.tricubic import tricubic_apply_pallas, tricubic_displace_pallas_many

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(rng, shape=(8, 12, 16), c=4, lim=3.0):
    f = jnp.asarray(rng.standard_normal((c,) + shape), jnp.float32)
    d = jnp.asarray(rng.uniform(-lim, lim, (3,) + shape), jnp.float32)
    return f, d


def _looped(f, d):
    return jnp.stack([ref.tricubic_displace(f[i], d) for i in range(f.shape[0])])


# ----------------------------------------------------------------------- #
# ref oracle
# ----------------------------------------------------------------------- #
def test_batched_matches_looped_ref(rng):
    f, d = _problem(rng)
    np.testing.assert_allclose(
        ref.tricubic_displace_many(f, d), _looped(f, d), atol=1e-4, rtol=1e-4
    )


def test_planned_matches_unplanned_ref(rng):
    f, d = _problem(rng)
    plan = ref.make_interp_plan(d)
    np.testing.assert_allclose(
        ref.interp_apply(f, plan), _looped(f, d), atol=1e-4, rtol=1e-4
    )
    # rank-3 (no channel axis) goes through the same plan
    np.testing.assert_allclose(
        ref.interp_apply(f[0], plan), _looped(f, d)[0], atol=1e-4, rtol=1e-4
    )


def test_plan_halo_need_matches_required_halo(rng):
    _, d = _problem(rng, lim=2.7)
    plan = ref.make_interp_plan(d)
    assert float(plan.halo_need) == float(jnp.ceil(jnp.max(jnp.abs(d))))


def test_plan_apply_padded_matches_global(rng):
    f, d = _problem(rng)
    lo, hi = 5, 6
    fp = jnp.pad(f, ((0, 0), (lo, hi), (lo, hi), (lo, hi)), mode="wrap")
    plan = ref.make_interp_plan(d)
    np.testing.assert_allclose(
        ref.interp_apply_padded(fp, plan, lo), ref.interp_apply(f, plan), atol=1e-5
    )


def test_plan_exact_at_grid_points(rng):
    f = jnp.asarray(rng.standard_normal((2, 8, 8, 8)), jnp.float32)
    plan = ref.make_interp_plan(jnp.zeros((3, 8, 8, 8)))
    np.testing.assert_array_equal(ref.interp_apply(f, plan), f)


# ----------------------------------------------------------------------- #
# Pallas kernel (interpret mode on CPU)
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("c", [1, 3])
def test_pallas_batched_matches_ref(rng, c):
    shape, tile, halo = (16, 16, 32), (8, 8, 16), 4
    f, d = _problem(rng, shape, c=c, lim=halo - 0.1)
    out = tricubic_displace_pallas_many(f, d, tile=tile, halo=halo, interpret=True)
    np.testing.assert_allclose(out, _looped(f, d), atol=1e-4, rtol=1e-4)


def test_pallas_planned_matches_ref(rng):
    shape, tile, halo = (16, 16, 32), (8, 8, 16), 4
    f, d = _problem(rng, shape, c=3, lim=halo - 0.1)
    plan = ref.make_interp_plan(d)
    out = tricubic_apply_pallas(f, plan, tile=tile, halo=halo, interpret=True)
    np.testing.assert_allclose(out, _looped(f, d), atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------- #
# bf16-packed plan weights (make_interp_plan(dtype=...), ROADMAP follow-up)
# ----------------------------------------------------------------------- #
def test_plan_bf16_packing_parity(rng):
    """Packing w to bf16 halves the plan's weight storage; the apply still
    contracts in f32 (output dtype unchanged, error at bf16 rounding level,
    far below the tricubic discretization error)."""
    f, d = _problem(rng)
    p32 = ref.make_interp_plan(d)
    pb = ref.make_interp_plan(d, dtype=jnp.bfloat16)
    assert pb.w.dtype == jnp.bfloat16
    assert pb.ib.dtype == jnp.int32 and pb.halo_need.dtype == jnp.float32
    np.testing.assert_array_equal(pb.ib, p32.ib)
    out32, outb = ref.interp_apply(f, p32), ref.interp_apply(f, pb)
    assert outb.dtype == f.dtype  # contraction upcasts, output stays f32
    np.testing.assert_allclose(outb, out32, atol=5e-2)
    assert float(jnp.max(jnp.abs(outb - out32))) > 0.0  # actually packed


def test_plan_bf16_executor_and_pallas(rng):
    """The flag rides the Interp executor (kernels.ops.make_interp) and the
    Pallas planned kernel (one-hot A-matrices built in f32 from bf16 w)."""
    shape, tile, halo = (16, 16, 32), (8, 8, 16), 4
    f, d = _problem(rng, shape, c=3, lim=halo - 0.1)
    expect = _looped(f, d)
    interp = kops.make_interp(method="ref", plan_dtype=jnp.bfloat16)
    plan = interp.make_plan(d)
    assert plan.w.dtype == jnp.bfloat16
    np.testing.assert_allclose(interp.apply_plan(f, plan), expect, atol=5e-2)
    out_pl = tricubic_apply_pallas(f, plan, tile=tile, halo=halo, interpret=True)
    np.testing.assert_allclose(out_pl, expect, atol=5e-2)


def test_plan_bf16_through_solver_config(gn_setup):
    """GNConfig(plan_dtype="bfloat16") threads the packing into the cached
    SLPlan operators without disturbing the transports beyond rounding."""
    from repro.core import gauss_newton as gn

    g, ops, prob, v = gn_setup
    interp = gn._interp_fn(gn.GNConfig(plan_dtype="bfloat16"))
    plan = make_plan(v, g, ops, 4, incompressible=False, interp=interp)
    assert plan.iplan_fwd.w.dtype == jnp.bfloat16
    assert plan.iplan_adj.w.dtype == jnp.bfloat16
    ref_series = semilag.transport_state(prob.rho_T, make_plan(v, g, ops, 4, False))
    np.testing.assert_allclose(
        semilag.transport_state(prob.rho_T, plan, interp), ref_series, atol=5e-2
    )


# ----------------------------------------------------------------------- #
# ops.Interp executor protocol
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("method", ["ref", "pallas"])
def test_interp_executor_protocol(rng, method):
    shape = (16, 16, 32)
    interp = kops.make_interp(method=method)
    f, d = _problem(rng, shape, c=3, lim=3.9)
    expect = _looped(f, d)
    np.testing.assert_allclose(interp(f, d), expect, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(interp(f[0], d), expect[0], atol=1e-4, rtol=1e-4)
    plan = interp.make_plan(d)
    np.testing.assert_allclose(interp.apply_plan(f, plan), expect, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------- #
# SLPlan integration: plans built once, reused everywhere
# ----------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def gn_setup():
    g = make_grid(16)
    ops = SpectralOps(g)
    rho_R, rho_T, v_star, _ = synthetic.synthetic_problem(16)
    prob = obj.Problem(g, rho_R, rho_T, 1e-2, 4, False)
    return g, ops, prob, 0.4 * v_star


def test_make_plan_builds_interp_plans(gn_setup):
    g, ops, prob, v = gn_setup
    plan = make_plan(v, g, ops, 4, incompressible=False)
    assert plan.iplan_fwd is not None and plan.iplan_adj is not None
    assert plan.iplan_fwd.ib.shape == (3,) + g.shape
    assert plan.iplan_fwd.w.shape == (3, 4) + g.shape
    # cached bound == the planner's recomputed bound
    bare = plan._replace(iplan_fwd=None, iplan_adj=None)
    assert float(required_halo(plan)) == float(required_halo(bare))


def test_transports_planned_equal_unplanned(gn_setup):
    """The planned applier path of semilag._bind is numerically the
    unplanned per-call path (same operators, cached vs rebuilt)."""
    g, ops, prob, v = gn_setup
    plan = make_plan(v, g, ops, 4, incompressible=False)
    bare = plan._replace(iplan_fwd=None, iplan_adj=None)
    rho = prob.rho_T
    np.testing.assert_allclose(
        semilag.transport_state(rho, plan),
        semilag.transport_state(rho, bare),
        atol=1e-4,
    )
    np.testing.assert_allclose(
        semilag.transport_adjoint(rho, plan),
        semilag.transport_adjoint(rho, bare),
        atol=1e-4,
    )
    np.testing.assert_allclose(
        semilag.deformation_displacement(v, plan),
        semilag.deformation_displacement(v, bare),
        atol=1e-4,
    )


def test_gn_matvec_plan_reuse(gn_setup, rng):
    """PCG Hessian matvecs through the cached InterpPlan equal the
    unplanned evaluation — plan reuse across matvecs is exact."""
    g, ops, prob, v = gn_setup
    interp = kops.make_interp(method="ref")
    state = obj.newton_state(v, prob, ops, interp)
    assert state.plan.iplan_fwd is not None  # threaded through newton_state
    state_bare = state._replace(plan=state.plan._replace(iplan_fwd=None, iplan_adj=None))
    for seed in (0, 1):
        vt = jnp.asarray(
            np.random.default_rng(seed).standard_normal((3,) + g.shape), jnp.float32
        )
        hp = obj.gn_hessian_matvec(vt, state, prob, ops, interp)
        hb = obj.gn_hessian_matvec(vt, state_bare, prob, ops, interp)
        np.testing.assert_allclose(hp, hb, atol=1e-4)


# ----------------------------------------------------------------------- #
# committed benchmark record (written by `benchmarks.run --suite interp`)
# ----------------------------------------------------------------------- #
def test_bench_interp_record():
    path = os.path.join(ROOT, "BENCH_interp.json")
    assert os.path.exists(path), "run: PYTHONPATH=src python -m benchmarks.run --suite interp"
    rec = json.load(open(path))
    # (a) batched C-field interp beats C looped calls in wall time at 64^3+
    rows = [r for r in rec["single_device"] if r["n"] >= 64]
    assert rows, rec
    for r in rows:
        assert r["batched_s"] < r["looped_s"], r
        assert r["planned_s"] < r["looped_s"], r
    # (b) counted: one ghost-exchange round per batched mesh call vs C
    mesh = rec["mesh"]
    assert mesh["collective_permutes"]["batched_c3"] == mesh["collective_permutes"]["c1"]
    assert (
        mesh["collective_permutes"]["looped_c3"]
        == 3 * mesh["collective_permutes"]["c1"]
    )


def test_bench_interp_record_bf16_and_pallas_columns():
    """ISSUE 8 satellite: the committed record carries measured bf16-plan
    and batched-Pallas columns next to the f32 planned path."""
    path = os.path.join(ROOT, "BENCH_interp.json")
    assert os.path.exists(path), "run: PYTHONPATH=src python -m benchmarks.run --suite interp"
    rec = json.load(open(path))
    for r in rec["single_device"]:
        # bf16-packed plans are measured on every row and stay within the
        # storage dtype's noise floor (~1e-2 relative)
        assert r["planned_bf16_s"] > 0.0, r
        assert r["planned_bf16_rel_err"] < 3e-2, r
    pallas_rows = [r for r in rec["single_device"] if "pallas_batched_s" in r]
    assert pallas_rows, "no Pallas rows in the committed record"
    for r in pallas_rows:
        assert r["pallas_mode"] in ("tpu", "interpret"), r
        assert r["pallas_rel_err"] < 1e-3, r

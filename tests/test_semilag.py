"""Semi-Lagrangian transport solvers (paper §III-B2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import semilag
from repro.core.grid import make_grid
from repro.core.planner import make_plan, required_halo
from repro.core.spectral import SpectralOps
from repro.data import synthetic


@pytest.fixture(scope="module")
def setup():
    g = make_grid(32)
    return g, SpectralOps(g)


def test_translation_constant_velocity(setup):
    g, ops = setup
    x = g.coords_jnp()
    f = 0.3 * jnp.exp(jnp.cos(x[0]) + jnp.sin(x[1])) + 0.1 * jnp.sin(x[2])
    v = jnp.stack([jnp.ones(g.shape), 0.5 * jnp.ones(g.shape), jnp.zeros(g.shape)])
    plan = make_plan(v, g, ops, 4, incompressible=False)
    rho1 = semilag.transport_state(f, plan)[-1]
    exact = 0.3 * jnp.exp(jnp.cos(x[0] - 1.0) + jnp.sin(x[1] - 0.5)) + 0.1 * jnp.sin(x[2])
    assert float(jnp.max(jnp.abs(rho1 - exact))) < 5e-3


def test_zero_velocity_is_identity(setup, rng):
    g, ops = setup
    f = jnp.asarray(rng.standard_normal(g.shape), jnp.float32)
    plan = make_plan(jnp.zeros((3,) + g.shape), g, ops, 4, False)
    series = semilag.transport_state(f, plan)
    np.testing.assert_array_equal(series[-1], f)


def test_adjoint_mass_conservation(setup):
    """d/dt int lam dx = -int div(v lam) = 0 (periodic)."""
    g, ops = setup
    x = g.coords_jnp()
    lam1 = jnp.exp(jnp.cos(x[0]) * jnp.sin(x[1]))
    v = synthetic.paper_velocity(g, 0.5)
    plan = make_plan(v, g, ops, 4, incompressible=False)
    lams = semilag.transport_adjoint(lam1, plan)
    masses = jnp.sum(lams, axis=(1, 2, 3)) * g.cell_volume
    assert float(jnp.max(jnp.abs(masses - masses[-1]))) < 5e-3 * abs(float(masses[-1]))


def test_state_convergence_in_nt(setup):
    """RK2: halving dt cuts the error ~4x against an n_t=64 reference."""
    g, ops = setup
    rho_T = synthetic.paper_template(g)
    v = synthetic.paper_velocity(g, 1.0)
    sol = {}
    for nt in (2, 4, 64):
        plan = make_plan(v, g, ops, nt, False)
        sol[nt] = semilag.transport_state(rho_T, plan)[-1]
    e2 = float(jnp.max(jnp.abs(sol[2] - sol[64])))
    e4 = float(jnp.max(jnp.abs(sol[4] - sol[64])))
    assert e2 / e4 > 2.5  # ~4x for 2nd order


def test_deformation_map_matches_transport(setup):
    """rho_T(y1(x)) should equal the transported rho(1) (paper §II)."""
    g, ops = setup
    from repro.kernels import ref

    rho_T = synthetic.paper_template(g)
    v = synthetic.paper_velocity(g, 0.5)
    plan = make_plan(v, g, ops, 8, False)
    rho1 = semilag.transport_state(rho_T, plan)[-1]
    u = semilag.deformation_displacement(v, plan)
    h = jnp.asarray(g.spacing).reshape(3, 1, 1, 1)
    warped = ref.tricubic_displace(rho_T, u / h)
    assert float(jnp.max(jnp.abs(warped - rho1))) < 2e-2


def test_required_halo(setup):
    g, ops = setup
    v = jnp.ones((3,) + g.shape, jnp.float32)  # |v| = 1, dt = 0.25
    plan = make_plan(v, g, ops, 4, False)
    halo = float(required_halo(plan))
    # dt * |v| / h = 0.25 * 32 / (2 pi) ~ 1.27 cells per dim
    assert 1.0 <= halo <= 4.0


def test_incremental_state_linearity(setup, rng):
    """(5a) is linear in vtilde."""
    g, ops = setup
    import repro.core.objective as obj

    rho_R, rho_T, v_star, _ = synthetic.synthetic_problem(32)
    prob = obj.Problem(g, rho_R, rho_T, 1e-2, 4, False)
    st = obj.newton_state(0.3 * v_star, prob, ops)
    vt = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    r1 = semilag.transport_inc_state(vt, st.grad_rho_series, st.plan)
    r2 = semilag.transport_inc_state(2.0 * vt, st.grad_rho_series, st.plan)
    np.testing.assert_allclose(2.0 * r1, r2, atol=1e-4)

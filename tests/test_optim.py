"""Optimizer + data-pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import TokenStream
from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0], jnp.float32)}
    state = adamw.init_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = adamw.apply_updates(cfg, params, g, adamw.init_state(params))
    assert float(m["grad_norm"]) == 100.0  # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) < 1.0
    assert abs(float(adamw.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(cfg, jnp.int32(100))) - 0.1) < 1e-6


def test_compression_roundtrip_close():
    cfg = adamw.AdamWConfig(compress_grads=True, warmup_steps=1)
    cfg2 = adamw.AdamWConfig(compress_grads=False, warmup_steps=1)
    params = {"w": jnp.ones((8, 8), jnp.float32)}
    g = {"w": jnp.full((8, 8), 0.123, jnp.float32)}
    p1, _, _ = adamw.apply_updates(cfg, params, g, adamw.init_state(params))
    p2, _, _ = adamw.apply_updates(cfg2, params, g, adamw.init_state(params))
    np.testing.assert_allclose(p1["w"], p2["w"], rtol=1e-2)


def test_no_weight_decay_on_scalars_and_vectors():
    cfg = adamw.AdamWConfig(lr=0.0, weight_decay=1.0, warmup_steps=1)
    params = {"norm": jnp.ones(4), "w": jnp.ones((4, 4))}
    g = {"norm": jnp.zeros(4), "w": jnp.zeros((4, 4))}
    p, _, _ = adamw.apply_updates(cfg, params, g, adamw.init_state(params))
    np.testing.assert_array_equal(p["norm"], params["norm"])  # lr=0 anyway
    # with lr>0, zero grad + decay must move 2-D params but not 1-D
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1)
    p, _, _ = adamw.apply_updates(cfg, params, g, adamw.init_state(params))
    assert float(jnp.max(jnp.abs(p["norm"] - 1.0))) == 0.0
    assert float(jnp.max(jnp.abs(p["w"] - 1.0))) > 0.0


def test_token_stream_shapes_and_range():
    s = TokenStream(seed=0, batch=4, seq=32, vocab=1000)
    b = s(0)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    assert int(jnp.min(b["tokens"])) >= 0 and int(jnp.max(b["tokens"])) < 1000
    b2 = s(1)
    assert not np.array_equal(np.asarray(b["tokens"]), np.asarray(b2["tokens"]))

"""Mamba2 SSD: chunked algorithm vs recurrent oracle, decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2
from repro.models.common import ArchConfig, ShardRules


def _rand_ssd_inputs(rng, B=2, S=64, H=4, P=16, N=8):
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    return x, dt, a, b, c


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_reference(rng, chunk):
    x, dt, a, b, c = _rand_ssd_inputs(rng)
    y_ref = mamba2.ssd_reference(x, dt, a, b, c)
    y = mamba2.ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, atol=2e-5, rtol=1e-4)


def test_ssd_causal(rng):
    """Future inputs must not affect past outputs."""
    x, dt, a, b, c = _rand_ssd_inputs(rng)
    y1 = mamba2.ssd_chunked(x, dt, a, b, c, chunk=16)
    x2 = x.at[:, -1].add(10.0)
    y2 = mamba2.ssd_chunked(x2, dt, a, b, c, chunk=16)
    np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], atol=1e-5)
    assert float(jnp.max(jnp.abs(y1[:, -1] - y2[:, -1]))) > 1e-3


def _block_cfg():
    return ArchConfig(
        name="m", family="ssm", n_layers=1, d_model=32, n_heads=0, n_kv=0,
        head_dim=0, d_ff=0, vocab=100, layer_pattern=("mamba",),
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, dtype=jnp.float32,
    )


def test_mamba_block_decode_matches_full(rng, jax_key, single_mesh):
    cfg = _block_cfg()
    rules = ShardRules(single_mesh)
    p, _ = mamba2.mamba_init(cfg, jax_key, rules)
    B, S = 2, 10
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3, jnp.float32)
    full = mamba2.mamba_apply(cfg, p, x, chunk=5)
    state, _ = mamba2.mamba_state_init(cfg, B, rules)
    outs = []
    for t in range(S):
        y, state = mamba2.mamba_decode(cfg, p, x[:, t : t + 1], state)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=1e-4, rtol=1e-3)


def test_conv_state_consistency(rng):
    """Streaming causal conv == full causal conv."""
    w = jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)
    b = jnp.zeros((6,), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 12, 6)), jnp.float32)
    full, _ = mamba2._causal_conv(x, w, b)
    state = jnp.zeros((2, 3, 6), jnp.float32)
    outs = []
    for t in range(12):
        y, state = mamba2._causal_conv(x[:, t : t + 1], w, b, state)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, axis=1), full, atol=1e-5)

"""repro.telemetry (ISSUE 7): schema stability, disabled-mode invariance,
console parity, and the trace_report golden path.

Acceptance pins:
* the JSONL schema round-trips (every event kind -> sink -> load ->
  ``validate_record`` clean) and ``validate_record`` rejects malformed
  records — the CI contract of ``scripts/ci.sh``;
* telemetry enabled vs disabled is invisible to the compiler: the cohort
  solver still compiles ONE executable with a sink installed, and (slow/
  dist) a 2x4-mesh Newton program's counted collectives are bit-identical
  with and without telemetry;
* the console sink / echo path renders byte-identical legacy progress
  lines (default output unchanged);
* ``trace_report`` renders per-phase wall/matvec/collective tables from a
  toy run and its matvec sums match the solver's own meters.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from conftest import run_multidevice as _run  # noqa: E402

from repro import telemetry  # noqa: E402
from repro.analysis import trace_report  # noqa: E402
from repro.core import gauss_newton as gn  # noqa: E402
from repro.data.synthetic import synthetic_problem  # noqa: E402


@pytest.fixture(autouse=True)
def _clean_registry():
    """No sink leakage between tests (the registry is process-global)."""
    yield
    for s in telemetry.sinks():
        telemetry.remove_sink(s)


def _one_of_each():
    return [
        telemetry.SpanEvent(name="pcg", wall_s=0.25, path="gn/pcg", depth=1,
                            attrs={"iter": 3}),
        telemetry.NewtonIterEvent(
            source="gn.solve", beta=1e-2, iter=0, j_val=1.0, misfit=0.9,
            reg=0.1, gnorm=2.0, rel_gnorm=1.0, cg_iters=4, step_len=1.0,
            armijo_trials=1, wall_s=0.5),
        telemetry.LevelEvent(level=0, shape=[8, 8, 8], betas=[1e-2],
                             warm_start=False, newton_iters=3,
                             hessian_matvecs=7, fine_equiv_matvecs=0.9,
                             precond_fine_equiv_matvecs=0.0, wall_s=1.0),
        telemetry.LevelStartEvent(level=0, n_levels=2, shape=[8, 8, 8],
                                  betas=[1e-2], warm_start=False),
        telemetry.JobEvent(job_id="job0", newton_iters=4, hessian_matvecs=8,
                           fine_equiv_matvecs=8.0, rel_gnorm=1e-3,
                           converged=True, slot=1, queue_wait_steps=2,
                           admitted_step=3, retired_step=7),
        telemetry.ServeStepEvent(iteration=1, slots=2, occupancy=2,
                                 queue_len=3, refills=0),
        telemetry.CounterEvent(name="halo_budget_exceeded", value=1.0,
                               total=1.0, attrs={"required": 5.0, "budget": 3}),
        telemetry.CollectivesEvent(label="step", collectives={
            "all-to-all": {"count": 4, "bytes": 1024}, "total_bytes": 1024}),
        telemetry.BenchEvent(name="fft/mesh", us_per_call=12.5, derived="x=1"),
        telemetry.SolveEvent(source="gn.solve", newton_iters=3,
                             hessian_matvecs=7),
    ]


# --------------------------------------------------------------------------- #
# schema
# --------------------------------------------------------------------------- #
def test_schema_roundtrip_all_kinds(tmp_path):
    """Every event kind survives sink -> JSONL -> load -> validate."""
    path = tmp_path / "trace.jsonl"
    events = _one_of_each()
    with telemetry.jsonl_sink(path):
        for e in events:
            telemetry.emit(e)
    recs = trace_report.load(str(path))
    assert len(recs) == len(events)
    for rec, ev in zip(recs, events):
        assert rec["v"] == telemetry.SCHEMA_VERSION
        assert rec["kind"] == ev.kind
        assert telemetry.validate_record(rec) == []
    # payload fields survive numerically
    ni = next(r for r in recs if r["kind"] == "newton_iter")
    assert ni["cg_iters"] == 4 and ni["beta"] == 1e-2
    job = next(r for r in recs if r["kind"] == "job")
    assert job["queue_wait_steps"] == 2 and job["converged"] is True


def test_validate_record_rejects_malformed():
    good = telemetry.NewtonIterEvent(
        source="gn.solve", beta=1e-2, iter=0, j_val=1.0, misfit=0.9, reg=0.1,
        gnorm=2.0, rel_gnorm=1.0, cg_iters=4, step_len=1.0).to_record()
    assert telemetry.validate_record(good) == []
    assert telemetry.validate_record("nope")
    assert telemetry.validate_record({**good, "v": 999})
    assert telemetry.validate_record({**good, "kind": "martian"})
    bad = dict(good)
    del bad["cg_iters"]
    assert any("cg_iters" in e for e in telemetry.validate_record(bad))
    no_ts = dict(good)
    no_ts["ts"] = "yesterday"
    assert any("ts" in e for e in telemetry.validate_record(no_ts))


def test_clean_converts_numpy_and_jax():
    rec = telemetry.SolveEvent(
        source="t", newton_iters=np.int64(3),
        hessian_matvecs=jnp.asarray([1, 2])).to_record()
    assert json.loads(json.dumps(rec))["newton_iters"] == 3
    assert rec["hessian_matvecs"] == [1, 2]


# --------------------------------------------------------------------------- #
# runtime: spans, counters, echo
# --------------------------------------------------------------------------- #
def test_span_nesting_and_disabled_mode():
    with telemetry.span("outer") as sp:
        pass
    assert sp.wall_s is None  # disabled: no clock read, no event
    sink = telemetry.ListSink()
    with sink:
        with telemetry.span("outer") as so:
            with telemetry.span("inner") as si:
                si.sync(jnp.ones(3) * 2)
    paths = [r["path"] for r in sink.records]
    assert paths == ["outer/inner", "outer"]
    assert sink.records[0]["depth"] == 1
    assert so.wall_s >= si.wall_s >= 0.0


def test_counter_accumulates_and_emits():
    telemetry.reset_counters()
    sink = telemetry.ListSink()
    with sink:
        telemetry.counter("widgets", 2.0)
        total = telemetry.counter("widgets", 3.0, flavor="blue")
    assert total == 5.0
    assert telemetry.counters()["widgets"] == 5.0
    assert [r["total"] for r in sink.records] == [2.0, 5.0]
    assert sink.records[1]["attrs"] == {"flavor": "blue"}
    telemetry.reset_counters()


def test_echo_renders_legacy_line_without_double_print(capsys):
    ev = telemetry.NewtonIterEvent(
        source="gn.solve", beta=1e-2, iter=3, j_val=1.2345e-1, misfit=1e-1,
        reg=2e-2, gnorm=0.5, rel_gnorm=2.5e-3, cg_iters=7, step_len=0.5)
    legacy = ("[beta=1e-02] it= 3 J=1.2345e-01 misfit=1.0000e-01 "
              "|g|/|g0|=2.500e-03 cg=7 step=0.500")
    telemetry.emit(ev, echo=True)
    assert capsys.readouterr().out.strip() == legacy
    telemetry.emit(ev, echo=False)  # no sink + no echo: silent no-op
    assert capsys.readouterr().out == ""
    with telemetry.ListSink():
        telemetry.add_sink(telemetry.ConsoleSink(verbosity=1))
        telemetry.emit(ev, echo=True)  # ConsoleSink owns rendering: no double
    assert capsys.readouterr().out.strip() == legacy


def test_solver_verbose_output_unchanged(capsys):
    """gn.solve verbose=True prints exactly the legacy per-iteration lines."""
    rho_R, rho_T, _, grid = synthetic_problem(8, n_t=2)
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=2, max_cg=4, gtol=1e-2)
    out = gn.solve(rho_R, rho_T, grid, cfg, verbose=True)
    lines = [l for l in capsys.readouterr().out.splitlines() if l.strip()]
    assert len(lines) == len(out["history"])
    for line, rec in zip(lines, out["history"]):
        assert line == (
            f"[beta={rec['beta']:.0e}] it={rec['iter']:2d} "
            f"J={rec['J']:.4e} misfit={rec['misfit']:.4e} "
            f"|g|/|g0|={rec['rel_gnorm']:.3e} cg={rec['cg_iters']} "
            f"step={rec['step']:.3f}"
        )


# --------------------------------------------------------------------------- #
# disabled-mode invariance: telemetry cannot change what gets compiled
# --------------------------------------------------------------------------- #
def test_cohort_one_executable_with_and_without_sink(tmp_path):
    probs = [synthetic_problem(8, n_t=2, amplitude=a) for a in (0.4, 1.0)]
    grid = probs[0][3]
    rho_R = jnp.stack([p[0] for p in probs])
    rho_T = jnp.stack([p[1] for p in probs])
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=3, max_cg=5, gtol=1e-2)
    off = gn.solve_cohort(rho_R, rho_T, grid, cfg)
    with telemetry.jsonl_sink(tmp_path / "t.jsonl"):
        on = gn.solve_cohort(rho_R, rho_T, grid, cfg)
    # the one-executable pin holds identically in both modes, and the
    # telemetry run converges to the same trajectory
    assert off["compiled_executables"] == on["compiled_executables"] == 1
    assert list(on["newton_iters"]) == list(off["newton_iters"])
    assert list(on["hessian_matvecs"]) == list(off["hessian_matvecs"])


def test_count_collectives_on_hlo_text():
    hlo = "\n".join([
        "ENTRY %main {",
        '  %a2a = f32[4,8]{1,0} all-to-all(%p0), dimensions={0}',
        '  %cp-start = f32[4,8]{1,0} collective-permute-start(%p1)',
        '  %cp-done = f32[4,8]{1,0} collective-permute-done(%cp-start)',
        "}",
    ])
    coll = telemetry.count_collectives(hlo)
    assert coll["all-to-all"]["count"] == 1
    # -start counted once, -done skipped: no double billing
    assert coll["collective-permute"]["count"] == 1
    assert coll["total_count"] == 2
    with pytest.raises(TypeError):
        telemetry.count_collectives(42)


@pytest.mark.slow
@pytest.mark.dist
def test_telemetry_does_not_change_mesh_collectives():
    """On the 2x4 mesh, the compiled cohort Newton program has bit-identical
    per-kind collective counts with a sink installed and without."""
    _run(
        """
        from functools import partial
        from repro import telemetry
        from repro.core import objective as obj, gauss_newton as gn
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.data.synthetic import synthetic_problem

        probs = [synthetic_problem(16, n_t=2, amplitude=a) for a in (0.4, 1.0)]
        grid = probs[0][3]
        rho_R = jnp.stack([p[0] for p in probs])
        rho_T = jnp.stack([p[1] for p in probs])
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        cfg = gn.GNConfig(n_t=2, max_cg=10)
        prob = obj.Problem(grid, rho_R, rho_T, 1e-2, 2, False)
        vc = jnp.zeros((2, 3) + grid.shape, jnp.float32)
        gf = jnp.full((2,), 1e-30, jnp.float32)
        act = jnp.ones((2,), bool)

        def compile_counts():
            step = jax.jit(partial(gn.newton_iteration_cohort, prob=prob,
                                   ops=ctx.ops, cfg=cfg, interp=ctx.interp))
            return telemetry.count_collectives(step.lower(vc, gf, act))

        off = compile_counts()
        with telemetry.ListSink():
            with telemetry.span("outer"):
                on = compile_counts()
        assert on == off, (on, off)
        assert off["all-to-all"]["count"] > 0  # the mesh program is real
        print("collective parity OK:", off["total_count"])
        """,
        devices=8,
    )


# --------------------------------------------------------------------------- #
# reg_serve job billing events
# --------------------------------------------------------------------------- #
def test_serve_emits_job_and_step_events():
    from repro.launch.reg_serve import CohortServer, RegJob

    probs = [synthetic_problem(8, n_t=2, amplitude=a)
             for a in (0.3, 0.6, 0.9, 1.2)]
    grid = probs[0][3]
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=6, max_cg=10, gtol=1e-2)
    sink = telemetry.ListSink()
    with sink:
        server = CohortServer(grid, cfg, slots=2)
        server.admit(*(RegJob(job_id=f"j{i}", rho_R=p[0], rho_T=p[1])
                       for i, p in enumerate(probs)))
        results = server.run()
    jobs = [r for r in sink.records if r["kind"] == "job"]
    steps = [r for r in sink.records if r["kind"] == "serve_step"]
    assert len(jobs) == 4 and len(results) == 4
    by_id = {j["job_id"]: j for j in jobs}
    for res in results:
        j = by_id[str(res.job_id)]
        # the event IS the billing record: matvecs/newton match the result
        assert j["hessian_matvecs"] == res.hessian_matvecs
        assert j["newton_iters"] == res.newton_iters
        assert j["retired_step"] >= j["admitted_step"] >= 0
    # the first two jobs are admitted at step 0; later ones waited
    waits = sorted(j["queue_wait_steps"] for j in jobs)
    assert waits[0] == 0 and waits[-1] > 0
    assert steps[-1]["refills"] >= 2  # 4 jobs through 2 slots: >= 2 refills
    assert all(s["occupancy"] <= s["slots"] for s in steps)


# --------------------------------------------------------------------------- #
# trace_report golden path
# --------------------------------------------------------------------------- #
def test_trace_report_golden(tmp_path, capsys):
    rho_R, rho_T, _, grid = synthetic_problem(8, n_t=2)
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=3, max_cg=5, gtol=1e-2)
    path = tmp_path / "run.jsonl"
    with telemetry.jsonl_sink(path):
        out = gn.solve(rho_R, rho_T, grid, cfg)
    recs = trace_report.load(str(path))
    summary = trace_report.summarize(recs)
    # per-phase matvec accounting closes against the solver's own meter
    assert sum(p["cg_iters"] for p in summary["phases"]) == out["hessian_matvecs"]
    assert sum(p["iters"] for p in summary["phases"]) == out["newton_iters"]
    spans = summary["spans"]
    assert spans["gn.newton_iter"]["count"] == out["newton_iters"]
    assert spans["gn.newton_iter"]["total_s"] > 0
    text = trace_report.render(summary)
    for needle in ("phases", "cg_matvecs", "spans", "gn.newton_iter"):
        assert needle in text, needle
    # the CLI --validate path exits clean on a healthy trace
    assert trace_report.main([str(path), "--validate"]) == 0
    assert "validate" in capsys.readouterr().out


def test_trace_report_validate_fails_on_bad_record(tmp_path, capsys):
    path = tmp_path / "bad.jsonl"
    rec = telemetry.BenchEvent(name="x", us_per_call=1.0).to_record()
    del rec["us_per_call"]
    path.write_text(json.dumps(rec) + "\n")
    assert trace_report.main([str(path), "--validate"]) == 1
    assert "us_per_call" in capsys.readouterr().err

"""Adjoint-gradient and Gauss-Newton Hessian checks — the numerical heart
of the paper (eq. (3)-(5)).  The FD check plateaus at the
optimize-then-discretize adjoint inconsistency (~1e-3 rel at n_t=4), never
at a sign/scale error."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import objective as obj
from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps
from repro.data import synthetic


@pytest.fixture(scope="module", params=[False, True], ids=["compressible", "incompressible"])
def problem(request, test_seed):
    # module-scoped, so it draws its own stream off the session seed (the
    # function-scoped ``rng`` fixture can't be requested from module scope).
    # Offset so v0 is decorrelated from each test's first ``rng`` draw —
    # u == v0 exactly degenerates the symmetry checks.
    rng = np.random.default_rng(test_seed + 1)
    incomp = request.param
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16, amplitude=0.5, incompressible=incomp)
    ops = SpectralOps(grid)
    prob = obj.Problem(grid, rho_R, rho_T, beta=1e-2, n_t=4, incompressible=incomp)
    v0 = jnp.asarray(rng.standard_normal((3,) + grid.shape) * 0.1, jnp.float32)
    if incomp:
        v0 = ops.leray(v0)
    return prob, ops, v0, incomp


def _rand_field(rng, grid, ops, incomp):
    w = jnp.asarray(rng.standard_normal((3,) + grid.shape) * 0.1, jnp.float32)
    return ops.leray(w) if incomp else w


def test_gradient_matches_finite_differences(problem, rng):
    """FD check along the *gradient* direction: <g, g> = ||g||^2 is the
    best-conditioned directional derivative (a random direction can be
    near-orthogonal to g, making the relative error meaningless)."""
    prob, ops, v0, incomp = problem
    grid = prob.grid
    st = obj.newton_state(v0, prob, ops)
    w = st.g / jnp.sqrt(grid.norm_sq(st.g))
    gw = float(grid.inner(st.g, w))
    j = lambda vv: float(obj.evaluate_objective(vv, prob, ops)[0])
    eps = 1e-2
    fd = (j(v0 + eps * w) - j(v0 - eps * w)) / (2 * eps)
    assert abs(fd - gw) / max(abs(fd), 1e-8) < 2e-2


def test_gradient_matches_fd_random_direction_absolute(problem, rng):
    """Random direction, absolute scale: |<g,w> - fd| small relative to
    ||g|| ||w|| (immune to near-orthogonal cancellation)."""
    prob, ops, v0, incomp = problem
    grid = prob.grid
    w = _rand_field(rng, grid, ops, incomp)
    st = obj.newton_state(v0, prob, ops)
    gw = float(grid.inner(st.g, w))
    j = lambda vv: float(obj.evaluate_objective(vv, prob, ops)[0])
    eps = 1e-2
    fd = (j(v0 + eps * w) - j(v0 - eps * w)) / (2 * eps)
    scale = float(jnp.sqrt(grid.norm_sq(st.g)) * jnp.sqrt(grid.norm_sq(w)))
    assert abs(fd - gw) < 2e-2 * scale


def test_gradient_zero_at_perfect_match(problem):
    prob, ops, _, incomp = problem
    grid = prob.grid
    # rho_R == rho_T and v=0: misfit gradient vanishes identically
    prob0 = obj.Problem(grid, prob.rho_T, prob.rho_T, prob.beta, prob.n_t, incomp)
    st = obj.newton_state(jnp.zeros((3,) + grid.shape), prob0, ops)
    assert float(jnp.max(jnp.abs(st.g))) < 1e-5


def test_gn_hessian_symmetric(problem, rng):
    prob, ops, v0, incomp = problem
    grid = prob.grid
    st = obj.newton_state(v0, prob, ops)
    u = _rand_field(rng, grid, ops, incomp)
    w = _rand_field(rng, grid, ops, incomp)
    hu = obj.gn_hessian_matvec(u, st, prob, ops)
    hw = obj.gn_hessian_matvec(w, st, prob, ops)
    a, b = float(grid.inner(hu, w)), float(grid.inner(u, hw))
    assert abs(a - b) < 5e-3 * max(abs(a), abs(b), 1e-6)


def test_gn_hessian_positive_definite(problem, rng):
    prob, ops, v0, incomp = problem
    grid = prob.grid
    st = obj.newton_state(v0, prob, ops)
    for _ in range(3):
        u = _rand_field(rng, grid, ops, incomp)
        hu = obj.gn_hessian_matvec(u, st, prob, ops)
        assert float(grid.inner(hu, u)) > 0.0


def test_full_newton_hessian_is_exact_second_derivative(problem, rng):
    """Paper eq. (5) with ALL terms: <H w, w> must match the FD second
    derivative of J (the GN approximation only nearly does)."""
    prob, ops, v0, incomp = problem
    grid = prob.grid
    st = obj.newton_state(v0, prob, ops)
    w = _rand_field(rng, grid, ops, incomp)
    hww = float(grid.inner(obj.full_hessian_matvec(w, st, prob, ops), w))
    j = lambda vv: float(obj.evaluate_objective(vv, prob, ops)[0])
    e = 3e-2
    fd2 = (j(v0 + e * w) - 2 * j(v0) + j(v0 - e * w)) / e**2
    assert abs(fd2 - hww) / max(abs(fd2), 1e-8) < 2e-2


def test_full_newton_symmetric_and_matches_gn_at_solution(problem, rng):
    prob, ops, v0, incomp = problem
    grid = prob.grid
    st = obj.newton_state(v0, prob, ops)
    u = _rand_field(rng, grid, ops, incomp)
    w = _rand_field(rng, grid, ops, incomp)
    hu = obj.full_hessian_matvec(u, st, prob, ops)
    hw = obj.full_hessian_matvec(w, st, prob, ops)
    a, b = float(grid.inner(hu, w)), float(grid.inner(u, hw))
    # the discretized full Hessian is only symmetric up to the
    # optimize-then-discretize adjoint inconsistency (~1e-3 rel at n_t=4,
    # see module docstring) — seed-dependent, so 1% not 0.5%
    assert abs(a - b) < 1e-2 * max(abs(a), abs(b), 1e-6)
    # at a perfect match lam == 0: full Newton == Gauss-Newton exactly
    prob0 = obj.Problem(grid, prob.rho_T, prob.rho_T, prob.beta, prob.n_t, incomp)
    st0 = obj.newton_state(jnp.zeros_like(v0), prob0, ops)
    np.testing.assert_allclose(
        obj.full_hessian_matvec(w, st0, prob0, ops),
        obj.gn_hessian_matvec(w, st0, prob0, ops),
        atol=1e-6,
    )


def test_hessian_reduces_to_regularization_for_constant_image(problem, rng):
    """Constant images have grad rho = 0, so the GN data block (which is
    driven by vt . grad rho) vanishes and H = beta Lap^2 exactly."""
    prob, ops, _, incomp = problem
    grid = prob.grid
    const = jnp.full(grid.shape, 0.5, jnp.float32)
    prob0 = obj.Problem(grid, const, const, prob.beta, prob.n_t, incomp)
    st = obj.newton_state(jnp.zeros((3,) + grid.shape), prob0, ops)
    u = _rand_field(rng, grid, ops, incomp)
    hu = obj.gn_hessian_matvec(u, st, prob0, ops)
    np.testing.assert_allclose(hu, ops.reg_apply(u, prob.beta), atol=1e-3)

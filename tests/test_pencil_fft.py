"""Unit-level properties of the pencil FFT backend itself (repro.dist.
pencil_fft.PencilFFT), parametrized over mesh shapes and non-cubic grids.

Complement to test_dist.py's solver-level equivalences: these pin the
backend directly — exact agreement with ``jnp.fft.fftn``, fwd/inv
roundtrip, linearity, Parseval, and the complex-packed inverse against
the plain inverse.
"""
import pytest

from conftest import run_multidevice

pytestmark = [pytest.mark.slow, pytest.mark.dist]

# degenerate slab decompositions (1x8, 8x1) and the full 2-D pencil (2x4),
# each over a cubic and a non-cubic (all-axes-distinct) grid
MESHES = [(1, 8), (2, 4), (8, 1)]
GRIDS = ((16, 16, 16), (16, 8, 32))


@pytest.mark.parametrize("mesh_shape", MESHES, ids=lambda m: f"{m[0]}x{m[1]}")
def test_pencil_fft_properties(mesh_shape):
    run_multidevice(
        f"""
        from repro.core.grid import make_grid
        from repro.dist.pencil_fft import PencilFFT
        from repro.launch.mesh import make_mesh

        mesh = make_mesh({mesh_shape!r}, ("data", "model"))
        rng = np.random.default_rng(0)
        for shape in {GRIDS!r}:
            grid = make_grid(shape)
            fft = PencilFFT(grid, mesh)
            f = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            g = jnp.asarray(rng.standard_normal(shape), jnp.float32)

            # exactness: the pencil transposes reassemble jnp.fft.fftn
            spec = fft.fwd(f)
            err = float(jnp.max(jnp.abs(spec - jnp.fft.fftn(f, axes=(-3, -2, -1)))))
            assert err < 1e-3, ("fftn", shape, err)

            # fwd . inv roundtrip
            err = float(jnp.max(jnp.abs(fft.inv(spec) - f)))
            assert err < 1e-4, ("roundtrip", shape, err)

            # linearity
            lin = fft.fwd(2.0 * f - 3.0 * g) - (2.0 * spec - 3.0 * fft.fwd(g))
            err = float(jnp.max(jnp.abs(lin)))
            assert err < 1e-3, ("linearity", shape, err)

            # Parseval (unnormalized c2c forward): sum|F|^2 = Ntot sum|f|^2
            lhs = float(jnp.sum(jnp.abs(spec) ** 2))
            rhs = float(grid.num_points * jnp.sum(f**2))
            assert abs(lhs - rhs) / rhs < 1e-5, ("parseval", shape, lhs, rhs)

            # packed inverse == plain inverse on batched real-destined
            # spectra (odd and even batch sizes hit both pairing paths)
            for b in (2, 3):
                batch = jnp.stack([f + i * g for i in range(b)])
                sb = fft.fwd(batch)
                err = float(jnp.max(jnp.abs(fft.inv_packed(sb) - fft.inv(sb))))
                assert err < 1e-4, ("inv_packed", shape, b, err)

            # packed forward == plain forward on batched REAL fields
            # (Hermitian unpack incl. the sharded-axis frequency reversal;
            # b=1 passes through, b=3 hits the odd tail)
            for b in (1, 2, 3, 6):
                batch = jnp.stack([f + i * g for i in range(b)])
                err = float(jnp.max(jnp.abs(fft.fwd_packed(batch) - fft.fwd(batch))))
                assert err < 1e-3, ("fwd_packed", shape, b, err)

            # communication-pipelined (chunked) transforms are EXACTLY the
            # unchunked programs' results: every chunk setting, odd batch
            # sizes, and trailing chunk remainders (e.g. chunk=2 at b=5),
            # on all four entry points
            batch5 = jnp.stack([f + i * g for i in range(5)])
            spec5 = fft.fwd(batch5)
            for chunk in (1, 2, "auto"):
                cfft = PencilFFT(grid, mesh, chunk=chunk)
                for b in (1, 3, 5):
                    u, s = batch5[:b], spec5[:b]
                    for name, got, want in [
                        ("fwd", cfft.fwd(u), s),
                        ("inv", cfft.inv(s), u),
                        ("fwd_packed", cfft.fwd_packed(u), s),
                        ("inv_packed", cfft.inv_packed(s), u),
                    ]:
                        err = float(jnp.max(jnp.abs(got - want)))
                        assert err < 1e-3, ("chunk", chunk, name, shape, b, err)
        """
    )

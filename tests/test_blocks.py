"""Blockwise (out-of-core map-reduce) registration: partition geometry,
partition-of-unity reduction, and the served-blocks economics.

The two system invariants (also asserted by ``benchmarks/blocks_suite.py``
on every run and recorded in ``BENCH_blocks.json``):

* the blockwise transported residual lands within tolerance of the
  monolithic solve on the same pair, and
* every block of a partition is served by ONE compiled cohort executable.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.blocks import reduce as blk_reduce
from repro.blocks.partition import BlockPartition
from repro.core import gauss_newton as gn
from repro.core.grid import make_grid

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---- partition geometry -----------------------------------------------------

def test_cores_tile_exactly():
    part = BlockPartition((24, 16, 32), 8, 2)
    seen = np.zeros((24, 16, 32), np.int32)
    for b in part.blocks:
        seen[b.core_slice(0), b.core_slice(1), b.core_slice(2)] += 1
    np.testing.assert_array_equal(seen, 1)


def test_overlap_clamps():
    # requested overlap 8 > half the 8-wide cores -> clamped to 4
    part = BlockPartition(16, 8, 8)
    assert part.overlap == (4, 4, 4)
    # single block per axis -> no overlap (no self-blend through the wrap)
    part = BlockPartition((16, 16, 16), (16, 8, 16), 2)
    assert part.overlap == (0, 2, 0)


def test_weight_windows_sum_to_one():
    """The partition-of-unity pin (float64 exact)."""
    for shape, bs, ov in [((32, 32, 32), 16, 4), ((24, 16, 32), 8, 3),
                          ((18, 16, 16), 7, 2)]:
        part = BlockPartition(shape, bs, ov)
        assert float(np.abs(part.weight_sum() - 1.0).max()) < 1e-12, (shape, bs, ov)


def test_extract_wraps_periodically():
    part = BlockPartition(8, 4, 2)
    f = np.arange(8 * 8 * 8).reshape(8, 8, 8).astype(np.float32)
    b = part.blocks[0]  # core [0,4): extended [-2,6) wraps to 6,7,0..5
    ext = part.extract(f, b)
    np.testing.assert_array_equal(ext[:, 0, 0] % 8**3 // 8**2 * 1.0,
                                  np.asarray([6, 7, 0, 1, 2, 3, 4, 5], np.float32))


def test_velocity_scale_is_grid_ratio():
    part = BlockPartition((32, 16, 16), (16, 16, 8), 4)
    b = part.blocks[0]
    assert b.ext_shape == (24, 16, 16)  # axis 1 single-block: no halo
    np.testing.assert_allclose(
        b.velocity_scale().ravel(), [32 / 24, 1.0, 16 / 16]
    )


# ---- reduce -----------------------------------------------------------------

def test_constant_field_partition_reduce_bit_exact():
    """A constant velocity survives partition -> blend bit-for-bit."""
    part = BlockPartition(16, 8, 3)
    c = np.full((3, 16, 16, 16), 0.7182817, np.float32)
    fields = [part.extract(c, b) for b in part.blocks]
    out = blk_reduce.blend(fields, part, dtype=np.float32)
    assert out.dtype == np.float32
    np.testing.assert_array_equal(out, c)


def test_seam_report_flags_disagreement():
    part = BlockPartition(16, 8, 2)
    f = np.random.default_rng(0).standard_normal((16, 16, 16)).astype(np.float32)
    agree = [part.extract(f, b) for b in part.blocks]
    rep = blk_reduce.seam_report(agree, part)
    assert rep["seam_max"] < 1e-12 and rep["overlap_fraction"] > 0
    disagree = [g + 0.5 * i for i, g in enumerate(agree)]
    rep2 = blk_reduce.seam_report(disagree, part)
    assert rep2["seam_rms"] > 0.1 and rep2["seam_rel"] > 0.0


def test_seam_report_no_overlap():
    part = BlockPartition(16, 16, 0)  # one block, no overlap anywhere
    rep = blk_reduce.seam_report(
        [np.zeros((16, 16, 16), np.float32)], part
    )
    assert rep == {"seam_max": 0.0, "seam_rms": 0.0, "seam_rel": 0.0,
                   "overlap_fraction": 0.0}


# ---- the served blockwise solve --------------------------------------------

@pytest.fixture(scope="module")
def blocks_out():
    """One toy blockwise solve shared by the solver-level assertions."""
    from repro import blocks
    from repro.data.synthetic import synthetic_problem

    rho_R, rho_T, _, grid = synthetic_problem(24, n_t=2, amplitude=0.4)
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=6, max_cg=15)
    bcfg = blocks.BlocksConfig(solver=cfg, block_shape=12, overlap=4,
                               coarse_shape=12, slots=4, presmooth=False)
    with telemetry.ListSink() as sink:
        out = blocks.solve(rho_R, rho_T, grid, bcfg)
    return out, sink.records, (rho_R, rho_T, grid, cfg)


def test_blockwise_matches_monolithic(blocks_out):
    """Tolerance pin: blockwise residual within 10% of the monolithic one."""
    from repro.core import semilag
    from repro.core.planner import make_plan
    from repro.core.spectral import SpectralOps

    out, _, (rho_R, rho_T, grid, cfg) = blocks_out
    mono = gn.solve(rho_R, rho_T, grid, cfg)
    ops = SpectralOps(grid)

    def resid(v):
        plan = make_plan(v, grid, ops, cfg.n_t, cfg.incompressible, None)
        rho1 = semilag.transport_state(rho_T, plan, None)[-1]
        return float(jnp.linalg.norm((rho1 - rho_R).ravel())) / float(
            jnp.linalg.norm((rho_T - rho_R).ravel())
        )

    r_mono, r_blocks = resid(mono["v"]), resid(out["v"])
    assert r_blocks <= 1.1 * r_mono, (r_blocks, r_mono)
    assert out["all_converged"]


def test_blocks_share_one_executable(blocks_out):
    """The economics pin: 8 blocks, one ext shape, ONE compiled step."""
    out, _, _ = blocks_out
    assert out["partition"]["n_blocks"] == 8
    assert len(out["partition"]["ext_shapes"]) == 1
    assert out["compiled_executables"] == 1


def test_per_block_billing_events(blocks_out):
    """Every block retires exactly one JobEvent carrying its tile index."""
    out, records, _ = blocks_out
    jobs = [r for r in records if r["kind"] == "job"]
    assert len(jobs) == out["partition"]["n_blocks"]
    tiles = sorted(tuple(r["block"]) for r in jobs)
    assert tiles == sorted(
        (i, j, k) for i in range(2) for j in range(2) for k in range(2)
    )
    for r in jobs:
        assert r["hessian_matvecs"] >= 0
        assert not telemetry.validate_record(r)
    # the bill adds up: per_block rows match the emitted events
    by_tile = {tuple(p["block"]): p for p in out["per_block"]}
    for r in jobs:
        assert by_tile[tuple(r["block"])]["hessian_matvecs"] == r["hessian_matvecs"]


def test_seam_within_overlap_capacity(blocks_out):
    out, _, _ = blocks_out
    seam = out["seam"]
    assert seam["overlap_fraction"] > 0
    # blocks agree on their shared voxels to well under the field scale
    assert seam["seam_rel"] < 0.75


def test_register_routes_blocks():
    """RegistrationConfig(blocks=...) end-to-end, including diagnostics."""
    from repro import blocks
    from repro.core.registration import RegistrationConfig, register
    from repro.data.synthetic import synthetic_problem

    rho_R, rho_T, _, grid = synthetic_problem(16, n_t=2, amplitude=0.3)
    cfg = RegistrationConfig(
        blocks=blocks.BlocksConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=2, max_newton=4, max_cg=10),
            block_shape=8, overlap=3, coarse_shape=8, slots=4,
        )
    )
    out = register(rho_R, rho_T, cfg, grid)
    assert out["v"].shape == (3,) + grid.shape
    assert out["residual_rel"] < 1.0
    assert out["det_min"] > 0.0
    assert "seam" in out and "per_block" in out


def test_register_rejects_blocks_plus_multilevel():
    from repro import blocks
    from repro.core.registration import RegistrationConfig, register
    from repro.multilevel.hierarchy import MultilevelConfig

    cfg = RegistrationConfig(blocks=blocks.BlocksConfig(),
                             multilevel=MultilevelConfig())
    r = np.zeros((8, 8, 8), np.float32)
    with pytest.raises(ValueError, match="mutually exclusive"):
        register(r, r, cfg)


def test_blocks_config_rejects_beta_continuation():
    from repro import blocks

    with pytest.raises(ValueError, match="beta_continuation"):
        blocks.BlocksConfig(solver=gn.GNConfig(beta_continuation=(1e-1, 1e-2)))


def test_bench_blocks_record():
    """The committed BENCH_blocks.json pins the two suite invariants."""
    path = os.path.join(ROOT, "BENCH_blocks.json")
    with open(path) as fh:
        rec = json.load(fh)
    tiled, dryrun = rec["tiled"], rec["dryrun"]
    assert tiled["residual_ratio"] <= 1.1
    assert tiled["blockwise"]["compiled_executables"] == 1
    # warm-started blocks may stall the Armijo search shy of gtol; every
    # block must still land within 2x of it (the blend-quality invariant
    # proper is the residual_ratio pin above)
    gtol = tiled["problem"]["gtol"]
    for p in tiled["per_block"]:
        assert p["converged"] or p["rel_gnorm"] <= 2 * gtol, p
    assert dryrun["grid"] == [4096, 4096, 4096]
    assert dryrun["n_blocks"] == 16**3
    assert dryrun["served_shapes"] == 1
    # 256 GiB volume vs ~0.71 GiB resident per in-flight 288^3 block job
    assert dryrun["out_of_core_ratio"] > 300

"""repro.multilevel: spectral transfer operators, grid hierarchy, and the
coarse-to-fine solver (local fast tier + 8-device mesh cases).

The solve test doubles as the measured coarse-to-fine record: the counts it
pins (same gtol as single-level, strictly fewer fine-grid Hessian matvecs)
are written to BENCH_multilevel.json at the repo root.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice
from repro.core import gauss_newton as gn
from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps, mode_indices, nyquist_mask
from repro.data import synthetic
from repro import multilevel
from repro.multilevel import transfer
from repro.multilevel.hierarchy import GridHierarchy, MultilevelConfig, split_beta_schedule

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# transfer operators
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def grids():
    gf, gc = make_grid((16, 12, 24)), make_grid((8, 6, 12))
    return gf, gc, SpectralOps(gf), SpectralOps(gc)


def test_mode_indices_and_mask():
    idx = mode_indices(16, 8)
    assert list(idx) == [0, 1, 2, 3, 12, 13, 14, 15]
    assert list(mode_indices(16, 8, rfft=True)) == [0, 1, 2, 3, 4]
    m = nyquist_mask(16, 8)
    assert m[4] == 0.0 and m.sum() == 7
    assert nyquist_mask(16, 16).sum() == 16  # no truncation -> no masking


def test_restrict_prolong_adjoint(grids, rng):
    """<R f, g>_coarse == <f, P g>_fine under cell-volume inner products."""
    gf, gc, of, oc = grids
    f = jnp.asarray(rng.standard_normal(gf.shape), jnp.float32)
    g = jnp.asarray(rng.standard_normal(gc.shape), jnp.float32)
    a = float(gc.inner(transfer.restrict(f, of, oc), g))
    b = float(gf.inner(f, transfer.prolong(g, oc, of)))
    assert abs(a - b) < 1e-5 * max(1.0, abs(a))


def test_coarse_roundtrip_identity(grids, rng):
    """restrict(prolong(g)) == g for band-limited (Nyquist-free) coarse g."""
    gf, gc, of, oc = grids
    g = transfer.restrict(jnp.asarray(rng.standard_normal(gf.shape), jnp.float32), of, oc)
    rt = transfer.restrict(transfer.prolong(g, oc, of), of, oc)
    assert float(jnp.max(jnp.abs(rt - g))) < 1e-5


def test_transfer_exact_on_resolved_modes(grids):
    """Both directions are exact band-limited interpolation/sampling."""
    gf, gc, of, oc = grids
    xf, xc = gf.coords_jnp(), gc.coords_jnp()
    low_f = jnp.sin(2 * xf[0]) * jnp.cos(xf[1]) + jnp.cos(2 * xf[2])
    low_c = jnp.sin(2 * xc[0]) * jnp.cos(xc[1]) + jnp.cos(2 * xc[2])
    assert float(jnp.max(jnp.abs(transfer.restrict(low_f, of, oc) - low_c))) < 1e-5
    assert float(jnp.max(jnp.abs(transfer.prolong(low_c, oc, of) - low_f))) < 1e-5


def test_transfer_vector_fields(grids, rng):
    """Leading axes (velocity components) pass through both directions."""
    gf, gc, of, oc = grids
    v = jnp.asarray(rng.standard_normal((3,) + gf.shape), jnp.float32)
    rv = transfer.restrict(v, of, oc)
    assert rv.shape == (3,) + gc.shape
    for i in range(3):
        assert float(jnp.max(jnp.abs(rv[i] - transfer.restrict(v[i], of, oc)))) < 1e-6
    pv = transfer.prolong(rv, oc, of)
    assert pv.shape == v.shape


# --------------------------------------------------------------------------- #
# hierarchy
# --------------------------------------------------------------------------- #
def test_hierarchy_auto_halving():
    h = GridHierarchy(make_grid(32), MultilevelConfig(n_levels=3, min_size=8))
    assert [g.shape for g in h.grids] == [(8, 8, 8), (16, 16, 16), (32, 32, 32)]
    assert h.fine_equiv_weight(0) == pytest.approx(1 / 64)
    h2 = GridHierarchy(make_grid(16), MultilevelConfig(n_levels=4, min_size=8))
    assert [g.shape for g in h2.grids] == [(8, 8, 8), (16, 16, 16)]  # floor hit


def test_precond_kind_validation():
    with pytest.raises(ValueError):
        MultilevelConfig(precond="spectral")  # benchmark's column name != kind
    assert MultilevelConfig(two_level_precond=True).precond_kind == "two_level"
    assert MultilevelConfig(precond="vcycle").galerkin_resolved is True
    assert MultilevelConfig(precond="two_level").galerkin_resolved is False


def test_hierarchy_explicit_shapes_validation():
    with pytest.raises(ValueError):
        GridHierarchy(make_grid(32), MultilevelConfig(shapes=((16,) * 3, (24,) * 3)))
    with pytest.raises(ValueError):
        GridHierarchy(make_grid(32), MultilevelConfig(shapes=((64,) * 3, (32,) * 3)))


def test_beta_schedule_split():
    assert split_beta_schedule((1e-1, 1e-2, 1e-3), 2) == ((1e-1,), (1e-2, 1e-3))
    assert split_beta_schedule((1e-2,), 3) == ((1e-2,), (1e-2,), (1e-2,))
    cfg = MultilevelConfig(
        solver=gn.GNConfig(beta=1e-3, beta_continuation=(1e-1, 1e-2)), n_levels=2
    )
    h = GridHierarchy(make_grid(16), cfg)
    assert h.level_config(0).beta == 1e-1
    assert h.level_config(1).beta == 1e-3
    assert h.level_config(1).beta_continuation == (1e-2,)


def test_level_overrides():
    cfg = MultilevelConfig(
        solver=gn.GNConfig(max_cg=50), n_levels=2, level_overrides=({"max_cg": 10},)
    )
    h = GridHierarchy(make_grid(16), cfg)
    assert h.level_config(0).max_cg == 10 and h.level_config(1).max_cg == 50


# --------------------------------------------------------------------------- #
# coarse-to-fine solve: the acceptance pin + the measured record
# --------------------------------------------------------------------------- #
def test_multilevel_solve_fewer_fine_matvecs():
    """Same gtol as single-level, strictly fewer fine-grid Hessian matvecs;
    measured counts emitted to BENCH_multilevel.json."""
    import sys

    sys.path.insert(0, ROOT)
    from benchmarks import multilevel_c2f

    rec = multilevel_c2f.measure(n=24, beta=1e-2, gtol=1e-2, n_levels=2)
    single, ml = rec["single_level"], rec["multilevel"]

    assert single["rel_gnorm"] <= 1e-2 + 1e-6
    assert ml["rel_gnorm"] <= 1e-2 + 1e-6  # same gtol, vs the cold-start g0
    # warm-started fine level: strictly fewer fine-grid matvecs ...
    assert ml["fine_grid_matvecs"] < single["hessian_matvecs"]
    # ... and cheaper even with the coarse level charged at its point ratio
    assert ml["fine_equiv_matvecs"] < single["hessian_matvecs"]
    assert ml["levels"][-1]["warm_start"] and not ml["levels"][0]["warm_start"]

    multilevel_c2f.write_record(rec)
    assert os.path.exists(os.path.join(ROOT, "BENCH_multilevel.json"))


def test_multilevel_matches_single_level_solution():
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    base = gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30)
    single = gn.solve(rho_R, rho_T, grid, base)
    ml = multilevel.solve(rho_R, rho_T, grid, MultilevelConfig(solver=base, n_levels=2))
    err = float(jnp.max(jnp.abs(ml["v"] - single["v"])))
    scale = float(jnp.max(jnp.abs(single["v"])))
    assert err < 0.05 * scale, (err, scale)


def test_register_multilevel_pipeline():
    """End-to-end register() with the multilevel config: diffeomorphic map."""
    from repro.core.registration import RegistrationConfig, register

    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    cfg = RegistrationConfig(
        multilevel=MultilevelConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30),
            n_levels=2,
        )
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
    assert out["det_min"] > 0.0
    assert len(out["levels"]) == 2
    assert out["residual_rel"] < 0.7


def test_two_level_preconditioner_cuts_fine_cg():
    """beta small (data-dominated Hessian): the coarse-grid block beats the
    pure spectral preconditioner on fine-grid matvec count."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    base = gn.GNConfig(beta=1e-4, n_t=4, max_newton=6, gtol=1e-2, max_cg=200)
    counts = {}
    for tl in (False, True):
        cfg = MultilevelConfig(
            solver=base, n_levels=2, two_level_precond=tl, precond_cg_iters=4
        )
        out = multilevel.solve(rho_R, rho_T, grid, cfg)
        assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
        counts[tl] = out["fine_matvecs"]
        if tl:  # coarse matvecs spent inside the precond are accounted
            assert out["precond_fine_equiv_matvecs"] > 0.0
            assert out["total_fine_equiv_matvecs"] == pytest.approx(
                out["fine_equiv_matvecs"] + out["precond_fine_equiv_matvecs"]
            )
        else:
            assert out["precond_fine_equiv_matvecs"] == 0.0
    assert counts[True] < counts[False], counts


# --------------------------------------------------------------------------- #
# V-cycle preconditioner: Galerkin consistency, grid independence, accounting
# --------------------------------------------------------------------------- #
from repro.core import objective as obj  # noqa: E402
from repro.multilevel.precond import (  # noqa: E402
    make_vcycle_precond,
    restrict_state,
)


@pytest.fixture(scope="module")
def fine_state_16():
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16)
    ops = SpectralOps(grid)
    prob = obj.Problem(grid, rho_R, rho_T, 1e-4, 4, False)
    state = obj.newton_state(0.4 * v_star, prob, ops)
    return grid, ops, prob, state


def test_vcycle_galerkin_consistency(fine_state_16, rng):
    """The restricted-state coarse Hessian tracks the true Galerkin product
    R H_f P on band-limited vectors — strictly closer than the legacy
    re-linearized coarse operator (the residual gap is pseudospectral
    aliasing of the quadratic data terms, which vanishes with resolution).
    The regularization block commutes exactly."""
    grid, ops_f, prob, state = fine_state_16
    ops_c = SpectralOps(make_grid(8))
    st_g, pr_c = restrict_state(state, prob, ops_f, ops_c)

    # legacy construction: re-linearize from smooth-restricted images
    rR_c = transfer.smooth_restrict(prob.rho_R, ops_f, ops_c)
    rT_c = transfer.smooth_restrict(prob.rho_T, ops_f, ops_c)
    pr_leg = obj.Problem(ops_c.grid, rR_c, rT_c, prob.beta, prob.n_t, False)
    st_leg = obj.newton_state(
        transfer.restrict(state.v, ops_f, ops_c), pr_leg, ops_c
    )

    z = jnp.asarray(rng.standard_normal((3, 8, 8, 8)), jnp.float32)
    z = transfer.restrict(transfer.prolong(z, ops_c, ops_f), ops_f, ops_c)  # band-limit
    RHP = transfer.restrict(
        obj.gn_hessian_matvec(transfer.prolong(z, ops_c, ops_f), state, prob, ops_f),
        ops_f, ops_c,
    )
    reg_c = ops_c.reg_apply(z, prob.beta)
    # reg block: Lap^2 commutes with spectral truncation exactly
    RregP = transfer.restrict(
        ops_f.reg_apply(transfer.prolong(z, ops_c, ops_f), prob.beta), ops_f, ops_c
    )
    assert float(jnp.max(jnp.abs(reg_c - RregP))) < 1e-4 * float(jnp.max(jnp.abs(reg_c)))

    data_f = RHP - reg_c
    dn = float(jnp.linalg.norm(data_f.ravel()))

    def data_err(st, pr):
        Hc = obj.gn_hessian_matvec(z, st, pr, ops_c)
        return float(jnp.linalg.norm(((Hc - reg_c) - data_f).ravel())) / dn

    err_g, err_leg = data_err(st_g, pr_c), data_err(st_leg, pr_leg)
    assert err_g < 0.75, err_g  # discretization tolerance at this toy size
    assert err_g < 0.8 * err_leg, (err_g, err_leg)


def test_restrict_state_composes_down_ladder(fine_state_16):
    """Galerkin restriction walks the ladder: 16->8->4 == cascaded calls,
    with displacement fields rescaled into each level's grid units."""
    grid, ops_f, prob, state = fine_state_16
    ops_8, ops_4 = SpectralOps(make_grid(8)), SpectralOps(make_grid(4))
    st_8, pr_8 = restrict_state(state, prob, ops_f, ops_8)
    st_4, _ = restrict_state(st_8, pr_8, ops_8, ops_4)
    # direct 16->4 restriction agrees with the cascade (truncations compose)
    st_4d, _ = restrict_state(state, prob, ops_f, ops_4)
    np.testing.assert_allclose(st_4.plan.disp_fwd, st_4d.plan.disp_fwd, atol=1e-5)
    np.testing.assert_allclose(
        st_4.grad_rho_series, st_4d.grad_rho_series, atol=1e-4
    )
    assert st_8.plan.disp_fwd.shape == (3, 8, 8, 8)
    assert st_8.grad_rho_series.shape == state.grad_rho_series.shape[:2] + (8, 8, 8)
    # grid-unit displacement halves per coarsening (same physical departure)
    r = float(jnp.max(jnp.abs(st_8.plan.disp_fwd))) / float(
        jnp.max(jnp.abs(state.plan.disp_fwd))
    )
    assert 0.3 < r < 0.7, r


def test_vcycle_grid_independence():
    """The cycle's contraction factor is grid-independent: at fixed beta the
    outer PCG iteration count of one Newton step stays flat as levels are
    added (3- vs 2-level within 1.2x — deeper is typically slightly better),
    and both crush the spectral preconditioner."""
    beta = 1e-4
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(32)
    ops = SpectralOps(grid)
    prob = obj.Problem(grid, rho_R, rho_T, beta, 4, False)
    state = obj.newton_state(0.4 * v_star, prob, ops)
    rhs = -state.g

    def matvec(p):
        return obj.gn_hessian_matvec(p, state, prob, ops)

    iters = {}
    for name, coarse in [("spectral", ()), ("2lv", (16,)), ("3lv", (8, 16))]:
        if coarse:
            lops = [SpectralOps(make_grid(c)) for c in coarse] + [ops]
            apply = make_vcycle_precond(prob, lops, n_cg=4, n_cg_coarse=10)(state, prob)
        else:
            apply = lambda r: ops.precond_apply(r, beta)
        sol = gn.pcg(matvec, rhs, apply, grid.inner, 1e-2, 150)
        iters[name] = int(sol.iters)
        assert float(sol.rel_res) <= 1e-2 + 1e-6
    assert iters["3lv"] <= 1.2 * iters["2lv"] + 1e-9, iters
    assert iters["2lv"] < 0.5 * iters["spectral"], iters
    assert iters["3lv"] < 0.5 * iters["spectral"], iters


def test_vcycle_beats_two_level_fine_equiv():
    """The acceptance pin: at beta=1e-4 the V-cycle's fine-grid and total
    fine-equivalent matvec counts are <= the two-level scheme's on the same
    continuation ladder."""
    import sys

    sys.path.insert(0, ROOT)
    from benchmarks import multilevel_c2f

    rho_R, rho_T, _, grid = synthetic.synthetic_problem(24)
    cells = {
        s: multilevel_c2f.precond_cell(rho_R, rho_T, grid, s, 1e-4, n_levels=2)
        for s in ("two_level", "vcycle")
    }
    for c in cells.values():
        assert c["rel_gnorm"] <= 1e-2 + 1e-6, c
    assert cells["vcycle"]["fine_matvecs"] <= cells["two_level"]["fine_matvecs"], cells
    assert (
        cells["vcycle"]["total_fine_equiv_matvecs"]
        <= cells["two_level"]["total_fine_equiv_matvecs"]
    ), cells


def test_vcycle_recursion_floor():
    """Ladder levels below ``min_size`` points per axis are dropped from the
    cycle (their aliasing-dominated Hessians misdirect the level above); the
    immediate coarse level always survives."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    ops = [SpectralOps(make_grid(n)) for n in (4, 8, 16)]
    prob = obj.Problem(grid, rho_R, rho_T, 1e-2, 4, False)
    fac = make_vcycle_precond(prob, ops, min_size=8)
    assert fac.n_levels == 2  # the 4^3 level was floored out
    fac_all = make_vcycle_precond(prob, ops, min_size=4)
    assert fac_all.n_levels == 3
    # floor never drops the immediate coarse level
    fac2 = make_vcycle_precond(prob, ops[1:], min_size=16)
    assert fac2.n_levels == 2


def test_vcycle_fine_equiv_cost_static():
    """The factory's static cost model matches the nested-CG structure:
    iters matvecs per level + (iters+1) recursive preconditioner applies."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    prob = obj.Problem(grid, rho_R, rho_T, 1e-2, 4, False)
    ops = [SpectralOps(make_grid(n)) for n in (4, 8, 16)]
    two = make_vcycle_precond(prob, ops[1:], n_cg=4, n_cg_coarse=10)
    assert two.fine_equiv_cost == pytest.approx(10 * (8**3 / 16**3))
    three = make_vcycle_precond(prob, ops, n_cg=4, n_cg_coarse=10, min_size=4)
    assert three.fine_equiv_cost == pytest.approx(
        4 * (8**3 / 16**3) + 5 * (10 * (4**3 / 16**3))
    )


# --------------------------------------------------------------------------- #
# distributed: same operators on the 8-device mesh
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.dist
def test_transfer_adjoint_and_roundtrip_on_mesh():
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.core.spectral import SpectralOps
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.multilevel import transfer

        mesh = make_mesh((2, 4), ("data", "model"))
        gf, gc = make_grid((16, 16, 32)), make_grid((8, 8, 16))
        ctx_f = DistContext(gf, mesh, halo=4)
        ctx_c = ctx_f.coarsen(gc.shape)
        lf, lc = SpectralOps(gf), SpectralOps(gc)
        rng = np.random.default_rng(TEST_SEED)
        f = jnp.asarray(rng.standard_normal(gf.shape), jnp.float32)
        g = jnp.asarray(rng.standard_normal(gc.shape), jnp.float32)
        fs = ctx_f.shard_scalar(f); gs = ctx_c.shard_scalar(g)

        Rf = jax.jit(lambda x: transfer.restrict(x, ctx_f.ops, ctx_c.ops))(fs)
        Pg = jax.jit(lambda x: transfer.prolong(x, ctx_c.ops, ctx_f.ops))(gs)
        # pinned to the local (rfft) implementation
        assert float(jnp.max(jnp.abs(Rf - transfer.restrict(f, lf, lc)))) < 1e-5
        assert float(jnp.max(jnp.abs(Pg - transfer.prolong(g, lc, lf)))) < 1e-5
        # adjointness + roundtrip on the mesh
        a = float(gc.inner(Rf, gs)); b = float(gf.inner(fs, Pg))
        assert abs(a - b) < 1e-5 * max(1.0, abs(a)), (a, b)
        rt = jax.jit(lambda x: transfer.restrict(
            transfer.prolong(x, ctx_c.ops, ctx_f.ops), ctx_f.ops, ctx_c.ops))(Rf)
        assert float(jnp.max(jnp.abs(rt - Rf))) < 1e-5
        """
    )


@pytest.mark.slow
@pytest.mark.dist
def test_multilevel_solve_on_mesh_matches_local():
    run_multidevice(
        """
        from repro.core import gauss_newton as gn
        from repro.data import synthetic
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro import multilevel
        from repro.multilevel.hierarchy import MultilevelConfig

        rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        cfg = MultilevelConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=6, gtol=1e-2, max_cg=30),
            n_levels=2,
        )
        out_d = multilevel.solve(ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T),
                                 grid, cfg, ctx=ctx)
        out_l = multilevel.solve(rho_R, rho_T, grid, cfg)
        assert out_d["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
        err = float(jnp.max(jnp.abs(out_d["v"] - out_l["v"])))
        assert err < 1e-3, err
        assert [l["shape"] for l in out_d["levels"]] == [[8]*3, [16]*3]
        """
    )


@pytest.mark.slow
@pytest.mark.dist
def test_vcycle_precond_on_mesh_matches_local():
    """The V-cycle re-shards through ``ctx.coarsen``'s pencil transforms on
    the 8-device mesh (no fine-field gather) and matches the local solve."""
    run_multidevice(
        """
        from repro.core import gauss_newton as gn
        from repro.data import synthetic
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro import multilevel
        from repro.multilevel.hierarchy import MultilevelConfig

        rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        cfg = MultilevelConfig(
            solver=gn.GNConfig(beta=1e-3, n_t=4, max_newton=4, gtol=1e-2, max_cg=60),
            n_levels=2, precond="vcycle", precond_cg_iters=4,
            precond_coarse_cg_iters=6,
        )
        out_d = multilevel.solve(ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T),
                                 grid, cfg, ctx=ctx)
        out_l = multilevel.solve(rho_R, rho_T, grid, cfg)
        assert out_l["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
        # the distributed run gets 5% headroom on the nominal gtol: at the
        # max_newton cap, packed-pencil-FFT f32 rounding can land the final
        # gradient norm a hair over 1e-2 (observed 1.0017e-2 vs 9.7e-3
        # local) while the trajectories and v agree to ~1e-3
        assert out_d["history"][-1]["rel_gnorm"] <= 1.05e-2
        # near-identical preconditioned Krylov trajectories: pencil-vs-local
        # FFT rounding may flip a CG stop test by an iteration or two
        assert abs(out_d["fine_matvecs"] - out_l["fine_matvecs"]) <= 2, (
            out_d["fine_matvecs"], out_l["fine_matvecs"])
        assert out_d["precond_fine_equiv_matvecs"] > 0.0
        err = float(jnp.max(jnp.abs(out_d["v"] - out_l["v"])))
        scale = float(jnp.max(jnp.abs(out_l["v"])))
        assert err < 0.05 * scale, (err, scale)
        # coarsen() memoizes the derived contexts (one PencilFFT per shape)
        assert ctx.coarsen((8, 8, 8)) is ctx.coarsen((8, 8, 8))
        """
    )


# --------------------------------------------------------------------------- #
# committed benchmark record (written by `benchmarks.run --suite multilevel`)
# --------------------------------------------------------------------------- #
def test_bench_multilevel_record():
    path = os.path.join(ROOT, "BENCH_multilevel.json")
    assert os.path.exists(path), "run: PYTHONPATH=src python -m benchmarks.run --suite multilevel"
    import json

    rec = json.load(open(path))
    sweep = rec["precond_sweep"]
    assert sweep["schemes"] == ["spectral", "two_level", "vcycle"]
    betas = [row["beta"] for row in sweep["rows"]]
    assert 1e-4 in betas and 1e-2 in betas, betas
    for row in sweep["rows"]:
        for s in sweep["schemes"]:
            assert row[s]["rel_gnorm"] <= sweep["gtol"] + 1e-6, (row["beta"], s)
    low = next(r for r in sweep["rows"] if r["beta"] == 1e-4)
    # the acceptance row: V-cycle <= two-level on BOTH cost metrics, both
    # crush the paper's spectral preconditioner in the low-beta regime
    assert low["vcycle"]["fine_matvecs"] <= low["two_level"]["fine_matvecs"], low
    assert (
        low["vcycle"]["total_fine_equiv_matvecs"]
        <= low["two_level"]["total_fine_equiv_matvecs"]
    ), low
    assert low["vcycle"]["fine_matvecs"] < 0.5 * low["spectral"]["fine_matvecs"], low
    # Eisenstat-Walker forcing decoupled from the warm-start convergence
    # reference (gn.solve): warm levels no longer over-solve PCG, so the
    # committed hardest row must not regress past the post-fix cost
    assert low["vcycle"]["total_fine_equiv_matvecs"] <= 30.2, low


def test_warm_start_forcing_not_oversolved():
    """E-W decoupling regression: with a huge convergence reference g0_ref
    (the warm-multilevel regime — rel gnorm already tiny), the FIRST inner
    solve must still be loose (eta = eta_max), not driven to max_cg by the
    old eta = sqrt(gnorm / g0_ref) conflation."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(12, n_t=2)
    cfg = gn.GNConfig(beta=1e-2, n_t=2, max_newton=1, max_cg=40, gtol=1e-12)
    cold = gn.solve(rho_R, rho_T, grid, cfg)
    warm = gn.solve(rho_R, rho_T, grid, cfg, g0_ref=1e6)
    # forcing is per-stage-local: the absurd g0_ref changes ONLY the
    # termination test, so the first iteration's PCG work is identical
    assert warm["history"][0]["cg_iters"] == cold["history"][0]["cg_iters"], (
        warm["history"][0], cold["history"][0])
    assert warm["history"][0]["cg_iters"] < cfg.max_cg

"""repro.multilevel: spectral transfer operators, grid hierarchy, and the
coarse-to-fine solver (local fast tier + 8-device mesh cases).

The solve test doubles as the measured coarse-to-fine record: the counts it
pins (same gtol as single-level, strictly fewer fine-grid Hessian matvecs)
are written to BENCH_multilevel.json at the repo root.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice
from repro.core import gauss_newton as gn
from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps, mode_indices, nyquist_mask
from repro.data import synthetic
from repro import multilevel
from repro.multilevel import transfer
from repro.multilevel.hierarchy import GridHierarchy, MultilevelConfig, split_beta_schedule

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# transfer operators
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def grids():
    gf, gc = make_grid((16, 12, 24)), make_grid((8, 6, 12))
    return gf, gc, SpectralOps(gf), SpectralOps(gc)


def test_mode_indices_and_mask():
    idx = mode_indices(16, 8)
    assert list(idx) == [0, 1, 2, 3, 12, 13, 14, 15]
    assert list(mode_indices(16, 8, rfft=True)) == [0, 1, 2, 3, 4]
    m = nyquist_mask(16, 8)
    assert m[4] == 0.0 and m.sum() == 7
    assert nyquist_mask(16, 16).sum() == 16  # no truncation -> no masking


def test_restrict_prolong_adjoint(grids, rng):
    """<R f, g>_coarse == <f, P g>_fine under cell-volume inner products."""
    gf, gc, of, oc = grids
    f = jnp.asarray(rng.standard_normal(gf.shape), jnp.float32)
    g = jnp.asarray(rng.standard_normal(gc.shape), jnp.float32)
    a = float(gc.inner(transfer.restrict(f, of, oc), g))
    b = float(gf.inner(f, transfer.prolong(g, oc, of)))
    assert abs(a - b) < 1e-5 * max(1.0, abs(a))


def test_coarse_roundtrip_identity(grids, rng):
    """restrict(prolong(g)) == g for band-limited (Nyquist-free) coarse g."""
    gf, gc, of, oc = grids
    g = transfer.restrict(jnp.asarray(rng.standard_normal(gf.shape), jnp.float32), of, oc)
    rt = transfer.restrict(transfer.prolong(g, oc, of), of, oc)
    assert float(jnp.max(jnp.abs(rt - g))) < 1e-5


def test_transfer_exact_on_resolved_modes(grids):
    """Both directions are exact band-limited interpolation/sampling."""
    gf, gc, of, oc = grids
    xf, xc = gf.coords_jnp(), gc.coords_jnp()
    low_f = jnp.sin(2 * xf[0]) * jnp.cos(xf[1]) + jnp.cos(2 * xf[2])
    low_c = jnp.sin(2 * xc[0]) * jnp.cos(xc[1]) + jnp.cos(2 * xc[2])
    assert float(jnp.max(jnp.abs(transfer.restrict(low_f, of, oc) - low_c))) < 1e-5
    assert float(jnp.max(jnp.abs(transfer.prolong(low_c, oc, of) - low_f))) < 1e-5


def test_transfer_vector_fields(grids, rng):
    """Leading axes (velocity components) pass through both directions."""
    gf, gc, of, oc = grids
    v = jnp.asarray(rng.standard_normal((3,) + gf.shape), jnp.float32)
    rv = transfer.restrict(v, of, oc)
    assert rv.shape == (3,) + gc.shape
    for i in range(3):
        assert float(jnp.max(jnp.abs(rv[i] - transfer.restrict(v[i], of, oc)))) < 1e-6
    pv = transfer.prolong(rv, oc, of)
    assert pv.shape == v.shape


# --------------------------------------------------------------------------- #
# hierarchy
# --------------------------------------------------------------------------- #
def test_hierarchy_auto_halving():
    h = GridHierarchy(make_grid(32), MultilevelConfig(n_levels=3, min_size=8))
    assert [g.shape for g in h.grids] == [(8, 8, 8), (16, 16, 16), (32, 32, 32)]
    assert h.fine_equiv_weight(0) == pytest.approx(1 / 64)
    h2 = GridHierarchy(make_grid(16), MultilevelConfig(n_levels=4, min_size=8))
    assert [g.shape for g in h2.grids] == [(8, 8, 8), (16, 16, 16)]  # floor hit


def test_hierarchy_explicit_shapes_validation():
    with pytest.raises(ValueError):
        GridHierarchy(make_grid(32), MultilevelConfig(shapes=((16,) * 3, (24,) * 3)))
    with pytest.raises(ValueError):
        GridHierarchy(make_grid(32), MultilevelConfig(shapes=((64,) * 3, (32,) * 3)))


def test_beta_schedule_split():
    assert split_beta_schedule((1e-1, 1e-2, 1e-3), 2) == ((1e-1,), (1e-2, 1e-3))
    assert split_beta_schedule((1e-2,), 3) == ((1e-2,), (1e-2,), (1e-2,))
    cfg = MultilevelConfig(
        solver=gn.GNConfig(beta=1e-3, beta_continuation=(1e-1, 1e-2)), n_levels=2
    )
    h = GridHierarchy(make_grid(16), cfg)
    assert h.level_config(0).beta == 1e-1
    assert h.level_config(1).beta == 1e-3
    assert h.level_config(1).beta_continuation == (1e-2,)


def test_level_overrides():
    cfg = MultilevelConfig(
        solver=gn.GNConfig(max_cg=50), n_levels=2, level_overrides=({"max_cg": 10},)
    )
    h = GridHierarchy(make_grid(16), cfg)
    assert h.level_config(0).max_cg == 10 and h.level_config(1).max_cg == 50


# --------------------------------------------------------------------------- #
# coarse-to-fine solve: the acceptance pin + the measured record
# --------------------------------------------------------------------------- #
def test_multilevel_solve_fewer_fine_matvecs():
    """Same gtol as single-level, strictly fewer fine-grid Hessian matvecs;
    measured counts emitted to BENCH_multilevel.json."""
    import sys

    sys.path.insert(0, ROOT)
    from benchmarks import multilevel_c2f

    rec = multilevel_c2f.measure(n=24, beta=1e-2, gtol=1e-2, n_levels=2)
    single, ml = rec["single_level"], rec["multilevel"]

    assert single["rel_gnorm"] <= 1e-2 + 1e-6
    assert ml["rel_gnorm"] <= 1e-2 + 1e-6  # same gtol, vs the cold-start g0
    # warm-started fine level: strictly fewer fine-grid matvecs ...
    assert ml["fine_grid_matvecs"] < single["hessian_matvecs"]
    # ... and cheaper even with the coarse level charged at its point ratio
    assert ml["fine_equiv_matvecs"] < single["hessian_matvecs"]
    assert ml["levels"][-1]["warm_start"] and not ml["levels"][0]["warm_start"]

    multilevel_c2f.write_record(rec)
    assert os.path.exists(os.path.join(ROOT, "BENCH_multilevel.json"))


def test_multilevel_matches_single_level_solution():
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    base = gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30)
    single = gn.solve(rho_R, rho_T, grid, base)
    ml = multilevel.solve(rho_R, rho_T, grid, MultilevelConfig(solver=base, n_levels=2))
    err = float(jnp.max(jnp.abs(ml["v"] - single["v"])))
    scale = float(jnp.max(jnp.abs(single["v"])))
    assert err < 0.05 * scale, (err, scale)


def test_register_multilevel_pipeline():
    """End-to-end register() with the multilevel config: diffeomorphic map."""
    from repro.core.registration import RegistrationConfig, register

    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    cfg = RegistrationConfig(
        multilevel=MultilevelConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=8, gtol=1e-2, max_cg=30),
            n_levels=2,
        )
    )
    out = register(rho_R, rho_T, cfg, grid=grid)
    assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
    assert out["det_min"] > 0.0
    assert len(out["levels"]) == 2
    assert out["residual_rel"] < 0.7


def test_two_level_preconditioner_cuts_fine_cg():
    """beta small (data-dominated Hessian): the coarse-grid block beats the
    pure spectral preconditioner on fine-grid matvec count."""
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
    base = gn.GNConfig(beta=1e-4, n_t=4, max_newton=6, gtol=1e-2, max_cg=200)
    counts = {}
    for tl in (False, True):
        cfg = MultilevelConfig(
            solver=base, n_levels=2, two_level_precond=tl, precond_cg_iters=4
        )
        out = multilevel.solve(rho_R, rho_T, grid, cfg)
        assert out["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
        counts[tl] = out["fine_matvecs"]
    assert counts[True] < counts[False], counts


# --------------------------------------------------------------------------- #
# distributed: same operators on the 8-device mesh
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.dist
def test_transfer_adjoint_and_roundtrip_on_mesh():
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.core.spectral import SpectralOps
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.multilevel import transfer

        mesh = make_mesh((2, 4), ("data", "model"))
        gf, gc = make_grid((16, 16, 32)), make_grid((8, 8, 16))
        ctx_f = DistContext(gf, mesh, halo=4)
        ctx_c = ctx_f.coarsen(gc.shape)
        lf, lc = SpectralOps(gf), SpectralOps(gc)
        rng = np.random.default_rng(0)
        f = jnp.asarray(rng.standard_normal(gf.shape), jnp.float32)
        g = jnp.asarray(rng.standard_normal(gc.shape), jnp.float32)
        fs = ctx_f.shard_scalar(f); gs = ctx_c.shard_scalar(g)

        Rf = jax.jit(lambda x: transfer.restrict(x, ctx_f.ops, ctx_c.ops))(fs)
        Pg = jax.jit(lambda x: transfer.prolong(x, ctx_c.ops, ctx_f.ops))(gs)
        # pinned to the local (rfft) implementation
        assert float(jnp.max(jnp.abs(Rf - transfer.restrict(f, lf, lc)))) < 1e-5
        assert float(jnp.max(jnp.abs(Pg - transfer.prolong(g, lc, lf)))) < 1e-5
        # adjointness + roundtrip on the mesh
        a = float(gc.inner(Rf, gs)); b = float(gf.inner(fs, Pg))
        assert abs(a - b) < 1e-5 * max(1.0, abs(a)), (a, b)
        rt = jax.jit(lambda x: transfer.restrict(
            transfer.prolong(x, ctx_c.ops, ctx_f.ops), ctx_f.ops, ctx_c.ops))(Rf)
        assert float(jnp.max(jnp.abs(rt - Rf))) < 1e-5
        """
    )


@pytest.mark.slow
@pytest.mark.dist
def test_multilevel_solve_on_mesh_matches_local():
    run_multidevice(
        """
        from repro.core import gauss_newton as gn
        from repro.data import synthetic
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro import multilevel
        from repro.multilevel.hierarchy import MultilevelConfig

        rho_R, rho_T, _, grid = synthetic.synthetic_problem(16)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        cfg = MultilevelConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=6, gtol=1e-2, max_cg=30),
            n_levels=2,
        )
        out_d = multilevel.solve(ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T),
                                 grid, cfg, ctx=ctx)
        out_l = multilevel.solve(rho_R, rho_T, grid, cfg)
        assert out_d["history"][-1]["rel_gnorm"] <= 1e-2 + 1e-6
        err = float(jnp.max(jnp.abs(out_d["v"] - out_l["v"])))
        assert err < 1e-3, err
        assert [l["shape"] for l in out_d["levels"]] == [[8]*3, [16]*3]
        """
    )

"""Regression: the single-device Pallas tricubic kernel and the
distributed halo-exchange interpolation are pinned to EACH OTHER on the
same displacement field — not just each to kernels/ref.py — so a drift in
either interpolation path breaks this test even if it stays within its
own oracle tolerance.
"""
import pytest

from conftest import run_multidevice

pytestmark = [pytest.mark.slow, pytest.mark.dist]


def test_pallas_kernel_matches_halo_interp():
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.kernels.tricubic import tricubic_displace_pallas
        from repro.launch.mesh import make_mesh

        halo = 4
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=halo)
        rng = np.random.default_rng(1)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        d = jnp.asarray(
            rng.uniform(-halo + 0.01, halo - 0.01, (3,) + grid.shape), jnp.float32
        )

        out_halo = jax.jit(ctx.interp)(
            ctx.shard_scalar(f), jax.device_put(d, ctx.vector_sharding())
        )
        out_pallas = tricubic_displace_pallas(
            f, d, tile=(8, 8, 32), halo=halo, interpret=True
        )
        err = float(jnp.max(jnp.abs(out_halo - out_pallas)))
        assert err < 1e-4, err
        """
    )

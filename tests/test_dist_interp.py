"""Regression: the single-device Pallas tricubic kernel and the
distributed halo-exchange interpolation are pinned to EACH OTHER on the
same displacement field — not just each to kernels/ref.py — so a drift in
either interpolation path breaks this test even if it stays within its
own oracle tolerance.
"""
import pytest

from conftest import run_multidevice

pytestmark = [pytest.mark.slow, pytest.mark.dist]


def test_pallas_kernel_matches_halo_interp():
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.kernels.tricubic import tricubic_displace_pallas
        from repro.launch.mesh import make_mesh

        halo = 4
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=halo)
        rng = np.random.default_rng(1)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        d = jnp.asarray(
            rng.uniform(-halo + 0.01, halo - 0.01, (3,) + grid.shape), jnp.float32
        )

        out_halo = jax.jit(ctx.interp)(
            ctx.shard_scalar(f), jax.device_put(d, ctx.vector_sharding())
        )
        out_pallas = tricubic_displace_pallas(
            f, d, tile=(8, 8, 32), halo=halo, interpret=True
        )
        err = float(jnp.max(jnp.abs(out_halo - out_pallas)))
        assert err < 1e-4, err
        """
    )


def test_pallas_on_mesh_matches_gather_path():
    """ROADMAP 'Pallas halo interp on-mesh': the per-shard tricubic dispatched
    to the Pallas kernel *inside* the shard_map body (ghost-extended block fed
    straight to the kernel's padded-field layout) is pinned against the
    kernels/ref.py gather path of the same exchange."""
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        halo = 4
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        rng = np.random.default_rng(2)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        d = jnp.asarray(
            rng.uniform(-halo + 0.01, halo - 0.01, (3,) + grid.shape), jnp.float32
        )
        ctx_ref = DistContext(grid, mesh, halo=halo, interp_method="ref", halo_check="off")
        ctx_pal = DistContext(grid, mesh, halo=halo, interp_method="pallas", halo_check="off")
        args_ref = (ctx_ref.shard_scalar(f), jax.device_put(d, ctx_ref.vector_sharding()))
        args_pal = (ctx_pal.shard_scalar(f), jax.device_put(d, ctx_pal.vector_sharding()))
        out_ref = jax.jit(ctx_ref.interp)(*args_ref)
        out_pal = jax.jit(ctx_pal.interp)(*args_pal)
        err = float(jnp.max(jnp.abs(out_ref - out_pal)))
        assert err < 1e-4, err
        """
    )

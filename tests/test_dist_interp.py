"""Regression: the single-device Pallas tricubic kernel and the
distributed halo-exchange interpolation are pinned to EACH OTHER on the
same displacement field — not just each to kernels/ref.py — so a drift in
either interpolation path breaks this test even if it stays within its
own oracle tolerance.
"""
import pytest

from conftest import run_multidevice

pytestmark = [pytest.mark.slow, pytest.mark.dist]


def test_pallas_kernel_matches_halo_interp():
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.kernels.tricubic import tricubic_displace_pallas
        from repro.launch.mesh import make_mesh

        halo = 4
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=halo)
        rng = np.random.default_rng(1)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        d = jnp.asarray(
            rng.uniform(-halo + 0.01, halo - 0.01, (3,) + grid.shape), jnp.float32
        )

        out_halo = jax.jit(ctx.interp)(
            ctx.shard_scalar(f), jax.device_put(d, ctx.vector_sharding())
        )
        out_pallas = tricubic_displace_pallas(
            f, d, tile=(8, 8, 32), halo=halo, interpret=True
        )
        err = float(jnp.max(jnp.abs(out_halo - out_pallas)))
        assert err < 1e-4, err
        """
    )


def test_batched_and_planned_halo_interp():
    """ISSUE 3 tentpole, mesh leg: (i) batched (C,N..) fields through the
    halo interp equal C looped scalar calls; (ii) the planned apply
    (InterpPlan built once) equals both; (iii) COUNTED in the lowered
    program: the batched path issues exactly as many ``collective_permute``
    ops for C=3 stacked fields as for C=1 — one ghost-exchange sequence per
    call — while the looped baseline issues 3x."""
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.kernels import ref
        from repro.launch.mesh import make_mesh

        halo = 4
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=halo)
        rng = np.random.default_rng(7)
        f = jnp.asarray(rng.standard_normal((3,) + grid.shape), jnp.float32)
        d = jnp.asarray(
            rng.uniform(-halo + 0.01, halo - 0.01, (3,) + grid.shape), jnp.float32
        )
        fs = jax.device_put(f, ctx.vector_sharding())
        ds = jax.device_put(d, ctx.vector_sharding())
        expect = jnp.stack([ref.tricubic_displace(f[i], d) for i in range(3)])

        out_b = jax.jit(ctx.interp)(fs, ds)
        assert float(jnp.max(jnp.abs(out_b - expect))) < 1e-4

        plan = ctx.interp.make_plan(ds)
        out_p = jax.jit(ctx.interp.apply_plan)(fs, plan)
        assert float(jnp.max(jnp.abs(out_p - expect))) < 1e-4

        def count_cp(fn, *args):
            return jax.jit(fn).lower(*args).as_text().count("collective_permute")

        c1 = count_cp(ctx.interp, fs[0], ds)
        c_batched = count_cp(ctx.interp, fs, ds)
        c_planned = count_cp(ctx.interp.apply_plan, fs, plan)
        c_looped = count_cp(
            lambda ff, dd: jnp.stack([ctx.interp(ff[i], dd) for i in range(3)]), fs, ds
        )
        assert c1 > 0, c1
        assert c_batched == c1, (c_batched, c1)
        assert c_planned == c1, (c_planned, c1)
        assert c_looped == 3 * c1, (c_looped, c1)
        """
    )


def test_checked_interp_planned_overflow_paths():
    """Dynamic halo budget on the planned path: the cached
    ``InterpPlan.halo_need`` drives NaN-poisoning ("error") and the exact
    global-gather fallback ("gather") when a step overshoots the budget.
    Both paths COUNT the violation — one ``halo_budget_exceeded`` event per
    overflowing call lands in telemetry (resilience deliverable), and the
    gather fallback's output is finite and exact."""
    run_multidevice(
        """
        from repro import telemetry
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.kernels import ref
        from repro.launch.mesh import make_mesh
        from repro.resilience.faults import overflow_displacement

        halo = 3
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        rng = np.random.default_rng(8)
        f = jnp.asarray(rng.standard_normal((2,) + grid.shape), jnp.float32)
        # the chaos harness manufactures a displacement that exceeds the
        # budget by 2.5 voxels on every axis (and emits a FaultEvent)
        d = jnp.asarray(overflow_displacement(grid.shape, halo))

        with telemetry.ListSink() as sink:
            ctx_e = DistContext(grid, mesh, halo=halo, halo_check="error")
            fs = jax.device_put(f, ctx_e.vector_sharding())
            ds = jax.device_put(d, ctx_e.vector_sharding())
            plan = ctx_e.interp.make_plan(ds)
            out = jax.jit(ctx_e.interp.apply_plan)(fs, plan)
            assert bool(jnp.isnan(out).all()), "overflow must NaN-poison"

            ctx_g = DistContext(grid, mesh, halo=halo, halo_check="gather")
            out_g = jax.jit(ctx_g.interp.apply_plan)(fs, plan)
            expect = jnp.stack([ref.tricubic_displace(f[i], d) for i in range(2)])
            assert bool(jnp.isfinite(out_g).all()), "gather fallback must stay finite"
            assert float(jnp.max(jnp.abs(out_g - expect))) < 1e-4
            jax.effects_barrier()  # flush the debug-callback counter events

        # each overflowing apply counted exactly once, with the bound attrs
        hits = [r for r in sink.records
                if r["kind"] == "counter" and r["name"] == "halo_budget_exceeded"]
        assert len(hits) == 2, [r["name"] for r in sink.records if r["kind"] == "counter"]
        assert {h["attrs"]["mode"] for h in hits} == {"error", "gather"}
        for h in hits:
            assert h["attrs"]["required"] > h["attrs"]["budget"] == halo
        assert telemetry.counters().get("halo_budget_exceeded", 0) >= 2
        """
    )


def test_pallas_on_mesh_matches_gather_path():
    """ROADMAP 'Pallas halo interp on-mesh': the per-shard tricubic dispatched
    to the Pallas kernel *inside* the shard_map body (ghost-extended block fed
    straight to the kernel's padded-field layout) is pinned against the
    kernels/ref.py gather path of the same exchange."""
    run_multidevice(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh

        halo = 4
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        rng = np.random.default_rng(2)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        d = jnp.asarray(
            rng.uniform(-halo + 0.01, halo - 0.01, (3,) + grid.shape), jnp.float32
        )
        ctx_ref = DistContext(grid, mesh, halo=halo, interp_method="ref", halo_check="off")
        ctx_pal = DistContext(grid, mesh, halo=halo, interp_method="pallas", halo_check="off")
        args_ref = (ctx_ref.shard_scalar(f), jax.device_put(d, ctx_ref.vector_sharding()))
        args_pal = (ctx_pal.shard_scalar(f), jax.device_put(d, ctx_pal.vector_sharding()))
        out_ref = jax.jit(ctx_ref.interp)(*args_ref)
        out_pal = jax.jit(ctx_pal.interp)(*args_pal)
        err = float(jnp.max(jnp.abs(out_ref - out_pal)))
        assert err < 1e-4, err

        # batched (C=2) stacks agree across per-shard kernels too
        f2 = jnp.stack([f, f[::-1]])
        args2_ref = (jax.device_put(f2, ctx_ref.vector_sharding()), args_ref[1])
        args2_pal = (jax.device_put(f2, ctx_pal.vector_sharding()), args_pal[1])
        out2_ref = jax.jit(ctx_ref.interp)(*args2_ref)
        out2_pal = jax.jit(ctx_pal.interp)(*args2_pal)
        err2 = float(jnp.max(jnp.abs(out2_ref - out2_pal)))
        assert err2 < 1e-4, err2
        """
    )

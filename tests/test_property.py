"""Hypothesis property-based tests on system invariants.

``hypothesis`` is an optional dev dependency.  When it is absent the
module does NOT skip: a minimal stand-in below runs every ``@given``
test as a deterministic seeded sweep (``max_examples`` draws from one
``np.random.default_rng`` stream, seeded by ``REPRO_TEST_SEED``).  The
stand-in has no shrinking, no database, and no adaptive generation —
but the invariants still get exercised across many random inputs on
machines without the dev dependency, and the real hypothesis engine
takes over transparently wherever it is installed.
"""
import functools
import inspect
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # seeded-sweep stand-in (see module docstring)
    HAVE_HYPOTHESIS = False
    _SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> example

        def map(self, f):
            return _Strategy(lambda rng: f(self.draw(rng)))

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    def settings(max_examples=10, deadline=None):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper._max_examples = getattr(fn, "_max_examples", 10)
            # pytest must not mistake the drawn parameters for fixtures
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco


from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps
from repro.data.tokens import batch_at_step
from repro.kernels import ref
from repro.models import moe

_G = make_grid(8)
_OPS = SpectralOps(_G)

fields = st.integers(0, 2**31 - 1).map(
    lambda s: jnp.asarray(np.random.default_rng(s).standard_normal((3,) + _G.shape), jnp.float32)
)
scalars = st.integers(0, 2**31 - 1).map(
    lambda s: jnp.asarray(np.random.default_rng(s).standard_normal(_G.shape), jnp.float32)
)


@settings(max_examples=15, deadline=None)
@given(v=fields)
def test_leray_projection_idempotent(v):
    pv = _OPS.leray(v)
    np.testing.assert_allclose(_OPS.leray(pv), pv, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(v=fields)
def test_leray_output_divergence_free(v):
    assert float(jnp.max(jnp.abs(_OPS.div(_OPS.leray(v))))) < 1e-4


@settings(max_examples=15, deadline=None)
@given(f=scalars)
def test_fft_roundtrip(f):
    np.testing.assert_allclose(_OPS.fft.inv(_OPS.fft.fwd(f)), f, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(f=scalars)
def test_interp_exact_at_grid_points(f):
    out = ref.tricubic_displace(f, jnp.zeros((3,) + _G.shape))
    np.testing.assert_array_equal(out, f)


@settings(max_examples=15, deadline=None)
@given(f=scalars, s=st.integers(0, 7))
def test_interp_integer_shift_is_roll(f, s):
    d = jnp.full((3,) + _G.shape, float(s))
    out = ref.tricubic_displace(f, d)
    np.testing.assert_allclose(out, jnp.roll(f, (-s, -s, -s), (0, 1, 2)), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(t=st.floats(0, 1).map(lambda v: jnp.asarray([v], jnp.float32)))
def test_lagrange_weights_sum_to_one(t):
    np.testing.assert_allclose(jnp.sum(ref.lagrange_weights(t), axis=0), 1.0, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), ne=st.integers(2, 16))
def test_rank_in_expert_is_valid_permutation_within_expert(seed, ne):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, ne, 64), jnp.int32)
    ranks = np.asarray(moe._rank_in_expert(ids, ne))
    for e in range(ne):
        r = sorted(ranks[np.asarray(ids) == e])
        assert r == list(range(len(r)))  # 0..count-1, each exactly once


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), step=st.integers(0, 1000))
def test_token_stream_deterministic(seed, step):
    a = batch_at_step(seed, step, 2, 8, 100)
    b = batch_at_step(seed, step, 2, 8, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(amp=st.floats(0.05, 0.6))
def test_diffeomorphism_for_smooth_small_velocity(amp):
    """Smooth velocities with bounded magnitude yield det(grad y) > 0."""
    from repro.core import semilag
    from repro.core.planner import make_plan
    from repro.data.synthetic import paper_velocity

    g = make_grid(16)
    ops = SpectralOps(g)
    v = paper_velocity(g, float(amp))
    plan = make_plan(v, g, ops, 4, False)
    u = semilag.deformation_displacement(v, plan)
    assert float(jnp.min(ops.jacobian_det(u))) > 0.0


# ---- multilevel transfer: adjointness over varying grid shapes -------------

# (fine, coarse) layout pairs: isotropic, anisotropic, non-power-of-two,
# single-axis coarsening — the shapes the ladder actually visits
_TRANSFER_SHAPES = [
    ((16, 16, 16), (8, 8, 8)),
    ((16, 12, 24), (8, 6, 12)),
    ((12, 12, 12), (8, 8, 8)),
    ((16, 16, 16), (12, 12, 12)),
    ((16, 8, 12), (8, 8, 12)),
]


@settings(max_examples=15, deadline=None)
@given(pair=st.sampled_from(_TRANSFER_SHAPES), seed=st.integers(0, 2**31 - 1))
def test_restrict_prolong_adjoint_over_shapes(pair, seed):
    """<R f, g>_coarse == <f, P g>_fine for every ladder layout pair."""
    from repro.multilevel import transfer

    gf, gc = make_grid(pair[0]), make_grid(pair[1])
    of, oc = SpectralOps(gf), SpectralOps(gc)
    r = np.random.default_rng(seed)
    f = jnp.asarray(r.standard_normal(gf.shape), jnp.float32)
    g = jnp.asarray(r.standard_normal(gc.shape), jnp.float32)
    a = float(gc.inner(transfer.restrict(f, of, oc), g))
    b = float(gf.inner(f, transfer.prolong(g, oc, of)))
    assert abs(a - b) < 1e-5 * max(1.0, abs(a))


# ---- blocks: partition round-trip and partition of unity -------------------

_PARTITION_CASES = [
    ((16, 16, 16), 8, 2),
    ((16, 16, 16), 8, 4),
    ((24, 16, 32), 8, 3),
    ((20, 12, 16), (8, 6, 8), 2),
    ((16, 16, 16), 16, 4),  # single block per axis -> overlap clamps to 0
    ((18, 16, 16), 7, 1),  # uneven cores
]


@settings(max_examples=20, deadline=None)
@given(case=st.sampled_from(_PARTITION_CASES), seed=st.integers(0, 2**31 - 1))
def test_block_partition_roundtrip_exact(case, seed):
    """partition -> unweighted paste of interiors reconstructs bit-exactly."""
    from repro.blocks.partition import BlockPartition

    shape, bs, ov = case
    part = BlockPartition(shape, bs, ov)
    f = np.random.default_rng(seed).standard_normal((3,) + shape).astype(np.float32)
    fields = [part.extract(f, b) for b in part.blocks]
    np.testing.assert_array_equal(part.paste_interiors(fields), f)


@settings(max_examples=20, deadline=None)
@given(case=st.sampled_from(_PARTITION_CASES))
def test_block_windows_partition_of_unity(case):
    """Every partition's pasted weight windows sum to one everywhere."""
    from repro.blocks.partition import BlockPartition

    shape, bs, ov = case
    part = BlockPartition(shape, bs, ov)
    assert float(np.abs(part.weight_sum() - 1.0).max()) < 1e-12


@settings(max_examples=15, deadline=None)
@given(case=st.sampled_from(_PARTITION_CASES), seed=st.integers(0, 2**31 - 1))
def test_block_blend_is_convex_combination(case, seed):
    """Blending per-block views of ONE field returns that field (any
    disagreement-free reduction is the identity), and blending fields
    perturbed by +/-eps stays within the per-voxel claim envelope."""
    from repro.blocks import reduce as blk_reduce
    from repro.blocks.partition import BlockPartition

    shape, bs, ov = case
    part = BlockPartition(shape, bs, ov)
    f = np.random.default_rng(seed).standard_normal(shape).astype(np.float32)
    fields = [part.extract(f, b) for b in part.blocks]
    out = blk_reduce.blend(fields, part, dtype=np.float32)
    np.testing.assert_array_equal(out, f)
    eps = 0.125  # exactly representable: envelope bound stays exact
    bumped = [
        g.astype(np.float64) + ((-1.0) ** i) * eps for i, g in enumerate(fields)
    ]
    out2 = blk_reduce.blend(bumped, part, dtype=np.float64)
    assert float(np.abs(out2 - f).max()) <= eps * (1 + 1e-12)

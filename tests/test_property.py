"""Hypothesis property-based tests on system invariants.

``hypothesis`` is an optional dev dependency: without it this module
degrades to a skip instead of hard-aborting suite collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps
from repro.data.tokens import batch_at_step
from repro.kernels import ref
from repro.models import moe

_G = make_grid(8)
_OPS = SpectralOps(_G)

fields = st.integers(0, 2**31 - 1).map(
    lambda s: jnp.asarray(np.random.default_rng(s).standard_normal((3,) + _G.shape), jnp.float32)
)
scalars = st.integers(0, 2**31 - 1).map(
    lambda s: jnp.asarray(np.random.default_rng(s).standard_normal(_G.shape), jnp.float32)
)


@settings(max_examples=15, deadline=None)
@given(v=fields)
def test_leray_projection_idempotent(v):
    pv = _OPS.leray(v)
    np.testing.assert_allclose(_OPS.leray(pv), pv, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(v=fields)
def test_leray_output_divergence_free(v):
    assert float(jnp.max(jnp.abs(_OPS.div(_OPS.leray(v))))) < 1e-4


@settings(max_examples=15, deadline=None)
@given(f=scalars)
def test_fft_roundtrip(f):
    np.testing.assert_allclose(_OPS.fft.inv(_OPS.fft.fwd(f)), f, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(f=scalars)
def test_interp_exact_at_grid_points(f):
    out = ref.tricubic_displace(f, jnp.zeros((3,) + _G.shape))
    np.testing.assert_array_equal(out, f)


@settings(max_examples=15, deadline=None)
@given(f=scalars, s=st.integers(0, 7))
def test_interp_integer_shift_is_roll(f, s):
    d = jnp.full((3,) + _G.shape, float(s))
    out = ref.tricubic_displace(f, d)
    np.testing.assert_allclose(out, jnp.roll(f, (-s, -s, -s), (0, 1, 2)), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(t=st.floats(0, 1).map(lambda v: jnp.asarray([v], jnp.float32)))
def test_lagrange_weights_sum_to_one(t):
    np.testing.assert_allclose(jnp.sum(ref.lagrange_weights(t), axis=0), 1.0, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), ne=st.integers(2, 16))
def test_rank_in_expert_is_valid_permutation_within_expert(seed, ne):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, ne, 64), jnp.int32)
    ranks = np.asarray(moe._rank_in_expert(ids, ne))
    for e in range(ne):
        r = sorted(ranks[np.asarray(ids) == e])
        assert r == list(range(len(r)))  # 0..count-1, each exactly once


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), step=st.integers(0, 1000))
def test_token_stream_deterministic(seed, step):
    a = batch_at_step(seed, step, 2, 8, 100)
    b = batch_at_step(seed, step, 2, 8, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(amp=st.floats(0.05, 0.6))
def test_diffeomorphism_for_smooth_small_velocity(amp):
    """Smooth velocities with bounded magnitude yield det(grad y) > 0."""
    from repro.core import semilag
    from repro.core.planner import make_plan
    from repro.data.synthetic import paper_velocity

    g = make_grid(16)
    ops = SpectralOps(g)
    v = paper_velocity(g, float(amp))
    plan = make_plan(v, g, ops, 4, False)
    u = semilag.deformation_displacement(v, plan)
    assert float(jnp.min(ops.jacobian_det(u))) > 0.0

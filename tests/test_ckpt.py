"""Checkpoint manager: atomicity, keep-k, async, elastic restore, bit-exact
resume, checksum verification + corrupt-step fallback (fault-tolerance
deliverable)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointCorrupt, CheckpointManager
from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream
from repro.models.common import ShardRules
from repro.optim import adamw
from repro.train.steps import build_model, make_train_step


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "b": {"c": jnp.arange(7), "d": jnp.asarray(rng.standard_normal(3), jnp.float32)},
    }


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    mgr.save(5, tree, metadata={"note": "x"})
    out, meta = mgr.restore()
    assert meta["step"] == 5 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(a, b)


def test_keep_k_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(rng))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(rng)
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    out, meta = mgr.restore(1)
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_elastic_restore_respec(tmp_path, rng, single_mesh):
    from jax.sharding import PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
    mgr.save(1, tree)
    out, _ = mgr.restore(1, mesh=single_mesh, specs={"w": P("data", None)})
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert out["w"].sharding.spec == P("data", None)


def test_close_joins_async_writer(tmp_path, rng):
    """close() (and the context manager) joins the writer thread, so an
    async save issued right before process exit still lands complete."""
    tree = _tree(rng)
    with CheckpointManager(str(tmp_path), keep=3) as mgr:
        mgr.save(1, tree, blocking=False)
    # context exit == close(): the step directory is fully written
    assert mgr.latest_step() == 1
    out, meta = CheckpointManager(str(tmp_path)).restore()
    np.testing.assert_array_equal(out["a"], tree["a"])
    mgr.close()  # idempotent


def test_overlapping_async_saves_serialize(tmp_path, rng):
    """Back-to-back non-blocking saves never interleave writers: every
    step lands intact and verified."""
    mgr = CheckpointManager(str(tmp_path), keep=0)
    trees = {s: _tree(rng) for s in range(1, 6)}
    for s, tree in trees.items():
        mgr.save(s, tree, blocking=False)
    mgr.close()
    for s, tree in trees.items():
        out, meta = mgr.restore(s)
        assert meta["step"] == s
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(a, b)


def _corrupt_step(tmp_path, step):
    """Flip bytes inside the npz payload of a step directory."""
    path = os.path.join(str(tmp_path), f"step_{step}", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(-8, os.SEEK_END)
        f.write(b"\xde\xad\xbe\xef\xde\xad\xbe\xef")


def test_checksum_detects_corruption(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    mgr.save(1, _tree(rng))
    meta = json.load(open(os.path.join(str(tmp_path), "step_1", "meta.json")))
    assert "checksums" in meta and len(meta["checksums"]) == 3
    _corrupt_step(tmp_path, 1)
    with pytest.raises(CheckpointCorrupt):
        mgr.restore(1)  # explicit step: strict


def test_restore_falls_back_over_corrupt_steps(tmp_path, rng):
    """Latest-step restore skips corrupt steps (counted + RecoveryEvent)
    and resumes from the newest intact one; all-corrupt raises."""
    from repro import telemetry

    mgr = CheckpointManager(str(tmp_path), keep=0)
    trees = {s: _tree(rng) for s in (1, 2, 3)}
    for s, tree in trees.items():
        mgr.save(s, tree)
    _corrupt_step(tmp_path, 3)

    before = telemetry.counters().get("ckpt.corrupt_step", 0)
    with telemetry.ListSink() as sink:
        out, meta = mgr.restore()
    assert meta["step"] == 2  # fell back past the torn newest step
    for a, b in zip(jax.tree.leaves(trees[2]), jax.tree.leaves(out)):
        np.testing.assert_array_equal(a, b)
    assert telemetry.counters().get("ckpt.corrupt_step", 0) == before + 1
    recov = [r for r in sink.records if r["kind"] == "recovery"]
    assert recov and recov[0]["action"] == "ckpt_fallback" and recov[0]["step"] == 3

    _corrupt_step(tmp_path, 1)
    _corrupt_step(tmp_path, 2)
    with pytest.raises(CheckpointCorrupt):
        mgr.restore()


def test_pre_checksum_checkpoints_load_unverified(tmp_path, rng):
    """A checkpoint written before the checksum scheme (no ``checksums``
    key) still restores."""
    mgr = CheckpointManager(str(tmp_path), keep=0)
    tree = _tree(rng)
    mgr.save(1, tree)
    meta_path = os.path.join(str(tmp_path), "step_1", "meta.json")
    meta = json.load(open(meta_path))
    del meta["checksums"]
    json.dump(meta, open(meta_path, "w"))
    out, _ = mgr.restore()
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_same_step_overwrite(tmp_path, rng):
    """Re-saving an existing step replaces it atomically (the serve layer
    writes its final session snapshot onto the last periodic one)."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(2, {"x": jnp.zeros(3)})
    tree = {"x": jnp.arange(3.0)}
    mgr.save(2, tree)
    out, meta = mgr.restore(2)
    np.testing.assert_array_equal(out["x"], tree["x"])


@pytest.mark.slow
def test_bit_exact_resume(tmp_path, rng, jax_key, single_mesh):
    """Train 4 steps; or train 2, checkpoint, restart, train 2: identical."""
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    opt_cfg = adamw.AdamWConfig(warmup_steps=2)
    step = jax.jit(make_train_step(model, opt_cfg))
    stream = TokenStream(seed=3, batch=2, seq=16, vocab=cfg.vocab)

    params, _ = model.init(jax_key, rules)
    opt = adamw.init_state(params)

    # straight 4 steps
    p, o = params, opt
    for s in range(4):
        p, o, _ = step(p, o, stream(s))

    # 2 steps -> checkpoint -> restore -> 2 steps
    mgr = CheckpointManager(str(tmp_path))
    p2, o2 = params, opt
    for s in range(2):
        p2, o2, _ = step(p2, o2, stream(s))
    mgr.save(2, {"params": p2, "opt": o2})
    rest, meta = mgr.restore(2)
    p3, o3 = rest["params"], rest["opt"]
    for s in range(meta["step"], 4):
        p3, o3, _ = step(p3, o3, stream(s))

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

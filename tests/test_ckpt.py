"""Checkpoint manager: atomicity, keep-k, async, elastic restore, bit-exact
resume (fault-tolerance deliverable)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream
from repro.models.common import ShardRules
from repro.optim import adamw
from repro.train.steps import build_model, make_train_step


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "b": {"c": jnp.arange(7), "d": jnp.asarray(rng.standard_normal(3), jnp.float32)},
    }


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    mgr.save(5, tree, metadata={"note": "x"})
    out, meta = mgr.restore()
    assert meta["step"] == 5 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(a, b)


def test_keep_k_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(rng))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(rng)
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    out, meta = mgr.restore(1)
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_elastic_restore_respec(tmp_path, rng, single_mesh):
    from jax.sharding import PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
    mgr.save(1, tree)
    out, _ = mgr.restore(1, mesh=single_mesh, specs={"w": P("data", None)})
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert out["w"].sharding.spec == P("data", None)


@pytest.mark.slow
def test_bit_exact_resume(tmp_path, rng, jax_key, single_mesh):
    """Train 4 steps; or train 2, checkpoint, restart, train 2: identical."""
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    opt_cfg = adamw.AdamWConfig(warmup_steps=2)
    step = jax.jit(make_train_step(model, opt_cfg))
    stream = TokenStream(seed=3, batch=2, seq=16, vocab=cfg.vocab)

    params, _ = model.init(jax_key, rules)
    opt = adamw.init_state(params)

    # straight 4 steps
    p, o = params, opt
    for s in range(4):
        p, o, _ = step(p, o, stream(s))

    # 2 steps -> checkpoint -> restore -> 2 steps
    mgr = CheckpointManager(str(tmp_path))
    p2, o2 = params, opt
    for s in range(2):
        p2, o2, _ = step(p2, o2, stream(s))
    mgr.save(2, {"params": p2, "opt": o2})
    rest, meta = mgr.restore(2)
    p3, o3 = rest["params"], rest["opt"]
    for s in range(meta["step"], 4):
        p3, o3, _ = step(p3, o3, stream(s))

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Tricubic interpolation: oracle properties + Pallas kernel parity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels import ops as kops
from repro.kernels.tricubic import tricubic_displace_pallas


def test_exact_at_grid_points(rng):
    f = jnp.asarray(rng.standard_normal((8, 12, 16)), jnp.float32)
    out = ref.tricubic_displace(f, jnp.zeros((3, 8, 12, 16)))
    np.testing.assert_array_equal(out, f)


def test_weights_partition_of_unity(rng):
    t = jnp.asarray(rng.uniform(0, 1, 100), jnp.float32)
    w = ref.lagrange_weights(t)
    np.testing.assert_allclose(jnp.sum(w, axis=0), 1.0, atol=1e-6)


def test_fourth_order_convergence(rng):
    errs = []
    for n in (16, 32):
        h = 2 * np.pi / n
        xs = np.arange(n) * h
        x = np.stack(np.meshgrid(xs, xs, xs, indexing="ij"))
        f = jnp.asarray(np.sin(x[0]) * np.cos(x[1]) + np.sin(x[2]), jnp.float32)
        d = jnp.asarray(rng.uniform(-0.5, 0.5, (3, n, n, n)), jnp.float32)
        out = ref.tricubic_displace(f, d)
        q = x + np.asarray(d) * h
        exact = np.sin(q[0]) * np.cos(q[1]) + np.sin(q[2])
        errs.append(float(jnp.max(jnp.abs(out - exact))))
    # 4th order: doubling N cuts error ~16x (allow slack for f32)
    assert errs[0] / errs[1] > 8.0


def test_periodic_wrap(rng):
    f = jnp.asarray(rng.standard_normal((8, 8, 8)), jnp.float32)
    d = jnp.ones((3, 8, 8, 8), jnp.float32) * 8.0  # exactly one period
    np.testing.assert_allclose(ref.tricubic_displace(f, d), f, atol=1e-4)


def test_chunked_matches_direct(rng):
    f = jnp.asarray(rng.standard_normal((8, 8, 16)), jnp.float32)
    q = jnp.asarray(rng.uniform(0, 8, (3, 333)), jnp.float32)
    a = ref.tricubic_points(f, q)
    b = ref.tricubic_points_chunked(f, q, chunk=64)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_vector_displace(rng):
    f = jnp.asarray(rng.standard_normal((3, 8, 8, 16)), jnp.float32)
    d = jnp.asarray(rng.uniform(-2, 2, (3, 8, 8, 16)), jnp.float32)
    out = ref.tricubic_displace_vec(f, d)
    for c in range(3):
        np.testing.assert_allclose(out[c], ref.tricubic_displace(f[c], d), atol=1e-6)


# ----------------------------------------------------------------------- #
# Pallas kernel parity sweeps (interpret mode on CPU)
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("shape,tile", [
    ((16, 16, 32), (8, 8, 16)),
    ((8, 16, 64), (4, 8, 32)),
    ((16, 8, 16), (8, 4, 16)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("halo", [2, 4])
def test_pallas_matches_ref(rng, shape, tile, dtype, halo):
    f = jnp.asarray(rng.standard_normal(shape), dtype)
    d = jnp.asarray(rng.uniform(-halo + 0.1, halo - 0.1, (3,) + shape), jnp.float32)
    out = tricubic_displace_pallas(f, d, tile=tile, halo=halo, interpret=True)
    expect = ref.tricubic_displace(f.astype(jnp.float32), d)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol, rtol=tol
    )


def test_ops_dispatcher_ref_path(rng):
    f = jnp.asarray(rng.standard_normal((8, 8, 16)), jnp.float32)
    d = jnp.asarray(rng.uniform(-1, 1, (3, 8, 8, 16)), jnp.float32)
    a = kops.tricubic_displace(f, d, method="ref")
    b = kops.tricubic_displace(f, d, method="auto")  # CPU -> ref
    np.testing.assert_array_equal(a, b)

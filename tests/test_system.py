"""End-to-end behaviour tests for the paper's system.

The headline claim of the paper is: a preconditioned, inexact
Gauss-Newton-Krylov solver with spectral discretization and semi-Lagrangian
transport registers two images to practical accuracy (relative gradient
1e-2) in a handful of Newton iterations, producing a *diffeomorphic* map,
with mesh-independent convergence.  These tests exercise the full pipeline
the way §IV does, on CPU-scale grids.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic

pytestmark = pytest.mark.slow  # full end-to-end solves, ~25s of the suite


def test_synthetic_registration_end_to_end():
    """Paper §IV-B setup: sin^2 template, analytic velocity, beta=1e-2."""
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(32)
    out = register(
        rho_R,
        rho_T,
        RegistrationConfig(solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=20, gtol=1e-2)),
        grid=grid,
    )
    h = out["history"]
    assert h[-1]["rel_gnorm"] <= 1e-2  # paper's g_tol
    assert out["newton_iters"] <= 10  # a handful of GN iterations
    assert out["det_min"] > 0  # diffeomorphic
    assert out["residual_rel"] < 0.6
    assert all(rec["step"] > 0 for rec in h)  # line search always accepted


def test_brain_like_multisubject_registration():
    """Paper §IV-C analogue: NIREP-like multi-subject pair, beta=1e-4-ish."""
    rho_R, rho_T, grid = synthetic.brain_like(24, seed=1)
    out = register(
        rho_R,
        rho_T,
        RegistrationConfig(
            solver=gn.GNConfig(beta=1e-3, n_t=4, max_newton=8, gtol=1e-2, max_cg=40)
        ),
        grid=grid,
    )
    assert out["det_min"] > 0
    assert out["residual_rel"] < 0.9
    assert out["history"][-1]["misfit"] < out["history"][0]["misfit"]


def test_recovered_velocity_reduces_transport_error():
    """The solver's v reproduces the observed deformation: transporting
    rho_T with the recovered v approximates rho_R far better than rho_T."""
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(24)
    out = register(
        rho_R,
        rho_T,
        RegistrationConfig(solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=10, gtol=1e-2)),
        grid=grid,
    )
    res0 = float(jnp.linalg.norm((rho_T - rho_R).ravel()))
    res1 = float(jnp.linalg.norm((out["rho_deformed"] - rho_R).ravel()))
    assert res1 < 0.6 * res0

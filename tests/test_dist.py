"""Distributed-path equivalence, via subprocesses with 8 placeholder
devices (XLA locks device count at first jax init, so these cannot run
in-process with the rest of the suite)."""
import pytest

from conftest import run_multidevice as _run

pytestmark = [pytest.mark.slow, pytest.mark.dist]


def test_pencil_fft_matches_local():
    _run(
        """
        from repro.core.grid import make_grid
        from repro.core.spectral import SpectralOps
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        ctx = DistContext(grid, mesh, halo=2)
        local = SpectralOps(grid)
        rng = np.random.default_rng(0)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        v = jnp.asarray(rng.standard_normal((3,)+grid.shape), jnp.float32)
        fs, vs = ctx.shard_scalar(f), ctx.shard_vector(v)
        for name, a, b in [
            ("grad", ctx.ops.grad(fs), local.grad(f)),
            ("div", ctx.ops.div(vs), local.div(v)),
            ("leray", ctx.ops.leray(vs), local.leray(v)),
            ("invbih", ctx.ops.inv_biharmonic(fs), local.inv_biharmonic(f)),
        ]:
            err = float(jnp.max(jnp.abs(a - b)))
            assert err < 1e-3, (name, err)
        """
    )


def test_halo_interp_matches_reference():
    _run(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.kernels import ref
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        rng = np.random.default_rng(0)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        for halo in (1, 4, 9):  # 9 > shard width: multi-hop exchange
            ctx = DistContext(grid, mesh, halo=halo)
            d = jnp.asarray(rng.uniform(-halo+0.01, halo-0.01, (3,)+grid.shape), jnp.float32)
            out = jax.jit(ctx.interp)(ctx.shard_scalar(f), jax.device_put(d, ctx.vector_sharding()))
            err = float(jnp.max(jnp.abs(out - ref.tricubic_displace(f, d))))
            assert err < 1e-4, (halo, err)
        """
    )


def test_distributed_gn_iteration_matches_local():
    _run(
        """
        from functools import partial
        from repro.core.grid import make_grid
        from repro.core.spectral import SpectralOps
        from repro.core import objective as obj, gauss_newton as gn
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.data import synthetic
        rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        local = SpectralOps(grid)
        cfg = gn.GNConfig()
        prob_l = obj.Problem(grid, rho_R, rho_T, 1e-2, 4, False)
        prob_d = obj.Problem(grid, ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T), 1e-2, 4, False)
        v0 = jnp.zeros((3,)+grid.shape, jnp.float32)
        vl, ll = jax.jit(partial(gn.newton_iteration, prob=prob_l, ops=local, cfg=cfg))(v0, jnp.float32(1))
        vd, ld = jax.jit(partial(gn.newton_iteration, prob=prob_d, ops=ctx.ops, cfg=cfg, interp=ctx.interp))(
            ctx.shard_vector(v0), jnp.float32(1))
        assert float(jnp.max(jnp.abs(vl - vd))) < 1e-4
        assert int(ll.cg_iters) == int(ld.cg_iters)
        """
    )


def test_multipod_tuple_axis_pencil():
    _run(
        """
        from functools import partial
        from repro.core.grid import make_grid
        from repro.core import objective as obj, gauss_newton as gn
        from repro.core.spectral import SpectralOps
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.data import synthetic
        rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16)
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        ctx = DistContext(grid, mesh, axes=(("pod","data"),"model"), halo=4)
        local = SpectralOps(grid)
        cfg = gn.GNConfig()
        prob_d = obj.Problem(grid, ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T), 1e-2, 4, False)
        prob_l = obj.Problem(grid, rho_R, rho_T, 1e-2, 4, False)
        v0 = jnp.zeros((3,)+grid.shape, jnp.float32)
        vd, _ = jax.jit(partial(gn.newton_iteration, prob=prob_d, ops=ctx.ops, cfg=cfg, interp=ctx.interp))(
            ctx.shard_vector(v0), jnp.float32(1))
        vl, _ = jax.jit(partial(gn.newton_iteration, prob=prob_l, ops=local, cfg=cfg))(v0, jnp.float32(1))
        assert float(jnp.max(jnp.abs(vl - vd))) < 1e-4
        """
    )


def test_distributed_incompressible_gn_matches_local():
    """Leray/`ksq_d` on the PencilFFT backend: the incompressible GN
    iteration on the mesh is pinned to the local solver."""
    _run(
        """
        from functools import partial
        from repro.core.grid import make_grid
        from repro.core.spectral import SpectralOps
        from repro.core import objective as obj, gauss_newton as gn
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.data import synthetic
        rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16, incompressible=True, amplitude=0.5)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        local = SpectralOps(grid)
        cfg = gn.GNConfig(incompressible=True)
        prob_l = obj.Problem(grid, rho_R, rho_T, 1e-2, 4, True)
        prob_d = obj.Problem(grid, ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T), 1e-2, 4, True)
        v0 = jnp.zeros((3,)+grid.shape, jnp.float32)
        vl, ll = jax.jit(partial(gn.newton_iteration, prob=prob_l, ops=local, cfg=cfg))(v0, jnp.float32(1))
        vd, ld = jax.jit(partial(gn.newton_iteration, prob=prob_d, ops=ctx.ops, cfg=cfg, interp=ctx.interp))(
            ctx.shard_vector(v0), jnp.float32(1))
        assert float(jnp.max(jnp.abs(vl - vd))) < 1e-4
        assert int(ll.cg_iters) == int(ld.cg_iters)
        # the step stays (discretely) divergence free on the mesh
        assert float(jnp.max(jnp.abs(ctx.ops.div(vd)))) < 1e-3
        """
    )


def test_register_on_mesh_matches_local():
    """``register(..., ctx=ctx)`` runs the SOLVE AND THE DIAGNOSTICS on the
    mesh backend (regression: diagnostics used to rebuild a local
    SpectralOps/default interp regardless of how the solve ran), and the
    whole result dict is pinned to the local pipeline."""
    _run(
        """
        from repro.core import gauss_newton as gn
        from repro.core.registration import RegistrationConfig, register
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.data import synthetic
        rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(16, n_t=2)
        cfg = RegistrationConfig(
            solver=gn.GNConfig(beta=1e-2, n_t=2, max_newton=3, gtol=1e-2, max_cg=10))
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = DistContext(grid, mesh, halo=4)
        out_l = register(rho_R, rho_T, cfg, grid=grid)
        out_d = register(ctx.shard_scalar(rho_R), ctx.shard_scalar(rho_T), cfg,
                         grid=grid, ctx=ctx)
        for key in ("v", "displacement", "det_grad_y", "rho_deformed"):
            err = float(jnp.max(jnp.abs(out_l[key] - out_d[key])))
            assert err < 1e-3, (key, err)
        for key in ("residual_rel", "residual_rel_smoothed", "det_min", "det_max"):
            assert abs(out_l[key] - out_d[key]) < 1e-3, (key, out_l[key], out_d[key])
        assert out_l["newton_iters"] == out_d["newton_iters"]
        """
    )


def test_halo_budget_check():
    """Dynamic halo budget (ROADMAP): an overshooting displacement either
    NaN-poisons (halo_check="error") or falls back to the exact global
    gather (halo_check="gather") instead of silently reading wrapped ghosts."""
    _run(
        """
        from repro.core.grid import make_grid
        from repro.dist.context import DistContext
        from repro.launch.mesh import make_mesh
        from repro.kernels import ref
        mesh = make_mesh((2, 4), ("data", "model"))
        grid = make_grid((16, 16, 32))
        halo = 4
        rng = np.random.default_rng(0)
        f = jnp.asarray(rng.standard_normal(grid.shape), jnp.float32)
        d_ok = jnp.asarray(rng.uniform(-halo+0.01, halo-0.01, (3,)+grid.shape), jnp.float32)
        d_bad = d_ok.at[0, 0, 0, 0].set(halo + 2.5)

        ctx = DistContext(grid, mesh, halo=halo)  # default: halo_check="error"
        put = lambda c, d: (c.shard_scalar(f), jax.device_put(d, c.vector_sharding()))
        ok_out = jax.jit(ctx.interp)(*put(ctx, d_ok))
        assert float(jnp.max(jnp.abs(ok_out - ref.tricubic_displace(f, d_ok)))) < 1e-4
        assert bool(jnp.all(jnp.isnan(jax.jit(ctx.interp)(*put(ctx, d_bad)))))

        ctx_g = DistContext(grid, mesh, halo=halo, halo_check="gather")
        bad_out = jax.jit(ctx_g.interp)(*put(ctx_g, d_bad))
        assert float(jnp.max(jnp.abs(bad_out - ref.tricubic_displace(f, d_bad)))) < 1e-4
        """
    )


def test_mini_registration_dryrun_cells():
    """The registration dry-run machinery (single-level incompressible +
    multilevel ladder) end-to-end on the shrunken 8-device mesh."""
    _run(
        """
        import repro.launch.dryrun as dr
        from repro.launch import mesh as meshmod
        meshmod.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2,2,2), ("pod","data","model")) if multi_pod
            else jax.make_mesh((2,4), ("data","model")))
        dr.make_production_mesh = meshmod.make_production_mesh
        from repro.configs.claire_registration import RegConfig
        rcfg = RegConfig("mini-inc", (16, 16, 32), incompressible=True, halo=2)
        rec = dr.lower_registration_cell("mini-inc", False, verbose=False, rcfg=rcfg)
        assert rec["status"] == "ok", rec
        assert rec["components"]["hessian_matvec"]["hbm_bytes_per_chip"] > 0
        rcfg_ml = RegConfig("mini-ml", (16, 16, 32), halo=2,
                            levels=((8, 8, 16), (16, 16, 32)))
        rec2 = dr.lower_multilevel_cell("mini-ml", False, verbose=False, rcfg=rcfg_ml)
        assert rec2["status"] == "ok", rec2
        assert len(rec2["levels"]) == 2
        assert rec2["levels"][0]["fine_equiv_matvec_weight"] == 0.125
        assert rec2["levels"][1]["prolong_collectives"], rec2["levels"][1]
        """
    )


def test_lm_train_step_shards_and_runs():
    """Sharded smoke-model train step on a 2x2x2 pod mesh executes and
    matches the single-device loss."""
    _run(
        """
        from repro.configs import get_smoke_config
        from repro.models.common import ShardRules
        from repro.optim import adamw
        from repro.train.steps import build_model, make_train_step
        from repro.launch.mesh import make_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_smoke_config("qwen3-1.7b")
        model = build_model(cfg)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = ShardRules(mesh)
        params, specs = model.init(jax.random.PRNGKey(0), rules)
        flat_p, tdef = jax.tree.flatten(params)
        flat_s = jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0]
        params = tdef.unflatten([
            jax.device_put(a, NamedSharding(mesh, s)) for a, s in zip(flat_p, flat_s)])
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        bsh = NamedSharding(mesh, P(("pod","data"), None))
        batch_sharded = {k: jax.device_put(v, bsh) for k, v in batch.items()}
        step = jax.jit(make_train_step(model, adamw.AdamWConfig()))
        opt = adamw.init_state(params)
        p2, o2, m = step(params, opt, batch_sharded)
        assert np.isfinite(float(m["loss"]))

        # single-device comparison
        params1, _ = model.init(jax.random.PRNGKey(0), ShardRules(mesh))
        step1 = jax.jit(make_train_step(model, adamw.AdamWConfig()))
        _, _, m1 = step1(params1, adamw.init_state(params1), batch)
        assert abs(float(m["loss"]) - float(m1["loss"])) < 1e-3
        """
    )


def test_mini_dryrun_cell():
    """The dry-run machinery end-to-end on 8 devices (8-chip 'production')."""
    _run(
        """
        import repro.launch.dryrun as dr
        from repro.launch import mesh as meshmod
        # shrink the production mesh for the 8-device subprocess
        meshmod.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2,2,2), ("pod","data","model")) if multi_pod
            else jax.make_mesh((2,4), ("data","model")))
        dr.make_production_mesh = meshmod.make_production_mesh
        import dataclasses
        from repro.configs import get_smoke_config
        import repro.configs as C
        smoke = get_smoke_config("qwen3-1.7b")
        smoke = dataclasses.replace(smoke, name="qwen3-1.7b")
        C._MODULES["qwen3-1.7b"].config = lambda: smoke
        rec = dr.lower_lm_cell("qwen3-1.7b", "train_4k", multi_pod=False, verbose=False)
        assert rec["status"] == "ok", rec
        assert rec["roofline"]["flops_per_chip"] > 0
        rec2 = dr.lower_lm_cell("qwen3-1.7b", "decode_32k", multi_pod=True, verbose=False)
        assert rec2["status"] == "ok", rec2
        """
    )

"""Per-architecture smoke tests (deliverable f): every assigned arch runs a
reduced-config forward + train step on CPU with shape/NaN assertions, and
representative archs check decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models.common import ShardRules
from repro.optim import adamw
from repro.train.steps import build_model, make_train_step

ARCHS = list_archs()


def _batch(cfg, rng, b=2, s=16):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.enc_layers:
        batch["frames"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    return batch


def test_all_ten_archs_present():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab)
    assert got == expected


def test_moe_expert_counts():
    m = get_config("moonshot-v1-16b-a3b")
    q = get_config("qwen3-moe-235b-a22b")
    assert (m.n_experts, m.top_k) == (64, 6)
    assert (q.n_experts, q.top_k) == (128, 8)


def test_param_counts_plausible():
    assert 8.0e9 < get_config("gemma-7b").param_count() < 9.5e9
    q = get_config("qwen3-moe-235b-a22b")
    assert 2.0e11 < q.param_count() < 2.6e11
    assert 1.5e10 < q.active_param_count() < 3.0e10
    assert 1.0e8 < get_config("mamba2-130m").param_count() < 2.0e8


@pytest.mark.parametrize(
    "arch",
    [
        # the two heaviest smoke configs only run in the full tier
        pytest.param(a, marks=pytest.mark.slow) if a in ("zamba2-2.7b", "gemma3-1b") else a
        for a in ARCHS
    ],
)
def test_smoke_forward_and_train(arch, rng, single_mesh):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    params, specs = model.init(jax.random.PRNGKey(0), rules)
    # every param leaf has a matching spec leaf
    from jax.sharding import PartitionSpec as P

    n_p = len(jax.tree.leaves(params))
    n_s = len(jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0])
    assert n_p == n_s

    batch = _batch(cfg, rng)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    step = jax.jit(make_train_step(model, adamw.AdamWConfig(warmup_steps=1)))
    p2, o2, metrics = step(params, adamw.init_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
        if a.dtype != jnp.int32
    )
    assert delta > 0


@pytest.mark.parametrize(
    "arch",
    [
        "gemma-7b",
        pytest.param("gemma3-1b", marks=pytest.mark.slow),
        "mamba2-130m",
        pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
        "qwen2-vl-72b",
    ],
)
def test_decode_matches_forward(arch, rng, single_mesh):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    params, _ = model.init(jax.random.PRNGKey(0), rules)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full = model.forward(params, {"tokens": tokens})
    caches, _ = model.cache_init(b, s, rules)
    dec = jax.jit(model.decode)
    outs = []
    for t in range(s):
        lg, caches = dec(params, tokens[:, t : t + 1], jnp.int32(t), caches)
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got[..., : cfg.vocab], np.float32),
        np.asarray(full[..., : cfg.vocab], np.float32),
        atol=2e-4, rtol=1e-3,
    )


@pytest.mark.slow
def test_moe_decode_matches_forward_dense_path(rng, single_mesh):
    cfg = dataclasses.replace(get_smoke_config("qwen3-moe-235b-a22b"), moe_dispatch="dense")
    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    params, _ = model.init(jax.random.PRNGKey(0), rules)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    full = model.forward(params, {"tokens": tokens})
    caches, _ = model.cache_init(2, 8, rules)
    outs = []
    for t in range(8):
        lg, caches = model.decode(params, tokens[:, t : t + 1], jnp.int32(t), caches)
        outs.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, axis=1), np.float32),
        np.asarray(full, np.float32), atol=2e-4, rtol=1e-3,
    )


def test_sliding_window_restricts_attention(rng, single_mesh):
    """gemma3 local layers: token attends at most `window` back."""
    cfg = get_smoke_config("gemma3-1b")
    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    params, _ = model.init(jax.random.PRNGKey(0), rules)
    s = 24  # window is 8 in the smoke config
    t1 = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
    t2 = t1.at[0, 0].set((int(t1[0, 0]) + 7) % cfg.vocab)  # perturb token 0
    l1 = model.forward(params, {"tokens": t1})
    l2 = model.forward(params, {"tokens": t2})
    # with 2 local(w=8) + 1 global per group x2 groups the receptive field is
    # bounded but wide; just assert the perturbation effect decays
    early = float(jnp.max(jnp.abs(l1[0, 1] - l2[0, 1])))
    late = float(jnp.max(jnp.abs(l1[0, -1] - l2[0, -1])))
    assert early > late


def test_seamless_encoder_is_bidirectional(rng, single_mesh):
    cfg = get_smoke_config("seamless-m4t-large-v2")
    from repro.models import encdec

    model = build_model(cfg)
    rules = ShardRules(single_mesh)
    params, _ = model.init(jax.random.PRNGKey(0), rules)
    frames = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    mem1 = encdec.encode(cfg, params, frames)
    frames2 = frames.at[0, -1].add(1.0)  # perturb LAST frame
    mem2 = encdec.encode(cfg, params, frames2)
    # first position must change too (bidirectional)
    assert float(jnp.max(jnp.abs(mem1[0, 0] - mem2[0, 0]))) > 1e-6

"""Spectral operator correctness (paper §III-B1) and the transform-
coalescing SpectralBatch (one forward + one inverse ride per batch)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grid import make_grid
from repro.core.spectral import SpectralOps


@pytest.fixture(scope="module")
def ops32():
    g = make_grid(32)
    return g, SpectralOps(g)


def test_gradient_analytic(ops32):
    g, ops = ops32
    x = g.coords_jnp()
    f = jnp.sin(x[0]) * jnp.cos(2 * x[1]) + jnp.sin(3 * x[2])
    gf = ops.grad(f)
    exact = jnp.stack(
        [
            jnp.cos(x[0]) * jnp.cos(2 * x[1]),
            -2 * jnp.sin(x[0]) * jnp.sin(2 * x[1]),
            3 * jnp.cos(3 * x[2]),
        ]
    )
    np.testing.assert_allclose(gf, exact, atol=1e-4)


def test_divergence_analytic(ops32):
    g, ops = ops32
    x = g.coords_jnp()
    v = jnp.stack([jnp.sin(x[0]), jnp.cos(x[1]), jnp.sin(2 * x[2])])
    exact = jnp.cos(x[0]) - jnp.sin(x[1]) + 2 * jnp.cos(2 * x[2])
    np.testing.assert_allclose(ops.div(v), exact, atol=1e-4)


def test_laplacian_and_inverse(ops32):
    g, ops = ops32
    x = g.coords_jnp()
    f = jnp.sin(x[0]) * jnp.cos(2 * x[1]) + jnp.sin(3 * x[2])
    f0 = f - jnp.mean(f)
    np.testing.assert_allclose(ops.inv_laplacian(ops.laplacian(f)), f0, atol=1e-4)


def test_biharmonic_inverse_roundtrip(ops32, rng):
    g, ops = ops32
    f = ops.smooth(jnp.asarray(rng.standard_normal(g.shape), jnp.float32), 0.4)
    f0 = f - jnp.mean(f)
    np.testing.assert_allclose(ops.inv_biharmonic(ops.biharmonic(f)), f0, atol=1e-3)


def test_leray_projection_divergence_free(ops32, rng):
    g, ops = ops32
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    pv = ops.leray(v)
    assert float(jnp.max(jnp.abs(ops.div(pv)))) < 1e-4


def test_leray_idempotent_and_symmetric(ops32, rng):
    g, ops = ops32
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    pv = ops.leray(v)
    np.testing.assert_allclose(ops.leray(pv), pv, atol=2e-5)
    # <Pv, w> == <v, Pw>
    a = float(g.inner(pv, w))
    b = float(g.inner(v, ops.leray(w)))
    assert abs(a - b) < 1e-3 * max(abs(a), 1.0)


def test_leray_keeps_divfree_field(ops32):
    g, ops = ops32
    x = g.coords_jnp()
    v = jnp.stack([jnp.sin(x[1]), jnp.sin(x[2]), jnp.sin(x[0])])  # div-free
    np.testing.assert_allclose(ops.leray(v), v, atol=1e-4)


def test_precond_is_reg_inverse(ops32, rng):
    g, ops = ops32
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    v0 = v - jnp.mean(v.reshape(3, -1), axis=1)[:, None, None, None]
    out = ops.precond_apply(ops.reg_apply(v0, 1e-2), 1e-2)
    # k^4 scaling amplifies f32 roundoff: condition ~ (N/2)^4
    np.testing.assert_allclose(out, v0, atol=2e-3)


def test_gaussian_smoothing_dc_preserving(ops32, rng):
    g, ops = ops32
    f = jnp.asarray(rng.standard_normal(g.shape), jnp.float32)
    sf = ops.smooth(f)
    assert abs(float(jnp.mean(sf) - jnp.mean(f))) < 1e-5
    # smoothing reduces the H1 seminorm
    gn = lambda a: float(g.norm_sq(ops.grad(a)))
    assert gn(sf) < gn(f)


def test_jacobian_det_identity_and_translation(ops32):
    g, ops = ops32
    u = jnp.zeros((3,) + g.shape, jnp.float32)
    np.testing.assert_allclose(ops.jacobian_det(u), 1.0, atol=1e-5)
    np.testing.assert_allclose(ops.jacobian_det(u + 0.3), 1.0, atol=1e-4)


def test_jacobian_det_analytic(ops32):
    g, ops = ops32
    x = g.coords_jnp()
    eps = 0.1
    u = jnp.stack([eps * jnp.sin(x[0]), jnp.zeros(g.shape), jnp.zeros(g.shape)])
    det = ops.jacobian_det(u)
    np.testing.assert_allclose(det, 1.0 + eps * jnp.cos(x[0]), atol=1e-4)


# --------------------------------------------------------------------------- #
# SpectralBatch: coalesced ops == eager ops (ISSUE 5 tentpole, local leg)
# --------------------------------------------------------------------------- #
def test_batch_matches_eager_ops(ops32, rng):
    """Every coalesced op resolves to its eager counterpart."""
    g, ops = ops32
    f = jnp.asarray(rng.standard_normal(g.shape), jnp.float32)
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    series = jnp.asarray(rng.standard_normal((2, 3) + g.shape), jnp.float32)
    with ops.batch() as sb:
        handles = {
            "grad": (sb.grad(f), ops.grad(f)),
            "div": (sb.div(v), ops.div(v)),
            "div_series": (sb.div(series), ops.div(series)),
            "laplacian": (sb.laplacian(f), ops.laplacian(f)),
            "biharmonic": (sb.biharmonic(f), ops.biharmonic(f)),
            "inv_laplacian": (sb.inv_laplacian(f), ops.inv_laplacian(f)),
            "inv_biharmonic": (sb.inv_biharmonic(f), ops.inv_biharmonic(f)),
            "reg_apply": (sb.reg_apply(v, 1e-2), ops.reg_apply(v, 1e-2)),
            "precond_apply": (sb.precond_apply(v, 1e-2), ops.precond_apply(v, 1e-2)),
            "leray": (sb.leray(v), ops.leray(v)),
            "precond_project": (
                sb.precond_project(v, 1e-2, True),
                ops.precond_project(v, 1e-2, True),
            ),
            "reg_plus_project": (
                sb.reg_plus_project(v, w, 1e-2, True),
                ops.reg_plus_project(v, w, 1e-2, True),
            ),
            "smooth": (sb.smooth(f, 0.4), ops.smooth(f, 0.4)),
        }
    for name, (h, want) in handles.items():
        got = h.get()
        assert got.shape == want.shape, (name, got.shape, want.shape)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 2e-3, (name, err)


def test_batch_dedups_inputs_one_ride_pair(ops32, rng):
    """N ops on the same field share one forward; the whole batch is ONE
    forward + ONE inverse call on the backend."""
    g, _ = ops32
    ops = SpectralOps(g)
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    calls = {"fwd": [], "inv": []}
    fwd0, inv0 = ops.fwd_real, ops.inv_real
    ops.fwd_real = lambda u: (calls["fwd"].append(u.shape), fwd0(u))[1]
    ops.inv_real = lambda s: (calls["inv"].append(s.shape), inv0(s))[1]
    with ops.batch() as sb:
        sb.div(v), sb.reg_apply(v, 1e-2), sb.laplacian(v)
    assert calls["fwd"] == [(3,) + g.shape], calls  # v transformed ONCE
    assert len(calls["inv"]) == 1, calls
    assert calls["inv"][0][0] == 1 + 3 + 3, calls  # div + reg + lap outputs


def test_batch_handle_laziness_and_reuse_guard(ops32, rng):
    g, ops = ops32
    f = jnp.asarray(rng.standard_normal(g.shape), jnp.float32)
    sb = ops.batch()
    h = sb.laplacian(f)
    # .get() outside a `with` block triggers the ride
    np.testing.assert_allclose(h.get(), ops.laplacian(f), atol=1e-4)
    with pytest.raises(RuntimeError):
        sb.grad(f)  # batch already ran
    with ops.batch() as sb2:
        pass  # empty batch is a no-op
    with pytest.raises(ValueError):
        ops.batch().laplacian(f[0])  # not a grid-shaped field


def test_reg_energy_parseval_matches_composition(ops32, rng):
    """The Parseval lever: spectrum-side reg energy equals the real-space
    composition 0.5 <v, A v> without ever leaving k-space."""
    g, ops = ops32
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    beta = 1e-2
    want = 0.5 * g.inner(v, ops.reg_apply(v, beta))
    got = ops.reg_energy(v, beta)
    assert abs(float(got - want)) <= 1e-5 * max(abs(float(want)), 1.0), (got, want)
    # cohort stack reduces per-subject
    vs = jnp.stack([v, 2.0 * v])
    per = ops.reg_energy(vs, beta)
    assert per.shape == (2,)
    np.testing.assert_allclose(np.asarray(per)[1], 4.0 * float(want), rtol=1e-5)


def test_batch_reg_energy_reduction_skips_inverse_ride(ops32, rng):
    """A reduction job returns its value from the forward spectrum: a batch
    of only reductions performs ZERO inverse transforms, and a mixed batch
    adds none for the reduction member."""
    g, _ = ops32
    ops = SpectralOps(g)
    v = jnp.asarray(rng.standard_normal((3,) + g.shape), jnp.float32)
    calls = {"fwd": 0, "inv": 0}
    fwd0, inv0 = ops.fwd_real, ops.inv_real
    ops.fwd_real = lambda u: (calls.__setitem__("fwd", calls["fwd"] + 1), fwd0(u))[1]
    ops.inv_real = lambda s: (calls.__setitem__("inv", calls["inv"] + 1), inv0(s))[1]
    with ops.batch() as sb:
        h = sb.reg_energy(v, 1e-2)
    assert calls == {"fwd": 1, "inv": 0}, calls
    want = 0.5 * g.inner(v, SpectralOps(g).reg_apply(v, 1e-2))
    assert abs(float(h.get() - want)) <= 1e-5 * max(abs(float(want)), 1.0)
    # mixed batch: the div output still rides one inverse, reg_energy adds none
    with ops.batch() as sb:
        hr = sb.reg_energy(v, 1e-2)
        hd = sb.div(v)
    assert calls == {"fwd": 2, "inv": 1}, calls
    np.testing.assert_allclose(hd.get(), SpectralOps(g).div(v), atol=1e-4)

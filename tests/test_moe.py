"""MoE dispatch correctness (dense oracle vs capacity-bounded scatter)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.common import ArchConfig, ShardRules


def _cfg(**kw):
    base = dict(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4, n_kv=2,
        head_dim=8, d_ff=48, vocab=100, n_experts=8, top_k=2, capacity_factor=8.0,
    )
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def setup(single_mesh, test_seed):
    cfg = _cfg()
    rules = ShardRules(single_mesh)
    p, _ = moe.moe_init(cfg, jax.random.PRNGKey(test_seed), rules)
    return cfg, p


def test_scatter_matches_dense_with_ample_capacity(setup, rng):
    cfg, p = setup
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    yd = moe.moe_apply_dense(cfg, p, x)
    ys = moe.moe_apply_scatter(cfg, p, x)
    np.testing.assert_allclose(yd, ys, atol=1e-4)


def test_capacity_drops_tokens(setup, rng):
    cfg, p = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.5)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    yd = moe.moe_apply_dense(cfg, p, x)
    ys = moe.moe_apply_scatter(tight, p, x)
    assert float(jnp.max(jnp.abs(yd - ys))) > 1e-3  # some tokens dropped


def test_router_weights_normalized(setup, rng):
    cfg, p = setup
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    idx, w = moe._routing(cfg, p, x)
    assert idx.shape == (2, 16, 2) and w.shape == (2, 16, 2)
    np.testing.assert_allclose(jnp.sum(w, axis=-1), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < cfg.n_experts


def test_rank_in_expert_matches_numpy(rng):
    ids = jnp.asarray(rng.integers(0, 7, 200), jnp.int32)
    ranks = np.asarray(moe._rank_in_expert(ids, 8))
    seen = {}
    for i, e in enumerate(np.asarray(ids)):
        expect = seen.get(int(e), 0)
        assert ranks[i] == expect, (i, e, ranks[i], expect)
        seen[int(e)] = expect + 1


def test_decode_single_group_dispatch(setup, rng):
    """S=1 uses one whole-batch dispatch group; ample cf => exact."""
    cfg, p = setup
    x = jnp.asarray(rng.standard_normal((16, 1, 32)), jnp.float32)
    yd = moe.moe_apply_dense(cfg, p, x)
    ys = moe.moe_apply_scatter(cfg, p, x)
    np.testing.assert_allclose(yd, ys, atol=1e-4)


def test_dropped_tokens_keep_residual_shape(setup, rng):
    cfg, p = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    x = jnp.asarray(rng.standard_normal((1, 8, 32)), jnp.float32)
    y = moe.moe_apply_scatter(tight, p, x)
    assert y.shape == x.shape and not bool(jnp.isnan(y).any())

"""End-to-end LM training driver on the framework substrate.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Trains a reduced-config qwen3 on the deterministic synthetic token stream
for a few hundred steps with checkpoint/restart, demonstrating the training
substrate (AdamW + schedule + clipping, scan-over-layers + remat, atomic
keep-k checkpoints, straggler watchdog).  Interrupt and re-run: it resumes
bit-exactly from the last checkpoint.
"""
import sys
sys.path.insert(0, "src")
sys.argv = [sys.argv[0], "--mode", "lm", "--arch", "qwen3-1.7b", "--smoke",
            "--steps", sys.argv[sys.argv.index("--steps")+1] if "--steps" in sys.argv else "200",
            "--batch", "8", "--seq", "64",
            "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "50", "--log-every", "20"]

from repro.launch.train import main

if __name__ == "__main__":
    main()

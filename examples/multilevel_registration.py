"""Coarse-to-fine registration (repro.multilevel, CLAIRE-style continuation).

    PYTHONPATH=src python examples/multilevel_registration.py

Solves the brain-like phantom pair through a 3-level ladder (n/4 -> n/2 -> n)
with the beta-continuation schedule spread across levels, then re-solves at
fixed fine resolution for the cost comparison the paper's successors report:
most Newton progress bought at coarse resolution, the warm-started fine
level finishing in a handful of cheap CG iterations.
"""
import sys, time
import numpy as np
sys.path.insert(0, "src")

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic
from repro.multilevel.hierarchy import MultilevelConfig


def main():
    n = 32
    rho_R, rho_T, grid = synthetic.brain_like(n, seed=3)
    solver = gn.GNConfig(
        beta=1e-3, beta_continuation=(1e-1, 1e-2), n_t=4,
        max_newton=8, gtol=1e-2, max_cg=40,
    )

    # precond="vcycle": recursive Galerkin multigrid preconditioner at every
    # warm-started level (see EXPERIMENTS.md §Multilevel for the beta sweep)
    cfg = RegistrationConfig(
        multilevel=MultilevelConfig(solver=solver, n_levels=3, precond="vcycle")
    )
    t0 = time.time()
    out = register(rho_R, rho_T, cfg, grid=grid, verbose=True)
    t_ml = time.time() - t0
    print(f"\nmultilevel: {t_ml:.1f}s residual_rel={out['residual_rel']:.4f} "
          f"det in [{out['det_min']:.3f}, {out['det_max']:.3f}]")
    for lv in out["levels"]:
        print(f"  level {lv['shape']} betas={lv['betas']} newton={lv['newton_iters']} "
              f"matvecs={lv['hessian_matvecs']} (fine-equiv {lv['fine_equiv_matvecs']:.1f}) "
              f"{lv['wall_s']:.1f}s")
    print(f"  fine-grid matvecs: {out['fine_matvecs']}  "
          f"fine-equivalent total: {out['fine_equiv_matvecs']:.1f}  "
          f"(+{out['precond_fine_equiv_matvecs']:.1f} inside the V-cycle)")

    t0 = time.time()
    single = register(rho_R, rho_T, RegistrationConfig(solver=solver), grid=grid)
    print(f"single-level: {time.time()-t0:.1f}s residual_rel={single['residual_rel']:.4f} "
          f"matvecs={single['hessian_matvecs']}")

    mid = n // 2
    np.save("/tmp/multilevel_slices.npy", {
        "ref": np.asarray(rho_R[mid]), "template": np.asarray(rho_T[mid]),
        "deformed": np.asarray(out["rho_deformed"][mid]),
        "det": np.asarray(out["det_grad_y"][mid]),
    }, allow_pickle=True)
    print("axial slices written to /tmp/multilevel_slices.npy")


if __name__ == "__main__":
    main()

"""Multi-subject brain-like registration (paper §IV-C analogue).

    PYTHONPATH=src python examples/brain_registration.py

Two NIREP-like phantom 'subjects' (shared anatomy, subject-specific jitter),
solved with beta continuation 1e-1 -> 1e-3 as the paper recommends for
real-world data; writes axial-slice arrays for inspection.
"""
import sys, time
import numpy as np
sys.path.insert(0, "src")

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


def main():
    n = 32
    rho_R, rho_T, grid = synthetic.brain_like(n, seed=3)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(
            beta=1e-3, beta_continuation=(1e-1, 1e-2), n_t=4,
            max_newton=8, gtol=1e-2, max_cg=40,
        )
    )
    t0 = time.time()
    out = register(rho_R, rho_T, cfg, grid=grid, verbose=True)
    print(f"\nsolved in {time.time()-t0:.1f}s; residual_rel={out['residual_rel']:.4f}")
    print(f"det(grad y1) in [{out['det_min']:.3f}, {out['det_max']:.3f}]")
    mid = n // 2
    np.save("/tmp/brain_slices.npy", {
        "ref": np.asarray(rho_R[mid]), "template": np.asarray(rho_T[mid]),
        "deformed": np.asarray(out["rho_deformed"][mid]),
        "det": np.asarray(out["det_grad_y"][mid]),
    }, allow_pickle=True)
    print("axial slices written to /tmp/brain_slices.npy")


if __name__ == "__main__":
    main()

"""Volume-preserving (incompressible) registration — the paper's hardest mode.

    PYTHONPATH=src python examples/incompressible_registration.py

Enforces div v = 0 via the spectral Leray projection; the resulting map is
locally volume preserving: det(grad y1) == 1 up to discretization error.
"""
import sys, time
sys.path.insert(0, "src")

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


def main():
    n = 24
    rho_R, rho_T, _, grid = synthetic.synthetic_problem(n, incompressible=True, amplitude=0.5)
    cfg = RegistrationConfig(
        solver=gn.GNConfig(beta=1e-2, n_t=4, incompressible=True, max_newton=10, gtol=1e-2)
    )
    t0 = time.time()
    out = register(rho_R, rho_T, cfg, grid=grid, verbose=True)
    print(f"\nsolved in {time.time()-t0:.1f}s; residual_rel={out['residual_rel']:.4f}")
    print(f"det(grad y1) in [{out['det_min']:.4f}, {out['det_max']:.4f}]  — volume preserving => ~1")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's synthetic registration problem (Fig. 5) end-to-end.

    PYTHONPATH=src python examples/quickstart.py

Builds rho_T = (sin^2 x1 + sin^2 x2 + sin^2 x3)/3, transports it with the
paper's analytic velocity to make rho_R, then recovers a velocity with the
Gauss-Newton-Krylov solver and reports convergence + diffeomorphism
diagnostics (det grad y > 0).
"""
import sys, time
sys.path.insert(0, "src")

from repro.core import gauss_newton as gn
from repro.core.registration import RegistrationConfig, register
from repro.data import synthetic


def main():
    n = 32
    rho_R, rho_T, v_star, grid = synthetic.synthetic_problem(n)
    print(f"grid {n}^3  |  beta=1e-2  n_t=4  gtol=1e-2  (paper defaults)")
    cfg = RegistrationConfig(
        solver=gn.GNConfig(beta=1e-2, n_t=4, max_newton=20, gtol=1e-2, max_cg=50)
    )
    t0 = time.time()
    out = register(rho_R, rho_T, cfg, grid=grid, verbose=True)
    print(f"\nsolved in {time.time()-t0:.1f}s")
    print(f"Newton iters: {out['newton_iters']}  Hessian matvecs: {out['hessian_matvecs']}")
    print(f"relative residual |rho_T(y1)-rho_R| / |rho_T-rho_R|: {out['residual_rel']:.4f}")
    print(f"det(grad y1) in [{out['det_min']:.3f}, {out['det_max']:.3f}]  (diffeomorphic: >0)")


if __name__ == "__main__":
    main()

"""Batched serving demo: prefill + streaming decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py

Runs a reduced gemma3 (sliding-window + global attention) through a
prefill-then-decode loop with ring-buffer local caches — the serving path
the decode_32k / long_500k dry-run cells lower at production shapes.
"""
import sys, time
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.common import ShardRules
from repro.train.steps import build_model, make_serve_step


def main():
    cfg = get_smoke_config("gemma3-1b")
    model = build_model(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    rules = ShardRules(mesh)
    params, _ = model.init(jax.random.PRNGKey(0), rules)

    b, prompt_len, gen_len = 4, 12, 20
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)), jnp.int32)

    caches, _ = model.cache_init(b, prompt_len + gen_len, rules)
    serve = jax.jit(make_serve_step(model))

    # prefill token-by-token (production path would batch this)
    tok = prompt[:, :1]
    for t in range(prompt_len):
        nxt, caches = serve(params, prompt[:, t:t+1], jnp.int32(t), caches)
    print(f"prefilled {b} sequences x {prompt_len} tokens")

    t0 = time.time()
    out = []
    tok = nxt
    for t in range(prompt_len, prompt_len + gen_len):
        tok, caches = serve(params, tok, jnp.int32(t), caches)
        out.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    print(f"decoded {gen_len} tokens/seq in {dt:.2f}s "
          f"({b*gen_len/dt:.1f} tok/s on CPU)")
    print("sample continuation (token ids):", [int(x) for x in np.stack(out, 1)[0]])


if __name__ == "__main__":
    main()

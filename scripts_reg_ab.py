import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json
sys.path.insert(0, "src")
from repro.launch.dryrun import _reg_component_costs
from repro.launch.mesh import make_production_mesh
from repro.core.grid import make_grid
from repro.dist.context import DistContext
from repro.configs import REGISTRATION_GRIDS

mesh = make_production_mesh()
rcfg = REGISTRATION_GRIDS["claire-256"]
grid = make_grid(rcfg.grid)
out = {}
for name, packed, fused in [("baseline", False, False), ("fused", False, True), ("fused+packed", True, True)]:
    ctx = DistContext(grid, mesh, halo=rcfg.halo, packed=packed)
    comps = _reg_component_costs(grid, ctx, rcfg, mesh, 256, fused=fused)
    out[name] = comps
    for c, v in comps.items():
        a2a = v["collectives"].get("all-to-all", {}).get("bytes", 0)
        cp = v["collectives"].get("collective-permute", {}).get("bytes", 0)
        print(f"{name:14s} {c:15s} coll={v['t_collective_s']*1e3:8.3f}ms  a2a={a2a/1e6:8.1f}MB  halo={cp/1e6:6.1f}MB  mem={v['t_memory_s']*1e3:8.3f}ms")
json.dump(out, open("results/reg_perf_ab.json", "w"), indent=1)
